"""Model configuration ladder shared between the Python compile path and the
Rust coordinator (via artifacts/<cfg>/manifest.json).

Two transformer families stand in for the paper's model zoo (DESIGN.md §2):

* family ``Q`` (Qwen3-like): RMSNorm pre-norm, RoPE, GQA, SwiGLU, QK-norm,
  tied input/output embedding.
* family ``L`` (LLaMA3-like): identical skeleton minus QK-norm, untied
  ``lm_head``.

The size ladder replaces the paper's 0.6B..8B / 1B..8B checkpoints with a
1-CPU-core-trainable ladder; layer-heterogeneity (what LieQ measures) comes
from training, not scale, so the ladder preserves the phenomenon.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "Q" | "L"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int = 512
    rope_theta: float = 10000.0
    group_size: int = 64  # quantization group size along input dim

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def qk_norm(self) -> bool:
        return self.family == "Q"

    @property
    def tied_embedding(self) -> bool:
        return self.family == "Q"

    def param_spec(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Canonical flat parameter order. The Rust side binds artifact
        arguments positionally against this exact list (via manifest.json),
        so the order here is load-bearing."""
        d, hd = self.d_model, self.d_head
        nq, nkv, dff, v = self.n_heads, self.n_kv_heads, self.d_ff, self.vocab
        spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (v, d))]
        for l in range(self.n_layers):
            p = f"layers.{l}."
            spec.append((p + "attn_norm", (d,)))
            spec.append((p + "q_proj", (d, nq * hd)))
            spec.append((p + "k_proj", (d, nkv * hd)))
            spec.append((p + "v_proj", (d, nkv * hd)))
            if self.qk_norm:
                spec.append((p + "q_norm", (hd,)))
                spec.append((p + "k_norm", (hd,)))
            spec.append((p + "o_proj", (nq * hd, d)))
            spec.append((p + "mlp_norm", (d,)))
            spec.append((p + "gate_proj", (d, dff)))
            spec.append((p + "up_proj", (d, dff)))
            spec.append((p + "down_proj", (dff, d)))
        spec.append(("final_norm", (d,)))
        if not self.tied_embedding:
            spec.append(("lm_head", (d, v)))
        return spec

    def n_params(self) -> int:
        return sum(int_prod(shape) for _, shape in self.param_spec())


def int_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# The ladder. Names mirror the paper's size axis (Table 1/2 rows).
LADDER: List[ModelConfig] = [
    ModelConfig("q_nano", "Q", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384),
    ModelConfig("q_micro", "Q", n_layers=6, d_model=192, n_heads=6, n_kv_heads=2, d_ff=512),
    ModelConfig("q_small", "Q", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4, d_ff=704),
    ModelConfig("q_base", "Q", n_layers=10, d_model=320, n_heads=8, n_kv_heads=4, d_ff=896),
    ModelConfig("l_nano", "L", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=384),
    ModelConfig("l_micro", "L", n_layers=6, d_model=192, n_heads=6, n_kv_heads=2, d_ff=512),
    ModelConfig("l_small", "L", n_layers=8, d_model=256, n_heads=8, n_kv_heads=4, d_ff=704),
]


def by_name(name: str) -> ModelConfig:
    for cfg in LADDER:
        if cfg.name == name:
            return cfg
    raise KeyError(f"unknown model config {name!r}")


# (batch, seq) shapes each artifact is lowered at.
EVAL_BATCH = {"b8_t128": (8, 128), "b2_t512": (2, 512)}
CAPTURE_BATCH = (4, 128)
TRAIN_BATCH = (8, 128)
LOGITS_BATCH = (4, 128)
