"""L2: JAX transformer families (Q = Qwen3-like, L = LLaMA3-like).

Everything here is build-time only. Each public entry point is lowered by
``aot.py`` to an HLO-text artifact executed from the Rust runtime:

* ``fwd_nll(tokens, skip_mask, *params)`` — per-token NLL with a per-layer
  residual-branch mask. ``skip_mask[l] = 0`` turns layer ``l`` into the
  identity-plus-residual of the paper's ΔPPL diagnostic (Eq. 1–2), so ONE
  artifact serves the baseline pass and all L ablation passes.
* ``capture(tokens, *params)`` — per-layer activations needed by the
  geometric diagnostics (Eq. 3–7) and the GPTQ/AWQ calibration Hessians.
* ``train_step(tokens, lr, step, *params, *m, *v)`` — AdamW with global
  gradient-norm clipping; the Rust coordinator drives the loop.
* ``fwd_logits(tokens, *params)`` — full logits for the generation demo.
* ``fwd_logits_quant(tokens, *packed)`` — deployment path: every linear
  goes through the Pallas fused dequant-GEMM kernel on bit-plane-packed
  weights (uniform bit-width; the paper's hardware-friendly layout).

Parameters are positional, in ``ModelConfig.param_spec()`` order — the
manifest pins this contract for the Rust side.
"""

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.dequant_matmul import dequant_matmul
from .kernels.rmsnorm import rmsnorm as rmsnorm_pallas

EPS = 1e-6


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + EPS)) * w


def rope_tables(t: int, d_head: int, theta: float):
    """Rotary embedding cos/sin tables: f32[T, d_head/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    ang = pos * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, T, H, hd]; rotate pairs (even, odd) along the last axis."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    y1 = x1 * c - x2 * s
    y2 = x1 * s + x2 * c
    # Re-interleave.
    y = jnp.stack([y1, y2], axis=-1)
    return y.reshape(x.shape)


def causal_attention(q, k, v, d_head: int):
    """q: [B, T, Hq, hd], k/v: [B, T, Hq, hd] (kv already repeated)."""
    t = q.shape[1]
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(d_head))
    mask = jnp.tril(jnp.ones((t, t), dtype=jnp.bool_))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bshd->bthd", probs, v)
    return ctx


class ParamView:
    """Positional parameter list with named access in param_spec order."""

    def __init__(self, cfg: ModelConfig, flat: Sequence):
        spec = cfg.param_spec()
        assert len(flat) == len(spec), (len(flat), len(spec))
        self.map = {name: p for (name, _), p in zip(spec, flat)}
        self.cfg = cfg

    def __getitem__(self, name: str):
        return self.map[name]


def _layer(cfg: ModelConfig, p: ParamView, l: int, x, cos, sin, gate, collect=None):
    """One transformer block; ``gate`` scales both residual branches
    (1.0 = normal, 0.0 = the paper's identity replacement)."""
    b, t, d = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pre = f"layers.{l}."

    a_in = rmsnorm(x, p[pre + "attn_norm"])
    q = (a_in @ p[pre + "q_proj"]).reshape(b, t, nq, hd)
    k = (a_in @ p[pre + "k_proj"]).reshape(b, t, nkv, hd)
    v = (a_in @ p[pre + "v_proj"]).reshape(b, t, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p[pre + "q_norm"])
        k = rmsnorm(k, p[pre + "k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    rep = nq // nkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    ctx = causal_attention(q, k, v, hd).reshape(b, t, nq * hd)
    attn_out = ctx @ p[pre + "o_proj"]
    x = x + gate * attn_out

    m_in = rmsnorm(x, p[pre + "mlp_norm"])
    gate_h = jax.nn.silu(m_in @ p[pre + "gate_proj"])
    up_h = m_in @ p[pre + "up_proj"]
    act = gate_h * up_h
    mlp_out = act @ p[pre + "down_proj"]
    x = x + gate * mlp_out

    if collect is not None:
        collect["attn_in"].append(a_in)
        collect["ctx"].append(ctx)
        collect["mlp_in"].append(m_in)
        collect["mlp_act"].append(act)
    return x


def _backbone(cfg: ModelConfig, p: ParamView, tokens, skip_mask=None, collect=None):
    x = p["embed"][tokens]
    t = tokens.shape[1]
    cos, sin = rope_tables(t, cfg.d_head, cfg.rope_theta)
    for l in range(cfg.n_layers):
        gate = 1.0 if skip_mask is None else skip_mask[l]
        x = _layer(cfg, p, l, x, cos, sin, gate, collect)
    return rmsnorm(x, p["final_norm"])


def _logits(cfg: ModelConfig, p: ParamView, h):
    if cfg.tied_embedding:
        return h @ p["embed"].T
    return h @ p["lm_head"]


def _nll_from_logits(logits, tokens):
    """Per-token NLL of tokens[:, 1:] under logits[:, :-1]. -> [B, T-1]."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def fwd_nll(cfg: ModelConfig, tokens, skip_mask, *params):
    """-> per-token NLL f32[B, T-1]. skip_mask: f32[L]."""
    p = ParamView(cfg, params)
    h = _backbone(cfg, p, tokens, skip_mask=skip_mask)
    return (_nll_from_logits(_logits(cfg, p, h), tokens),)


def fwd_logits(cfg: ModelConfig, tokens, *params):
    """-> logits f32[B, T, V] (generation demo / task scoring)."""
    p = ParamView(cfg, params)
    h = _backbone(cfg, p, tokens)
    return (_logits(cfg, p, h),)


def capture(cfg: ModelConfig, tokens, *params):
    """-> (attn_in [L,B,T,d], ctx [L,B,T,nq*hd], mlp_in [L,B,T,d],
            mlp_act [L,B,T,dff], final [B,T,d]).

    ``attn_in`` is the post-norm hidden state h^(l): the input the trained
    W_Q/W_K/W_V actually see (compactness Eq. 3 and q/k/v calibration);
    ``ctx``/``mlp_in``/``mlp_act`` are the o_proj / gate,up / down_proj
    calibration inputs for GPTQ/AWQ Hessians. ``final`` (the post-norm
    last hidden state) keeps every parameter live in the lowered module —
    XLA DCEs unused function arguments, which would break the positional
    argument contract with the Rust runtime.
    """
    p = ParamView(cfg, params)
    collect = {"attn_in": [], "ctx": [], "mlp_in": [], "mlp_act": []}
    final = _backbone(cfg, p, tokens, collect=collect)
    if not cfg.tied_embedding:
        # Touch lm_head so family-L modules keep it as a parameter too.
        final = final + 0.0 * (final @ p["lm_head"] @ p["lm_head"].T)
    return (
        jnp.stack(collect["attn_in"]),
        jnp.stack(collect["ctx"]),
        jnp.stack(collect["mlp_in"]),
        jnp.stack(collect["mlp_act"]),
        final,
    )


def _loss(cfg: ModelConfig, params: List, tokens):
    p = ParamView(cfg, params)
    h = _backbone(cfg, p, tokens)
    nll = _nll_from_logits(_logits(cfg, p, h), tokens)
    return jnp.mean(nll)


def train_step(
    cfg: ModelConfig,
    tokens,
    lr,
    step,
    *state,
    beta1=0.9,
    beta2=0.95,
    eps=1e-8,
    weight_decay=0.01,
    clip=1.0,
):
    """One AdamW step. state = params + m + v (each n_params long).

    -> (loss, *new_params, *new_m, *new_v). Decay is not applied to norm
    gains or the embedding, matching common small-LM practice.
    """
    n = len(cfg.param_spec())
    assert len(state) == 3 * n, (len(state), n)
    params = list(state[:n])
    m = list(state[n : 2 * n])
    v = list(state[2 * n :])

    loss, grads = jax.value_and_grad(lambda ps: _loss(cfg, ps, tokens))(params)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = [g * scale for g in grads]

    names = [name for name, _ in cfg.param_spec()]
    t = step + 1.0
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t
    new_params, new_m, new_v = [], [], []
    for name, pi, gi, mi, vi in zip(names, params, grads, m, v):
        mi = beta1 * mi + (1.0 - beta1) * gi
        vi = beta2 * vi + (1.0 - beta2) * jnp.square(gi)
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        decay = 0.0 if (pi.ndim <= 1 or name == "embed") else weight_decay
        new_params.append(pi - lr * (upd + decay * pi))
        new_m.append(mi)
        new_v.append(vi)
    return tuple([loss] + new_params + new_m + new_v)


# ---------------------------------------------------------------------------
# Quantized deployment forward (Pallas dequant-GEMM on the real path)
# ---------------------------------------------------------------------------

QUANT_LINEARS = ["q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj", "down_proj"]


def quant_param_spec(cfg: ModelConfig, bits: int):
    """Packed-parameter order for fwd_logits_quant artifacts: every linear
    becomes (planes u32[bits, K/32, N], scale, min); everything else f32."""
    g = cfg.group_size
    spec = []
    for name, shape in cfg.param_spec():
        base = name.split(".")[-1]
        if base in QUANT_LINEARS:
            k, n = shape
            spec.append((name + ".planes", (bits, k // 32, n), "u32"))
            spec.append((name + ".scale", (k // g, n), "f32"))
            spec.append((name + ".min", (k // g, n), "f32"))
        else:
            spec.append((name, shape, "f32"))
    return spec


def fwd_logits_quant(cfg: ModelConfig, bits: int, tokens, *packed):
    """Deployment forward: linears run the Pallas fused dequant-GEMM on
    packed planes; norms run the Pallas RMSNorm kernel."""
    spec = quant_param_spec(cfg, bits)
    assert len(packed) == len(spec), (len(packed), len(spec))
    pm = {name: x for (name, _, _), x in zip(spec, packed)}
    g = cfg.group_size
    b, t = tokens.shape
    d, nq, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def lin(x2d, name):
        return dequant_matmul(
            x2d, pm[name + ".planes"], pm[name + ".scale"], pm[name + ".min"],
            bits=bits, group_size=g, block_n=128,
        )

    def norm2d(x2d, name):
        return rmsnorm_pallas(x2d, pm[name])

    x = pm["embed"][tokens]
    cos, sin = rope_tables(t, hd, cfg.rope_theta)
    for l in range(cfg.n_layers):
        pre = f"layers.{l}."
        x2 = x.reshape(b * t, d)
        a_in = norm2d(x2, pre + "attn_norm")
        q = lin(a_in, pre + "q_proj").reshape(b, t, nq, hd)
        k = lin(a_in, pre + "k_proj").reshape(b, t, nkv, hd)
        v = lin(a_in, pre + "v_proj").reshape(b, t, nkv, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, pm[pre + "q_norm"])
            k = rmsnorm(k, pm[pre + "k_norm"])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        rep = nq // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        ctx = causal_attention(q, k, v, hd).reshape(b * t, nq * hd)
        x = x + lin(ctx, pre + "o_proj").reshape(b, t, d)
        m_in = norm2d(x.reshape(b * t, d), pre + "mlp_norm")
        act = jax.nn.silu(lin(m_in, pre + "gate_proj")) * lin(m_in, pre + "up_proj")
        x = x + lin(act, pre + "down_proj").reshape(b, t, d)
    h = norm2d(x.reshape(b * t, d), "final_norm").reshape(b, t, d)
    if cfg.tied_embedding:
        return (h @ pm["embed"].T,)
    return (h @ pm["lm_head"],)


# ---------------------------------------------------------------------------
# Initialization (exported to artifacts/<cfg>/init.lieq; Rust trains from it)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in cfg.param_spec():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            out.append(jnp.ones(shape, jnp.float32))
        elif name == "embed" or name == "lm_head":
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out
