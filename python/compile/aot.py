"""AOT compile path: lower every L2 entry point to HLO **text** artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model config this emits, under ``artifacts/<cfg>/``:

    fwd_nll_b8_t128.hlo.txt   per-token NLL + skip-mask (PPL, ΔPPL, tasks)
    fwd_nll_b2_t512.hlo.txt   long-bucket variant
    fwd_logits_b4_t128.hlo.txt logits (generation / option scoring demo)
    capture_b4_t128.hlo.txt   diagnostic/calibration activations
    train_step_b8_t128.hlo.txt AdamW step (Rust-driven training)
    init.lieq                 seeded init parameters (tensor archive)
    manifest.json             dims + positional arg contract

plus, under ``artifacts/kernels/``, standalone Pallas kernel artifacts
(fused dequant-GEMM at gate_proj shapes, group-quant, rmsnorm) used by the
Rust integration tests and the Fig. 4 cross-check.

Usage: cd python && python -m compile.aot --out ../artifacts [--configs a,b]
"""

import argparse
import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tensorio
from .configs import (
    CAPTURE_BATCH,
    EVAL_BATCH,
    LADDER,
    LOGITS_BATCH,
    TRAIN_BATCH,
    ModelConfig,
)
from .kernels.dequant_matmul import dequant_matmul
from .kernels.group_quant import group_quant
from .kernels.rmsnorm import rmsnorm

I32 = jnp.int32
F32 = jnp.float32
U32 = jnp.uint32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(dt) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32", jnp.uint32: "u32"}[dt]


def lower_artifact(fn, arg_specs, out_dir: str, name: str, manifest_entry: dict) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(*[spec(s, d) for s, d in arg_specs])
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest_entry["file"] = f"{name}.hlo.txt"
    manifest_entry["inputs"] = [
        {"shape": list(s), "dtype": _dtype_name(d)} for s, d in arg_specs
    ]
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text in {time.time() - t0:.1f}s")
    return manifest_entry


def emit_model_artifacts(cfg: ModelConfig, out_root: str) -> None:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    pspec = cfg.param_spec()
    pshapes = [(shape, F32) for _, shape in pspec]
    L = cfg.n_layers
    artifacts = {}

    print(f"[{cfg.name}] {cfg.n_params() / 1e6:.2f}M params, L={L}, d={cfg.d_model}")

    for tag, (b, t) in EVAL_BATCH.items():
        artifacts[f"fwd_nll_{tag}"] = lower_artifact(
            lambda tok, mask, *ps: M.fwd_nll(cfg, tok, mask, *ps),
            [((b, t), I32), ((L,), F32)] + pshapes,
            out_dir,
            f"fwd_nll_{tag}",
            {"kind": "fwd_nll", "batch": b, "seq": t},
        )

    b, t = LOGITS_BATCH
    artifacts["fwd_logits_b4_t128"] = lower_artifact(
        lambda tok, *ps: M.fwd_logits(cfg, tok, *ps),
        [((b, t), I32)] + pshapes,
        out_dir,
        "fwd_logits_b4_t128",
        {"kind": "fwd_logits", "batch": b, "seq": t},
    )

    b, t = CAPTURE_BATCH
    artifacts["capture_b4_t128"] = lower_artifact(
        lambda tok, *ps: M.capture(cfg, tok, *ps),
        [((b, t), I32)] + pshapes,
        out_dir,
        "capture_b4_t128",
        {"kind": "capture", "batch": b, "seq": t},
    )

    b, t = TRAIN_BATCH
    artifacts["train_step_b8_t128"] = lower_artifact(
        lambda tok, lr, st, *state: M.train_step(cfg, tok, lr, st, *state),
        [((b, t), I32), ((), F32), ((), F32)] + pshapes * 3,
        out_dir,
        "train_step_b8_t128",
        {"kind": "train_step", "batch": b, "seq": t},
    )

    params = M.init_params(cfg, seed=hash(cfg.name) % (2**31))
    tensorio.write_archive(
        os.path.join(out_dir, "init.lieq"),
        [(name, np.asarray(p)) for (name, _), p in zip(pspec, params)],
    )

    manifest = {
        "name": cfg.name,
        "family": cfg.family,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "d_head": cfg.d_head,
        "d_ff": cfg.d_ff,
        "vocab": cfg.vocab,
        "qk_norm": cfg.qk_norm,
        "tied_embedding": cfg.tied_embedding,
        "rope_theta": cfg.rope_theta,
        "group_size": cfg.group_size,
        "n_params": cfg.n_params(),
        "params": [{"name": n, "shape": list(s)} for n, s in pspec],
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def emit_quant_deploy(cfg: ModelConfig, out_root: str, bits_list=(2, 4)) -> None:
    """Deployment forward with Pallas dequant-GEMM — emitted for one config
    (edge_deploy example + integration test)."""
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    b, t = 1, 128
    for bits in bits_list:
        qspec = M.quant_param_spec(cfg, bits)
        args = [((b, t), I32)] + [
            (shape, {"f32": F32, "u32": U32}[dt]) for _, shape, dt in qspec
        ]
        name = f"fwd_logits_quant_b{bits}_t128"
        entry = lower_artifact(
            lambda tok, *ps, _bits=bits: M.fwd_logits_quant(cfg, _bits, tok, *ps),
            args,
            out_dir,
            name,
            {"kind": "fwd_logits_quant", "bits": bits, "batch": b, "seq": t},
        )
        entry["packed_params"] = [
            {"name": n, "shape": list(s), "dtype": dt} for n, s, dt in qspec
        ]
        manifest["artifacts"][name] = entry
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)


def emit_kernel_artifacts(out_root: str) -> None:
    """Standalone Pallas kernel artifacts at the paper's Fig. 4 shapes
    (gate_proj of our two largest configs) for Rust integration tests."""
    out_dir = os.path.join(out_root, "kernels")
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    g = 64
    shapes = [("small", 256, 704), ("base", 320, 896)]
    for tag, k, n in shapes:
        for bits in (2, 3, 4):
            for m in (128, 512):
                name = f"dq_matmul_{tag}_b{bits}_m{m}"
                manifest[name] = lower_artifact(
                    lambda x, p, s, mn, _b=bits: (
                        dequant_matmul(x, p, s, mn, bits=_b, group_size=g, block_n=n),
                    ),
                    [((m, k), F32), ((bits, k // 32, n), U32), ((k // g, n), F32), ((k // g, n), F32)],
                    out_dir,
                    name,
                    {"kind": "dq_matmul", "bits": bits, "m": m, "k": k, "n": n, "group": g},
                )
    for tag, k, n in shapes:
        for bits in (2, 3, 4):
            name = f"group_quant_{tag}_b{bits}"
            manifest[name] = lower_artifact(
                lambda w, _b=bits: group_quant(w, bits=_b, group_size=g, block_n=n),
                [((k, n), F32)],
                out_dir,
                name,
                {"kind": "group_quant", "bits": bits, "k": k, "n": n, "group": g},
            )
    name = "rmsnorm_r512_d256"
    manifest[name] = lower_artifact(
        lambda x, w: (rmsnorm(x, w, block_r=128),),
        [((512, 256), F32), ((256,), F32)],
        out_dir,
        name,
        {"kind": "rmsnorm", "rows": 512, "d": 256},
    )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="", help="comma-separated subset of config names")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--skip-quant-deploy", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    wanted = [c for c in args.configs.split(",") if c]
    configs: List[ModelConfig] = [c for c in LADDER if not wanted or c.name in wanted]

    t0 = time.time()
    for cfg in configs:
        emit_model_artifacts(cfg, args.out)
    if not args.skip_quant_deploy:
        for cfg in configs:
            if cfg.name == "q_nano":
                emit_quant_deploy(cfg, args.out)
    if not args.skip_kernels:
        emit_kernel_artifacts(args.out)
    print(f"AOT done in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
