"""L1 Pallas kernel: fused dequantize-GEMM over bit-plane-packed weights.

This is the paper's hardware-efficiency story (Fig. 4): with *uniform*
bit-width inside a layer, the packed weight tensor is contiguous and one
GEMM kernel serves the whole layer — no per-element format dispatch, no
index side-tables (contrast: APTQ / LLM-MQ irregular layouts).

TPU adaptation of the paper's CUDA kernel (DESIGN.md §Hardware-Adaptation):
the N dimension is tiled by ``block_n`` via ``BlockSpec`` so each grid step
stages ``bits * K/32 * block_n`` u32 words of packed weights (8x fewer HBM
bytes than f32 at 2-bit) into VMEM, unpacks them once in-register, and
feeds an ``[M, K] x [K, block_n]`` MXU matmul. ``interpret=True`` is
mandatory here: the CPU PJRT plugin cannot execute Mosaic custom-calls, so
the kernel lowers to plain HLO and stays executable from the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (VMEM tile width)."""
    bn = min(want, n)
    while n % bn != 0:
        bn -= 1
    return bn


def _dq_matmul_kernel(x_ref, planes_ref, scale_ref, min_ref, o_ref, *, bits: int, group_size: int):
    """One grid step: o[M, bn] = x[M, K] @ dequant(planes[:, K/32, bn])."""
    x = x_ref[...]
    planes = planes_ref[...]
    scale = scale_ref[...]
    minv = min_ref[...]
    kw, bn = planes.shape[1], planes.shape[2]
    k = kw * 32

    # Unpack bit planes -> codes u32[K, bn]. One shift-and per plane; the
    # loop is static (bits is a compile-time constant), mirroring the
    # unrolled unpack in the Rust deployment kernel.
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    codes = jnp.zeros((kw, 32, bn), dtype=jnp.uint32)
    for j in range(bits):
        bit = (planes[j][:, None, :] >> shifts) & jnp.uint32(1)
        codes = codes | (bit << jnp.uint32(j))
    codes = codes.reshape(k, bn)

    # Dequantize: W = c * scale + min, group stats broadcast along K.
    g = group_size
    s = jnp.repeat(scale, g, axis=0)
    m = jnp.repeat(minv, g, axis=0)
    w = codes.astype(jnp.float32) * s + m

    # MXU-shaped contraction.
    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "block_n"))
def dequant_matmul(x, planes, scale, minv, *, bits: int, group_size: int = 64, block_n: int = 128):
    """x f32[M, K] @ W where W is packed as planes u32[bits, K/32, N],
    scale/min f32[K/g, N]. Returns f32[M, N]."""
    m, k = x.shape
    b, kw, n = planes.shape
    assert b == bits and kw * 32 == k, (planes.shape, x.shape, bits)
    assert k % group_size == 0
    bn = pick_block(n, block_n)

    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_dq_matmul_kernel, bits=bits, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((bits, kw, bn), lambda i: (0, 0, i)),
            pl.BlockSpec((k // group_size, bn), lambda i: (0, i)),
            pl.BlockSpec((k // group_size, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, planes, scale, minv)
