"""L1 Pallas kernel: RMSNorm over the feature axis.

Used by the quantized deployment forward (fwd_logits_q*) so the served
graph exercises the Pallas path end-to-end; row-tiled so each grid step
normalizes a block of token rows held in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    w = w_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(var + eps)) * w


@functools.partial(jax.jit, static_argnames=("block_r", "eps"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_r: int = 128):
    """x f32[R, D] (rows = flattened tokens), w f32[D] -> f32[R, D]."""
    r, d = x.shape
    br = min(block_r, r)
    assert r % br == 0, f"rows={r} not divisible by block {br}"
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=True,
    )(x, w)
