"""L1 Pallas kernel: group-wise asymmetric uniform quantizer.

Produces integer codes plus per-(group, out-channel) scale/min — the
per-element hot loop of every PTQ backend (RTN directly; GPTQ/AWQ call it
per column block / after scaling). The packing into bit planes is a cheap
static reshape-shift-sum and happens outside the kernel in ``pack_planes``
(still inside the jitted artifact, so the AOT graph is self-contained).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _group_quant_kernel(w_ref, codes_ref, scale_ref, min_ref, *, bits: int, group_size: int):
    w = w_ref[...]
    k, bn = w.shape
    g = group_size
    levels = (1 << bits) - 1
    wg = w.reshape(k // g, g, bn)
    mx = jnp.max(wg, axis=1)
    mn = jnp.min(wg, axis=1)
    scale = jnp.maximum((mx - mn) / levels, 1e-8)
    c = jnp.round((wg - mn[:, None, :]) / scale[:, None, :])
    c = jnp.clip(c, 0, levels).astype(jnp.uint32)
    codes_ref[...] = c.reshape(k, bn)
    scale_ref[...] = scale
    min_ref[...] = mn


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "block_n"))
def group_quant(w, *, bits: int, group_size: int = 64, block_n: int = 128):
    """w f32[K, N] -> (codes u32[K, N], scale f32[K/g, N], min f32[K/g, N])."""
    k, n = w.shape
    g = group_size
    assert k % g == 0
    from .dequant_matmul import pick_block

    bn = pick_block(n, block_n)
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_group_quant_kernel, bits=bits, group_size=group_size),
        grid=grid,
        in_specs=[pl.BlockSpec((k, bn), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((k, bn), lambda i: (0, i)),
            pl.BlockSpec((k // g, bn), lambda i: (0, i)),
            pl.BlockSpec((k // g, bn), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.uint32),
            jax.ShapeDtypeStruct((k // g, n), jnp.float32),
            jax.ShapeDtypeStruct((k // g, n), jnp.float32),
        ],
        interpret=True,
    )(w)


def quant_pack(w, *, bits: int, group_size: int = 64):
    """Full quantize-and-pack pipeline: kernel codes + jnp plane packing."""
    codes, scale, mn = group_quant(w, bits=bits, group_size=group_size)
    planes = ref.pack_ref(codes, bits)
    return planes, scale, mn
