"""Pure-jnp oracles for every Pallas kernel (L1 correctness contracts).

The quantization format is shared with the Rust deployment kernels
(rust/src/quant/pack.rs): group-wise asymmetric uniform quantization along
the input (K) dimension with bit-plane packing —

* codes ``c in [0, 2^b - 1]``, ``W ≈ c * scale + minv`` with per-(group, n)
  ``scale = (max - min) / (2^b - 1)``, ``minv = min``.
* planes: ``u32[b, K/32, N]``; bit ``k % 32`` of ``plane[j, k // 32, n]``
  is bit ``j`` of ``c[k, n]``.

The same layout for every bit-width keeps the unpack loop uniform (one
shift-and per plane), which is what makes the paper's "uniform within a
layer" scheme a single GEMM kernel per layer.
"""

import jax.numpy as jnp


def quantize_ref(w, group_size: int, bits: int):
    """Group-wise asymmetric uniform quantization. w: f32[K, N].

    Returns (codes u32[K, N], scale f32[K/g, N], minv f32[K/g, N]).
    """
    k, n = w.shape
    g = group_size
    assert k % g == 0, f"K={k} not divisible by group {g}"
    levels = (1 << bits) - 1
    wg = w.reshape(k // g, g, n)
    mx = jnp.max(wg, axis=1)
    mn = jnp.min(wg, axis=1)
    scale = jnp.maximum((mx - mn) / levels, 1e-8)
    c = jnp.round((wg - mn[:, None, :]) / scale[:, None, :])
    c = jnp.clip(c, 0, levels).astype(jnp.uint32).reshape(k, n)
    return c, scale, mn


def pack_ref(codes, bits: int):
    """Pack u32 codes[K, N] into bit planes u32[bits, K/32, N]."""
    k, n = codes.shape
    assert k % 32 == 0, f"K={k} not divisible by 32"
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    cw = codes.reshape(k // 32, 32, n)
    planes = []
    for j in range(bits):
        bit = (cw >> jnp.uint32(j)) & jnp.uint32(1)
        planes.append(jnp.sum(bit << shifts, axis=1, dtype=jnp.uint32))
    return jnp.stack(planes, axis=0)


def unpack_ref(planes, bits: int):
    """Inverse of pack_ref: planes u32[bits, K/32, N] -> codes u32[K, N]."""
    b, kw, n = planes.shape
    assert b == bits
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    codes = jnp.zeros((kw, 32, n), dtype=jnp.uint32)
    for j in range(bits):
        bit = (planes[j][:, None, :] >> shifts) & jnp.uint32(1)
        codes = codes | (bit << jnp.uint32(j))
    return codes.reshape(kw * 32, n)


def dequant_ref(planes, scale, minv, bits: int, group_size: int):
    """Reconstruct f32[K, N] weights from packed planes + group stats."""
    codes = unpack_ref(planes, bits)
    g = group_size
    s = jnp.repeat(scale, g, axis=0)
    m = jnp.repeat(minv, g, axis=0)
    return codes.astype(jnp.float32) * s + m


def dequant_matmul_ref(x, planes, scale, minv, bits: int, group_size: int):
    """x f32[M, K] @ dequant(planes)[K, N] -> f32[M, N]."""
    w = dequant_ref(planes, scale, minv, bits, group_size)
    return x @ w


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis. x: f32[..., D], w: f32[D]."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def quant_dequant_ref(w, group_size: int, bits: int):
    """Round-trip simulated quantization (what table evals feed fwd_nll)."""
    codes, scale, mn = quantize_ref(w, group_size, bits)
    g = group_size
    s = jnp.repeat(scale, g, axis=0)
    m = jnp.repeat(mn, g, axis=0)
    return codes.astype(jnp.float32) * s + m
