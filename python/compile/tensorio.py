"""`.lieq` tensor-archive writer/reader (Python side).

Binary format shared with rust/src/tensor/archive.rs:

    magic   : 8 bytes  b"LIEQTNSR"
    version : u32 LE   (1)
    count   : u32 LE
    per tensor:
        name_len : u32 LE
        name     : utf-8 bytes
        dtype    : u8 (0 = f32, 1 = i32, 2 = u32)
        ndim     : u8
        dims     : ndim x u32 LE
        data     : raw little-endian values (prod(dims) elements)

No alignment padding; the reader streams sequentially. Used for exported
init parameters, trained checkpoints, and packed quantized weights.
"""

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"LIEQTNSR"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint32): 2}


def write_archive(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_archive(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, f"{path}: bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1, version
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = _DTYPES[code]
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * 4), dtype=np.dtype(dt).newbyteorder("<"))
            out[name] = data.reshape(dims).astype(dt)
    return out
