"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/bit-widths/group sizes; every property the Rust
deployment kernels rely on (pack/unpack inversion, dequant error bound,
fused-GEMM equivalence) is pinned here at build time.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dequant_matmul import dequant_matmul, pick_block
from compile.kernels.group_quant import group_quant, quant_pack
from compile.kernels.rmsnorm import rmsnorm

BITS = [2, 3, 4]


def rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale
    )


# ---------------------------------------------------------------------------
# Reference-level invariants (fast, wide hypothesis sweeps)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    kw=st.integers(1, 8),
    n=st.integers(1, 96),
    bits=st.sampled_from(BITS),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(kw, n, bits, seed):
    k = kw * 32
    codes = jnp.asarray(
        np.random.default_rng(seed).integers(0, 1 << bits, (k, n)).astype(np.uint32)
    )
    planes = ref.pack_ref(codes, bits)
    assert planes.shape == (bits, kw, n)
    out = ref.unpack_ref(planes, bits)
    assert (np.asarray(out) == np.asarray(codes)).all()


@settings(max_examples=20, deadline=None)
@given(
    groups=st.integers(1, 6),
    n=st.integers(1, 64),
    bits=st.sampled_from(BITS),
    gsize=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_error_bound(groups, n, bits, gsize, seed):
    """|W - dq(q(W))| <= scale/2 element-wise (round-to-nearest property)."""
    k = groups * gsize
    w = rand((k, n), seed)
    codes, scale, mn = ref.quantize_ref(w, gsize, bits)
    s = np.repeat(np.asarray(scale), gsize, axis=0)
    m = np.repeat(np.asarray(mn), gsize, axis=0)
    wq = np.asarray(codes).astype(np.float32) * s + m
    err = np.abs(wq - np.asarray(w))
    assert (err <= s / 2 + 1e-5).all(), float(err.max())


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1))
def test_codes_within_range(bits, seed):
    w = rand((64, 16), seed, scale=10.0)
    codes, _, _ = ref.quantize_ref(w, 32, bits)
    c = np.asarray(codes)
    assert c.max() <= (1 << bits) - 1 and c.min() >= 0


def test_monotone_bits_reduce_error():
    """More bits -> lower reconstruction error (sanity of the whole format)."""
    w = rand((128, 64), 7)
    errs = []
    for bits in BITS:
        codes, scale, mn = ref.quantize_ref(w, 64, bits)
        planes = ref.pack_ref(codes, bits)
        wq = ref.dequant_ref(planes, scale, mn, bits, 64)
        errs.append(float(jnp.abs(wq - w).mean()))
    assert errs[0] > errs[1] > errs[2], errs


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k,n,g", [(64, 32, 32), (128, 96, 64), (256, 704, 64)])
def test_group_quant_kernel_matches_ref(bits, k, n, g):
    w = rand((k, n), seed=bits * 100 + k)
    c_ref, s_ref, m_ref = ref.quantize_ref(w, g, bits)
    c, s, m = group_quant(w, bits=bits, group_size=g)
    assert (np.asarray(c) == np.asarray(c_ref)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref), rtol=1e-6)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("m,k,n", [(4, 64, 32), (16, 128, 128), (128, 256, 704)])
def test_dequant_matmul_kernel_matches_ref(bits, m, k, n):
    g = 64 if k % 64 == 0 else 32
    w = rand((k, n), seed=bits)
    x = rand((m, k), seed=bits + 1)
    codes, scale, mn = ref.quantize_ref(w, g, bits)
    planes = ref.pack_ref(codes, bits)
    out_ref = ref.dequant_matmul_ref(x, planes, scale, mn, bits, g)
    out = dequant_matmul(x, planes, scale, mn, bits=bits, group_size=g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 4, 32]),
    kw=st.sampled_from([2, 4, 8]),
    n=st.sampled_from([32, 88, 128]),
    bits=st.sampled_from(BITS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_matmul_hypothesis_sweep(m, kw, n, bits, seed):
    k = kw * 32
    g = 32
    w = rand((k, n), seed)
    x = rand((m, k), seed + 1)
    codes, scale, mn = ref.quantize_ref(w, g, bits)
    planes = ref.pack_ref(codes, bits)
    out_ref = ref.dequant_matmul_ref(x, planes, scale, mn, bits, g)
    out = dequant_matmul(x, planes, scale, mn, bits=bits, group_size=g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("bits", BITS)
def test_quant_pack_pipeline(bits):
    w = rand((128, 88), seed=3)
    planes, scale, mn = quant_pack(w, bits=bits, group_size=32)
    wq = ref.dequant_ref(planes, scale, mn, bits, 32)
    # reconstruction error bounded by scale/2 per group
    s = np.repeat(np.asarray(scale), 32, axis=0)
    assert (np.abs(np.asarray(wq - w)) <= s / 2 + 1e-5).all()


@pytest.mark.parametrize("r,d", [(128, 64), (512, 256), (256, 128)])
def test_rmsnorm_kernel_matches_ref(r, d):
    x = rand((r, d), seed=r + d)
    w = rand((d,), seed=d) + 1.0
    out = rmsnorm(x, w)
    out_ref = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), rtol=1e-5, atol=1e-5)


def test_pick_block_divides():
    for n in [32, 88, 128, 352, 704, 896, 1024]:
        bn = pick_block(n, 128)
        assert n % bn == 0 and 1 <= bn <= 128
