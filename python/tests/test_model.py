"""L2 correctness: model shapes, skip-mask semantics, training dynamics,
quantized deployment forward vs float forward, tensor archive round-trip."""

import os
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import tensorio
from compile.configs import LADDER, by_name
from compile.kernels import ref


def toks(cfg, b=2, t=32, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, cfg.vocab, (b, t)), jnp.int32
    )


@pytest.fixture(scope="module")
def nano():
    cfg = by_name("q_nano")
    return cfg, M.init_params(cfg, 0)


@pytest.fixture(scope="module")
def lnano():
    cfg = by_name("l_nano")
    return cfg, M.init_params(cfg, 0)


def test_param_spec_counts():
    for cfg in LADDER:
        spec = cfg.param_spec()
        per_layer = 11 if cfg.qk_norm else 9
        extra = 2 if cfg.tied_embedding else 3  # embed, final_norm, (lm_head)
        assert len(spec) == cfg.n_layers * per_layer + extra
        assert all(len(s) >= 1 for _, s in spec)


def test_fwd_nll_shape_and_uniform_init(nano):
    cfg, params = nano
    t = toks(cfg)
    mask = jnp.ones((cfg.n_layers,), jnp.float32)
    (nll,) = M.fwd_nll(cfg, t, mask, *params)
    assert nll.shape == (2, 31)
    # At random init the model is ~uniform over vocab: nll ~= ln(V).
    assert abs(float(nll.mean()) - np.log(cfg.vocab)) < 0.5


def test_skip_mask_identity(nano):
    """Zeroing every layer must reduce the model to embed->norm->logits:
    layer weights become irrelevant."""
    cfg, params = nano
    t = toks(cfg)
    zero_mask = jnp.zeros((cfg.n_layers,), jnp.float32)
    (nll_a,) = M.fwd_nll(cfg, t, zero_mask, *params)
    # Perturb all layer weights; with zero mask the output must not change.
    perturbed = []
    for (name, _), p in zip(cfg.param_spec(), params):
        perturbed.append(p + 1.0 if name.startswith("layers.") else p)
    (nll_b,) = M.fwd_nll(cfg, t, zero_mask, *perturbed)
    np.testing.assert_allclose(np.asarray(nll_a), np.asarray(nll_b), rtol=1e-5)


def test_skip_single_layer_changes_nll(nano):
    cfg, params = nano
    t = toks(cfg)
    base = jnp.ones((cfg.n_layers,), jnp.float32)
    (nll0,) = M.fwd_nll(cfg, t, base, *params)
    for l in range(cfg.n_layers):
        (nll,) = M.fwd_nll(cfg, t, base.at[l].set(0.0), *params)
        assert float(jnp.abs(nll - nll0).mean()) > 1e-6, f"layer {l} inert"


def test_capture_shapes(nano):
    cfg, params = nano
    t = toks(cfg, b=3, t=16)
    a, c, m, g, fin = M.capture(cfg, t, *params)
    L, d, dff = cfg.n_layers, cfg.d_model, cfg.d_ff
    assert a.shape == (L, 3, 16, d)
    assert c.shape == (L, 3, 16, cfg.n_heads * cfg.d_head)
    assert m.shape == (L, 3, 16, d)
    assert g.shape == (L, 3, 16, dff)
    assert fin.shape == (3, 16, d)


def test_train_step_reduces_loss(nano):
    cfg, params = nano
    t = toks(cfg, b=4, t=48, seed=3)
    zeros = [jnp.zeros_like(p) for p in params]
    state = list(params) + zeros + zeros
    losses = []
    for i in range(6):
        out = M.train_step(cfg, t, jnp.float32(3e-3), jnp.float32(i), *state)
        losses.append(float(out[0]))
        state = list(out[1:])
    assert losses[-1] < losses[0] - 0.5, losses


def test_family_l_untied(lnano):
    cfg, params = lnano
    assert not cfg.tied_embedding
    names = [n for n, _ in cfg.param_spec()]
    assert "lm_head" in names and "q_norm" not in " ".join(names)
    t = toks(cfg)
    (nll,) = M.fwd_nll(cfg, t, jnp.ones((cfg.n_layers,)), *params)
    assert np.isfinite(np.asarray(nll)).all()


def test_quant_forward_close_at_4bit(nano):
    """fwd_logits_quant(b=4) must track the float forward closely; b=2 less
    so but still finite — mirrors the PTQ noise ladder the paper studies."""
    cfg, params = nano
    t = toks(cfg, b=1, t=16)
    (logits_f,) = M.fwd_logits(cfg, t, *params)

    errs = {}
    for bits in (4, 2):
        packed = []
        for (name, shape), p in zip(cfg.param_spec(), params):
            base = name.split(".")[-1]
            if base in M.QUANT_LINEARS:
                codes, scale, mn = ref.quantize_ref(p, cfg.group_size, bits)
                packed += [ref.pack_ref(codes, bits), scale, mn]
            else:
                packed.append(p)
        (logits_q,) = M.fwd_logits_quant(cfg, bits, t, *packed)
        assert np.isfinite(np.asarray(logits_q)).all()
        errs[bits] = float(jnp.abs(logits_q - logits_f).mean())
    assert errs[4] < errs[2], errs
    assert errs[4] < 0.3, errs


def test_rope_rotation_preserves_norm():
    cos, sin = M.rope_tables(16, 32, 10000.0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 16, 2, 32)).astype(np.float32))
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-4,
    )


def test_tensor_archive_roundtrip():
    tensors = [
        ("a", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b.scale", np.ones((2, 2), dtype=np.float32) * 0.5),
        ("codes", np.arange(8, dtype=np.uint32)),
        ("ids", np.asarray([-1, 2, -3], dtype=np.int32)),
    ]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.lieq")
        tensorio.write_archive(path, tensors)
        back = tensorio.read_archive(path)
    assert set(back) == {"a", "b.scale", "codes", "ids"}
    for name, arr in tensors:
        assert back[name].dtype == arr.dtype
        np.testing.assert_array_equal(back[name], arr)
