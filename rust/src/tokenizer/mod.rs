//! Byte-level BPE tokenizer (trainer + encoder/decoder + persistence).

pub mod bpe;

pub use bpe::Bpe;
