//! Byte-level BPE: trainer, encoder, decoder, vocab persistence.
//!
//! The paper evaluates on tokenized corpora; since no pretrained tokenizer
//! ships with this testbed, we train a byte-level BPE on the synthetic
//! corpus mix to the exact model vocab (512). Byte fallback guarantees
//! total coverage: token ids 0..256 are raw bytes, merges fill the rest.

use std::collections::HashMap;

use anyhow::{bail, Result};

pub const BYTE_VOCAB: usize = 256;

/// A trained BPE tokenizer. Token ids: `0..256` raw bytes, then one id per
/// merge in creation order.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge list: (left_id, right_id) -> new_id = 256 + index.
    pub merges: Vec<(u32, u32)>,
    /// rank lookup for encoding.
    rank: HashMap<(u32, u32), u32>,
    /// id -> byte string.
    pub vocab_bytes: Vec<Vec<u8>>,
}

impl Bpe {
    pub fn vocab_size(&self) -> usize {
        BYTE_VOCAB + self.merges.len()
    }

    /// Train to `vocab_size` on the given texts (greedy most-frequent-pair).
    pub fn train(texts: &[String], vocab_size: usize) -> Bpe {
        assert!(vocab_size > BYTE_VOCAB, "vocab must exceed byte alphabet");
        // Work on word-like chunks (split at spaces, keep the space glued to
        // the following word GPT-style) so merges don't cross word borders.
        let mut word_counts: HashMap<Vec<u32>, usize> = HashMap::new();
        for text in texts {
            for chunk in split_chunks(text) {
                let ids: Vec<u32> = chunk.bytes().map(|b| b as u32).collect();
                if !ids.is_empty() {
                    *word_counts.entry(ids).or_insert(0) += 1;
                }
            }
        }
        let mut words: Vec<(Vec<u32>, usize)> = word_counts.into_iter().collect();
        words.sort(); // determinism

        let mut merges = Vec::new();
        while BYTE_VOCAB + merges.len() < vocab_size {
            // Count adjacent pairs.
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (w, c) in &words {
                for pair in w.windows(2) {
                    *pair_counts.entry((pair[0], pair[1])).or_insert(0) += c;
                }
            }
            // Deterministic argmax: highest count, then smallest pair.
            let best = pair_counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
                .map(|(&p, &c)| (p, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // nothing left worth merging
            }
            let new_id = (BYTE_VOCAB + merges.len()) as u32;
            merges.push(pair);
            for (w, _) in &mut words {
                merge_in_place(w, pair, new_id);
            }
        }
        Self::from_merges(merges)
    }

    pub fn from_merges(merges: Vec<(u32, u32)>) -> Bpe {
        let mut vocab_bytes: Vec<Vec<u8>> = (0..BYTE_VOCAB as u32).map(|b| vec![b as u8]).collect();
        for &(l, r) in &merges {
            let mut bytes = vocab_bytes[l as usize].clone();
            bytes.extend_from_slice(&vocab_bytes[r as usize]);
            vocab_bytes.push(bytes);
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        Bpe { merges, rank, vocab_bytes }
    }

    /// Encode text to token ids (greedy lowest-rank merging, BPE-standard).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 2);
        for chunk in split_chunks(text) {
            let mut ids: Vec<u32> = chunk.bytes().map(|b| b as u32).collect();
            loop {
                // Find the lowest-rank adjacent pair.
                let mut best: Option<(u32, usize)> = None;
                for (i, pair) in ids.windows(2).enumerate() {
                    if let Some(&r) = self.rank.get(&(pair[0], pair[1])) {
                        if best.map(|(br, _)| r < br).unwrap_or(true) {
                            best = Some((r, i));
                        }
                    }
                }
                let Some((r, i)) = best else { break };
                let new_id = BYTE_VOCAB as u32 + r;
                ids[i] = new_id;
                ids.remove(i + 1);
            }
            out.extend(ids);
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if (id as usize) < self.vocab_bytes.len() {
                bytes.extend_from_slice(&self.vocab_bytes[id as usize]);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    // -- persistence --------------------------------------------------------

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut s = String::from("lieq-bpe-v1\n");
        for &(l, r) in &self.merges {
            s.push_str(&format!("{l} {r}\n"));
        }
        std::fs::write(path, s)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Bpe> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        if lines.next() != Some("lieq-bpe-v1") {
            bail!("bad tokenizer file header");
        }
        let mut merges = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let l: u32 = it.next().unwrap().parse()?;
            let r: u32 = it.next().unwrap().parse()?;
            merges.push((l, r));
        }
        Ok(Self::from_merges(merges))
    }
}

/// GPT-style chunking: a chunk is an optional leading space plus a run of
/// non-space characters; newlines are their own chunks.
fn split_chunks(text: &str) -> Vec<&str> {
    let bytes = text.as_bytes();
    let mut chunks = Vec::new();
    let mut start = 0;
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'\n' {
            if start < i {
                chunks.push(&text[start..i]);
            }
            chunks.push(&text[i..i + 1]);
            i += 1;
            start = i;
        } else if bytes[i] == b' ' && i > start {
            chunks.push(&text[start..i]);
            start = i;
            i += 1;
        } else {
            i += 1;
        }
    }
    if start < bytes.len() {
        chunks.push(&text[start..]);
    }
    chunks
}

fn merge_in_place(w: &mut Vec<u32>, pair: (u32, u32), new_id: u32) {
    let mut i = 0;
    while i + 1 < w.len() {
        if w[i] == pair.0 && w[i + 1] == pair.1 {
            w[i] = new_id;
            w.remove(i + 1);
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_texts() -> Vec<String> {
        vec![
            "the quick brown fox jumps over the lazy dog".to_string(),
            "the dog sleeps while the fox runs the race".to_string(),
            "a quick brown dog and the quick fox".to_string(),
        ]
    }

    #[test]
    fn roundtrip_exact() {
        let bpe = Bpe::train(&sample_texts(), 300);
        for t in sample_texts() {
            assert_eq!(bpe.decode(&bpe.encode(&t)), t);
        }
        // Also text with unseen bytes (byte fallback).
        let odd = "zzz @#%^ unseen wörds\nnew line";
        assert_eq!(bpe.decode(&bpe.encode(odd)), odd);
    }

    #[test]
    fn reaches_requested_vocab() {
        let texts: Vec<String> = (0..50)
            .map(|i| format!("token{} repeated words words words {}", i % 7, i % 3))
            .collect();
        let bpe = Bpe::train(&texts, 320);
        assert!(bpe.vocab_size() <= 320);
        assert!(bpe.vocab_size() > 280, "vocab {}", bpe.vocab_size());
    }

    #[test]
    fn compression_beats_bytes() {
        let texts = sample_texts();
        let bpe = Bpe::train(&texts, 400);
        let text = &texts[0];
        let n_tokens = bpe.encode(text).len();
        assert!(n_tokens < text.len(), "{} tokens vs {} bytes", n_tokens, text.len());
    }

    #[test]
    fn ids_within_vocab() {
        let bpe = Bpe::train(&sample_texts(), 300);
        for id in bpe.encode("the quick brown fox") {
            assert!((id as usize) < bpe.vocab_size());
        }
    }

    #[test]
    fn save_load_identical() {
        let bpe = Bpe::train(&sample_texts(), 290);
        let path = std::env::temp_dir().join(format!("bpe_{}.txt", std::process::id()));
        bpe.save(&path).unwrap();
        let loaded = Bpe::load(&path).unwrap();
        assert_eq!(loaded.merges, bpe.merges);
        let t = "the quick brown fox";
        assert_eq!(loaded.encode(t), bpe.encode(t));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_training() {
        let a = Bpe::train(&sample_texts(), 300);
        let b = Bpe::train(&sample_texts(), 300);
        assert_eq!(a.merges, b.merges);
    }
}
