//! Threaded work queue for `'static` CPU-side calibration work.
//!
//! Largely superseded by [`crate::util::pool::Pool`], which is scoped (no
//! `'static` bounds) and deterministic under reduction — new code should
//! use the pool. `WorkQueue` stays for callers that want an owned,
//! channel-based fan-out. On a 1-core testbed both degenerate gracefully
//! to sequential execution.
//!
//! For *serving*-shaped work (long-lived consumers, bounded admission,
//! EDF-ranked insertion, mid-queue removal, non-blocking join scans for
//! continuous batching) the substrate is
//! [`crate::util::pool::TaskQueue`] and the client surface is
//! `coordinator::server::ServeSession` — this fork-join queue is
//! calibration-only.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// A simple fork-join pool: submit closures, collect results in order.
pub struct WorkQueue {
    workers: usize,
}

impl WorkQueue {
    pub fn new(workers: usize) -> WorkQueue {
        WorkQueue { workers: workers.max(1) }
    }

    /// Auto-size from available parallelism.
    pub fn auto() -> WorkQueue {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkQueue::new(n)
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if self.workers == 1 || n == 1 {
            return items.into_iter().map(f).collect();
        }
        let f = Arc::new(f);
        let work: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new(items.into_iter().map(Some).collect()));
        let (tx, rx) = mpsc::channel::<(usize, R)>();

        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(n) {
                let work = Arc::clone(&work);
                let f = Arc::clone(&f);
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let job = {
                        let mut w = work.lock().unwrap();
                        let idx = w.iter().position(|x| x.is_some());
                        match idx {
                            Some(i) => (i, w[i].take().unwrap()),
                            None => break,
                        }
                    };
                    let (i, item) = job;
                    let r = f(item);
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter().map(|r| r.expect("worker died")).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let q = WorkQueue::new(4);
        let out = q.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_sequential() {
        let q = WorkQueue::new(1);
        let out = q.map(vec![3, 1, 2], |x| x + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }

    #[test]
    fn empty_input() {
        let q = WorkQueue::new(2);
        let out: Vec<i32> = q.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn heavy_closure_runs_once_per_item() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let q = WorkQueue::new(3);
        let out = q.map((0..20).collect::<Vec<usize>>(), |x| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            x
        });
        assert_eq!(out.len(), 20);
        assert_eq!(CALLS.load(Ordering::SeqCst), 20);
    }
}
