//! Lightweight metrics registry: counters, gauges-as-series, and latency
//! histograms for the serving loop and pipeline phases. All methods take
//! `&self` and are safe to hammer from pool workers.

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    latencies: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a counter to an absolute value (for externally-accumulated
    /// counts like the runtime compile-cache hit/miss totals).
    pub fn set_counter(&self, name: &str, v: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), v);
    }

    /// Record one sample of a named series (latency in ms, queue depth, …).
    pub fn observe(&self, name: &str, v: f64) {
        self.latencies.lock().unwrap().entry(name.to_string()).or_default().push(v);
    }

    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.observe(name, ms);
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Maximum recorded sample of a series (e.g. peak queue depth).
    pub fn series_max(&self, name: &str) -> Option<f64> {
        let map = self.latencies.lock().unwrap();
        map.get(name)?.iter().copied().reduce(f64::max)
    }

    /// (p50, p95, mean) of a latency series in ms.
    pub fn latency_summary(&self, name: &str) -> Option<(f64, f64, f64)> {
        let map = self.latencies.lock().unwrap();
        let xs = map.get(name)?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = sorted[sorted.len() / 2];
        let p95 = sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)];
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        Some((p50, p95, mean))
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        let keys: Vec<String> = self.latencies.lock().unwrap().keys().cloned().collect();
        for k in keys {
            if let Some((p50, p95, mean)) = self.latency_summary(&k) {
                out.push_str(&format!(
                    "{k}: p50 {p50:.2} ms, p95 {p95:.2} ms, mean {mean:.2} ms\n"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_counter_overwrites() {
        let m = Metrics::new();
        m.incr("compile_cache_hits", 2);
        m.set_counter("compile_cache_hits", 7);
        assert_eq!(m.counter("compile_cache_hits"), 7);
        m.set_counter("compile_cache_misses", 0);
        assert_eq!(m.counter("compile_cache_misses"), 0);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..100 {
            m.observe_ms("call", i as f64);
        }
        let (p50, p95, mean) = m.latency_summary("call").unwrap();
        assert!(p50 <= p95);
        assert!(mean > 0.0);
    }

    #[test]
    fn series_max_tracks_peak() {
        let m = Metrics::new();
        assert_eq!(m.series_max("depth"), None);
        for d in [3.0, 9.0, 1.0] {
            m.observe("depth", d);
        }
        assert_eq!(m.series_max("depth"), Some(9.0));
    }

    #[test]
    fn report_contains_names() {
        let m = Metrics::new();
        m.incr("batches", 4);
        m.observe_ms("lat", 1.5);
        let r = m.report();
        assert!(r.contains("batches: 4"));
        assert!(r.contains("lat:"));
    }
}
