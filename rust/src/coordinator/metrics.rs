//! Lightweight metrics registry: counters, gauges-as-series, and latency
//! histograms for the serving runtime (per-request and per-token series:
//! `request_total`, `first_token`, `tokens_streamed`, `cached_tokens`,
//! …) and pipeline phases. All methods take `&self` and are safe to
//! hammer from pool workers.

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    latencies: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Set a counter to an absolute value (for externally-accumulated
    /// counts like the runtime compile-cache hit/miss totals).
    pub fn set_counter(&self, name: &str, v: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), v);
    }

    /// Record one sample of a named series (latency in ms, queue depth, …).
    pub fn observe(&self, name: &str, v: f64) {
        self.latencies.lock().unwrap().entry(name.to_string()).or_default().push(v);
    }

    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.observe(name, ms);
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Maximum recorded sample of a series (e.g. peak queue depth).
    pub fn series_max(&self, name: &str) -> Option<f64> {
        self.series_max_from(name, 0)
    }

    /// Maximum sample recorded at or after index `start` — the watermark
    /// form used for per-drain snapshots (record `series_len` at the
    /// drain point, summarize from there later).
    pub fn series_max_from(&self, name: &str, start: usize) -> Option<f64> {
        self.series_max_range(name, start, usize::MAX)
    }

    /// Maximum sample in the half-open window `[start, end)` (`end`
    /// clamps to the series length).
    pub fn series_max_range(&self, name: &str, start: usize, end: usize) -> Option<f64> {
        let map = self.latencies.lock().unwrap();
        let xs = map.get(name)?;
        xs.get(start..end.min(xs.len()))?.iter().copied().reduce(f64::max)
    }

    /// Number of samples recorded so far in a series (watermark for the
    /// `*_from` summaries).
    pub fn series_len(&self, name: &str) -> usize {
        self.latencies.lock().unwrap().get(name).map_or(0, |xs| xs.len())
    }

    /// Drop the first `drop_before` samples of a series, returning how
    /// many were removed. Long-lived consumers (the serving session's
    /// per-drain snapshots) compact consumed samples so an unbounded
    /// stream of observations doesn't grow the registry without bound;
    /// callers must rebase their watermarks by the returned count.
    pub fn compact_series(&self, name: &str, drop_before: usize) -> usize {
        let mut map = self.latencies.lock().unwrap();
        match map.get_mut(name) {
            Some(xs) => {
                let n = drop_before.min(xs.len());
                xs.drain(..n);
                n
            }
            None => 0,
        }
    }

    /// (p50, p95, mean) of a latency series in ms.
    pub fn latency_summary(&self, name: &str) -> Option<(f64, f64, f64)> {
        self.latency_summary_from(name, 0)
    }

    /// (p50, p95, mean) over the samples recorded at or after index
    /// `start` (per-drain window of a cumulative series).
    pub fn latency_summary_from(&self, name: &str, start: usize) -> Option<(f64, f64, f64)> {
        self.latency_summary_range(name, start, usize::MAX)
    }

    /// (p50, p95, mean) over the half-open sample window `[start, end)`
    /// (`end` clamps to the series length) — the bounded form used for
    /// drain snapshots so samples recorded concurrently with the
    /// snapshot land in the *next* window instead of vanishing.
    pub fn latency_summary_range(
        &self,
        name: &str,
        start: usize,
        end: usize,
    ) -> Option<(f64, f64, f64)> {
        let map = self.latencies.lock().unwrap();
        let xs = map.get(name)?;
        let xs = xs.get(start..end.min(xs.len()))?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = sorted[sorted.len() / 2];
        let p95 = sorted[((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1)];
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        Some((p50, p95, mean))
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        let keys: Vec<String> = self.latencies.lock().unwrap().keys().cloned().collect();
        for k in keys {
            if let Some((p50, p95, mean)) = self.latency_summary(&k) {
                out.push_str(&format!(
                    "{k}: p50 {p50:.2} ms, p95 {p95:.2} ms, mean {mean:.2} ms\n"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("req", 1);
        m.incr("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_counter_overwrites() {
        let m = Metrics::new();
        m.incr("compile_cache_hits", 2);
        m.set_counter("compile_cache_hits", 7);
        assert_eq!(m.counter("compile_cache_hits"), 7);
        m.set_counter("compile_cache_misses", 0);
        assert_eq!(m.counter("compile_cache_misses"), 0);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..100 {
            m.observe_ms("call", i as f64);
        }
        let (p50, p95, mean) = m.latency_summary("call").unwrap();
        assert!(p50 <= p95);
        assert!(mean > 0.0);
    }

    #[test]
    fn series_max_tracks_peak() {
        let m = Metrics::new();
        assert_eq!(m.series_max("depth"), None);
        for d in [3.0, 9.0, 1.0] {
            m.observe("depth", d);
        }
        assert_eq!(m.series_max("depth"), Some(9.0));
    }

    #[test]
    fn watermark_summaries_window_the_series() {
        let m = Metrics::new();
        assert_eq!(m.series_len("lat"), 0);
        assert!(m.latency_summary_from("lat", 0).is_none());
        for v in [10.0, 20.0, 30.0] {
            m.observe_ms("lat", v);
        }
        let mark = m.series_len("lat");
        assert_eq!(mark, 3);
        for v in [1.0, 2.0] {
            m.observe_ms("lat", v);
        }
        let (_, _, mean_all) = m.latency_summary("lat").unwrap();
        let (_, _, mean_tail) = m.latency_summary_from("lat", mark).unwrap();
        assert!((mean_all - 12.6).abs() < 1e-9);
        assert!((mean_tail - 1.5).abs() < 1e-9);
        assert_eq!(m.series_max_from("lat", mark), Some(2.0));
        assert_eq!(m.series_max("lat"), Some(30.0));
        // Bounded windows: [1, 4) covers 20, 30, 1.
        let (_, _, mean_mid) = m.latency_summary_range("lat", 1, 4).unwrap();
        assert!((mean_mid - 17.0).abs() < 1e-9);
        assert_eq!(m.series_max_range("lat", 1, 4), Some(30.0));
        assert!(m.latency_summary_range("lat", 2, 2).is_none());
        // Watermark at (or past) the end: an empty window, not a panic.
        assert!(m.latency_summary_from("lat", 5).is_none());
        assert!(m.latency_summary_from("lat", 99).is_none());
    }

    #[test]
    fn compact_series_drops_prefix_only() {
        let m = Metrics::new();
        assert_eq!(m.compact_series("missing", 4), 0);
        for v in [1.0, 2.0, 3.0, 4.0] {
            m.observe_ms("lat", v);
        }
        assert_eq!(m.compact_series("lat", 3), 3);
        assert_eq!(m.series_len("lat"), 1);
        assert_eq!(m.series_max("lat"), Some(4.0));
        // Over-long prefix clamps to the series length.
        assert_eq!(m.compact_series("lat", 99), 1);
        assert_eq!(m.series_len("lat"), 0);
    }

    #[test]
    fn report_contains_names() {
        let m = Metrics::new();
        m.incr("batches", 4);
        m.observe_ms("lat", 1.5);
        let r = m.report();
        assert!(r.contains("batches: 4"));
        assert!(r.contains("lat:"));
    }
}
