//! The LieQ pipeline: diagnose → score → allocate → quantize → evaluate.
//!
//! This is the end-to-end orchestration a user calls (`lieq e2e`, the
//! quickstart example, and every table bench): given a trained model it
//! produces the per-layer effectiveness scores, a bit allocation at the
//! requested budget, simulated-quantized weights through the chosen
//! backend, and before/after quality numbers.

use anyhow::Result;

use crate::corpus::{Bucket, Corpus, Domain};
use crate::diagnostics::capture::CaptureSet;
use crate::diagnostics::compactness::compact_delta;
use crate::diagnostics::energy::{energy_delta, DEFAULT_K};
use crate::diagnostics::ppl_drop::ppl_drop;
use crate::diagnostics::score::{aggregate, average_diagnostics, LayerScores, ScoreWeights};
use crate::diagnostics::{allocate_top_m, LayerDiagnostics};
use crate::eval::ppl::{nll_over_passages, NllBatcher};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::{quantize_model, Backend, LayerBits};
use crate::tensor::Tensor;
use crate::tokenizer::Bpe;
use crate::util::{Pool, Timer};

#[derive(Clone, Debug)]
pub struct PipelineOptions {
    /// Corpora used for the ΔPPL diagnostic.
    pub diag_domains: Vec<Domain>,
    /// Passages per (domain, bucket) for ΔPPL (paper: 100; scaled down for
    /// the 1-core testbed — configurable from the CLI).
    pub diag_passages: usize,
    pub buckets: Vec<Bucket>,
    pub weights: ScoreWeights,
    /// Number of 4-bit layers (paper's extreme config: 1).
    pub top_m: usize,
    pub hi_bits: u8,
    pub lo_bits: u8,
    pub backend: Backend,
    /// Top-ε outlier-column fraction for mixed packing (`--outlier-eps`):
    /// each packed linear extracts `ceil(eps·K)` high-impact input
    /// features into an fp16 sidecar. 0 keeps packing purely dense.
    pub outlier_eps: f64,
    pub seed: u64,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            diag_domains: vec![Domain::Wiki],
            diag_passages: 16,
            buckets: vec![Bucket::Short],
            weights: ScoreWeights::default(),
            top_m: 1,
            hi_bits: 4,
            lo_bits: 2,
            backend: Backend::Gptq,
            outlier_eps: 0.0,
            seed: 3,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub diagnostics: LayerDiagnostics,
    pub scores: LayerScores,
    pub bits: LayerBits,
    /// Parameter-weighted dense average bits (Eq. 12).
    pub avg_bits: f64,
    /// Average bits/weight the fp16 outlier sidecar adds on top of
    /// `avg_bits` at `PipelineOptions::outlier_eps` (0 when dense-only) —
    /// the re-spend line of the allocation table.
    pub outlier_overhead_bits: f64,
    pub fp16_ppl: f64,
    pub quant_ppl: f64,
    pub secs_diagnose: f64,
    pub secs_quantize: f64,
    /// Artifact compile-cache movement across this run (see
    /// [`crate::runtime::cache::stats`]): `misses` counts real
    /// loads/compiles, `hits` the reuses — the ΔPPL grid and the eval
    /// phase share executables instead of recompiling per phase.
    ///
    /// Pipeline phases fan out on ephemeral pool threads, so these are
    /// process-wide deltas (a concurrently-live `WorkerRuntime` shows up
    /// here); serving reads exact per-runtime counters instead via the
    /// thread-attached sinks (`WorkerRuntime::cache_stats`).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// CPU dq_gemm traffic per kernel path across this run (process-wide
    /// delta, same scope note as the cache stats) — the §Perf log's
    /// per-path attribution.
    pub kernel_paths: crate::kernels::KernelPathStats,
}

pub struct LieqPipeline<'a> {
    pub cfg: &'a ModelConfig,
    pub bpe: &'a Bpe,
}

impl<'a> LieqPipeline<'a> {
    pub fn new(cfg: &'a ModelConfig, bpe: &'a Bpe) -> Self {
        LieqPipeline { cfg, bpe }
    }

    /// Compute the full diagnostic triplet, averaged over the requested
    /// (domain, bucket) grid.
    ///
    /// The grid fans out on [`Pool::current`]: every (domain, bucket) cell
    /// is an independent ΔPPL sweep (each pool worker builds its own
    /// `NllBatcher`, keeping PJRT thread-confined), and the geometric
    /// diagnostics parallelize per layer inside `compact_delta` /
    /// `energy_delta`. Cell results merge in grid order, so the average is
    /// identical at any thread count.
    pub fn diagnose(
        &self,
        params: &ParamStore,
        opt: &PipelineOptions,
    ) -> Result<LayerDiagnostics> {
        let cfg = self.cfg;

        // Geometric diagnostics from one capture batch (paper: one
        // representative passage per bucket to bound memory).
        let cap = self.capture(params)?;
        let dr = compact_delta(cfg, params, &cap, opt.seed)?;
        let de = energy_delta(cfg, params, &cap, DEFAULT_K, opt.seed)?;

        let mut grid = Vec::new();
        for &domain in &opt.diag_domains {
            for &bucket in &opt.buckets {
                grid.push((domain, bucket));
            }
        }
        let cells = Pool::current().par_map(grid, |(domain, bucket)| {
            let corpus = Corpus::new(domain, opt.seed);
            let passages = corpus.sample_bucket(self.bpe, bucket, opt.diag_passages);
            let pd = ppl_drop(cfg, params, &passages)?;
            anyhow::Ok(LayerDiagnostics {
                ppl_drop: pd.delta,
                compact_delta: dr.clone(),
                energy_delta: de.clone(),
                base_ppl: pd.base_ppl,
            })
        });
        let runs = cells.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(average_diagnostics(&runs))
    }

    /// Run the capture artifact on a representative calibration batch.
    pub fn capture(&self, params: &ParamStore) -> Result<CaptureSet> {
        let cfg = self.cfg;
        let art = cfg.artifact("capture_b4_t128")?;
        let corpus = Corpus::new(Domain::Wiki, 7);
        let passages = corpus.sample_bucket(self.bpe, Bucket::Short, art.batch);
        let mut tokens = vec![0i32; art.batch * art.seq];
        for (row, p) in passages.iter().enumerate() {
            for (i, &t) in p.iter().take(art.seq).enumerate() {
                tokens[row * art.seq + i] = t as i32;
            }
        }
        CaptureSet::collect(cfg, params, &Tensor::from_i32(tokens, &[art.batch, art.seq]))
    }

    /// Full pipeline with PPL evaluation on held-out wiki passages.
    pub fn run(&self, params: &ParamStore, opt: &PipelineOptions) -> Result<PipelineResult> {
        let cfg = self.cfg;
        let cache_base = crate::runtime::cache::stats();
        let kernel_base = crate::kernels::kernel_path_stats();
        let t_diag = Timer::start();
        let diagnostics = self.diagnose(params, opt)?;
        let scores = aggregate(&diagnostics, opt.weights);
        let bits = allocate_top_m(&scores.s, opt.top_m, opt.hi_bits, opt.lo_bits);
        let secs_diagnose = t_diag.secs();

        let t_quant = Timer::start();
        let cap = self.capture(params)?;
        let qparams = quantize_model(cfg, params, &bits, opt.backend, Some(&cap))?;
        let secs_quantize = t_quant.secs();

        // Held-out eval: same world as calibration/training, but a disjoint
        // passage index range (unseen text, not an unseen universe).
        let corpus = Corpus::new(Domain::Wiki, opt.seed);
        let passages =
            corpus.sample_bucket_from(self.bpe, Bucket::Short, opt.diag_passages.max(8), 50_000);
        let mask = vec![1.0f32; cfg.n_layers];
        let mut batcher = NllBatcher::new(cfg, params)?;
        let fp16_ppl = nll_over_passages(&batcher, &passages, &mask)?.exp();
        batcher.set_params(&qparams);
        let quant_ppl = nll_over_passages(&batcher, &passages, &mask)?.exp();

        let cache = crate::runtime::cache::stats().delta_from(cache_base);
        Ok(PipelineResult {
            avg_bits: bits.avg_bits(cfg),
            outlier_overhead_bits: crate::diagnostics::outlier_overhead_bits(
                cfg,
                opt.outlier_eps,
            ),
            diagnostics,
            scores,
            bits,
            fp16_ppl,
            quant_ppl,
            secs_diagnose,
            secs_quantize,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            kernel_paths: crate::kernels::kernel_path_stats().delta_from(kernel_base),
        })
    }

    /// Quantize with an explicit bit allocation (table benches sweep this).
    pub fn quantize_with(
        &self,
        params: &ParamStore,
        bits: &LayerBits,
        backend: Backend,
    ) -> Result<ParamStore> {
        let needs_calib = matches!(backend, Backend::Gptq | Backend::Awq | Backend::SlimLlm);
        let cap = if needs_calib { Some(self.capture(params)?) } else { None };
        quantize_model(self.cfg, params, bits, backend, cap.as_ref())
    }
}
