//! Cluster serving: replica routing and layer-range sharding across
//! multiple [`WorkerRuntime`]s behind one session-compatible facade.
//!
//! One `WorkerRuntime` is one process-local pool — its threads and
//! memory cap capacity. [`ClusterRuntime`] owns N replicas and
//! [`ClusterSession`] multiplexes the familiar submit/ticket surface
//! over them:
//!
//! * **Replica routing** — `submit` scores replicas by queue depth,
//!   recorded worker failures, then index (deterministic least-loaded
//!   order; only replicas with live workers are candidates) and places
//!   the request on the best one. A submit refused under admission
//!   pressure ([`SubmitError::QueueFull`]) falls through to the next
//!   replica in the same order — shedding lands on the least-loaded
//!   healthy replica instead of bouncing the client.
//! * **Failover migration** — a [`ClusterTicket`] watches its inner
//!   stream; when the terminal is a worker-side loss
//!   (`WorkerFailure`/`Shutdown`) and migration budget remains, the
//!   accumulated decode state ([`ResumeState`]: every value the client
//!   already saw, cached + fresh, in index order) is resubmitted to the
//!   healthiest *other* replica via [`ServeSession::submit_resume`].
//!   The job resumes at `pos = vals.len()`: no token is re-emitted, the
//!   prefix-cache replay is structurally skipped (`pos > 0`), and the
//!   eventual completion publishes the *full* row to the new replica's
//!   KV cache. The failed replica's terminal error is swallowed, so the
//!   client still sees contiguous `Token` events and **exactly one**
//!   terminal. Deadlines survive migration as remaining budget;
//!   `Cancelled`/`DeadlineExceeded`/`QueueFull` terminals never migrate.
//! * **Layer-range sharding** — see [`shard`]: a [`ShardPlan`] splits a
//!   model's layers across pipeline stages connected by bounded
//!   [`crate::util::pool::Handoff`] conduits, so a model larger than one
//!   runtime's memory streams activations stage-to-stage between
//!   bounded decode iterations.
//! * **Aggregated observability** — see [`stats`]: [`ClusterStats`]
//!   merges per-replica [`SessionStats`] with replica health columns
//!   (live workers, failures, iteration heartbeat). Cache/kernel/KV
//!   attribution rides the existing per-runtime thread-attached sinks,
//!   so replica columns never bleed into each other.
//!
//! Variant/param swaps fan out to **every** replica
//! ([`ClusterRuntime::register_variant`] / `set_params_shared`), each
//! invalidating its own KV cache — a swap on one replica can therefore
//! never serve stale prefix blocks from another's cache after a
//! migration.

pub mod shard;
pub mod stats;

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::{ModelConfig, ParamStore};

use super::server::{
    Response, ResponseError, ResumeState, Scorer, ScorerFactory, ServeSession, SessionOptions,
    SessionStats, SubmitError, SubmitOptions, Ticket, TokenEvent, WorkerRuntime,
};

pub use shard::{ActivationBatch, ShardPipeline, ShardPlan, ShardStage, StageFactory};
pub use stats::{ClusterStats, ReplicaHealth, ReplicaStats};

/// Default per-ticket migration budget: how many times one request may
/// hop replicas before its worker-side error is surfaced as-is. Two
/// hops cover the acceptance scenario (one replica lost, its successor
/// possibly still absorbing the failure wave) without letting a
/// poisoned request ping-pong forever.
pub const DEFAULT_MAX_MIGRATIONS: u32 = 2;

/// Scorer factory with replica attribution: `(replica, worker_id,
/// params)`. The extra leading index lets tests/benches give each
/// replica distinct behaviour (e.g. a fail-switch on replica 0 only).
pub type ClusterScorerFactory =
    Arc<dyn Fn(usize, usize, &Arc<ParamStore>) -> Result<Box<dyn Scorer>> + Send + Sync>;

/// N [`WorkerRuntime`] replicas behind one facade. Replicas are fully
/// independent runtimes — own queue, own workers, own KV cache, own
/// counter sinks; the cluster owns routing, migration, fan-out swaps,
/// and merged reporting.
pub struct ClusterRuntime {
    replicas: Vec<WorkerRuntime>,
}

impl ClusterRuntime {
    /// Production cluster: `n_replicas` runtimes of `workers_per`
    /// NllScorer workers each, all serving `params`.
    pub fn new(
        cfg: &ModelConfig,
        params: &ParamStore,
        n_replicas: usize,
        workers_per: usize,
    ) -> ClusterRuntime {
        let n = n_replicas.max(1);
        let replicas = (0..n).map(|_| WorkerRuntime::new(cfg, params, workers_per)).collect();
        ClusterRuntime { replicas }
    }

    /// Cluster with an injected replica-aware scorer factory (tests,
    /// benches, custom backends).
    pub fn with_scorer_factory(
        n_replicas: usize,
        workers_per: usize,
        params: Arc<ParamStore>,
        factory: ClusterScorerFactory,
    ) -> ClusterRuntime {
        let n = n_replicas.max(1);
        let replicas = (0..n)
            .map(|ri| {
                let f = Arc::clone(&factory);
                let per_replica: ScorerFactory =
                    Arc::new(move |wid, params| f(ri, wid, params));
                WorkerRuntime::with_scorer_factory(workers_per, Arc::clone(&params), per_replica)
            })
            .collect();
        ClusterRuntime { replicas }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Direct handle on one replica (diagnostics, targeted kv access).
    pub fn replica(&self, i: usize) -> Option<&WorkerRuntime> {
        self.replicas.get(i)
    }

    /// Block until every replica's workers resolved their builds; returns
    /// the total number that ever came up.
    pub fn wait_ready(&self) -> usize {
        self.replicas.iter().map(|r| r.wait_ready()).sum()
    }

    /// Point-in-time health row per replica (the routing inputs plus the
    /// iteration heartbeat).
    pub fn health(&self) -> Vec<ReplicaHealth> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaHealth {
                replica: i,
                workers: r.workers(),
                live_workers: r.live_workers(),
                failures: r.failure_count(),
                iterations: r.iterations(),
            })
            .collect()
    }

    /// Swap the default serving weights on **every** replica. Each
    /// replica's own KV cache drops its default-variant blocks — the
    /// cluster-wide invalidation fan-out that keeps a post-swap
    /// migration from replaying blocks scored under the old weights on
    /// a replica that never saw the swap.
    pub fn set_params_shared(&mut self, params: Arc<ParamStore>) {
        for r in &mut self.replicas {
            r.set_params_shared(Arc::clone(&params));
        }
    }

    /// Register (or re-register) a variant on **every** replica — same
    /// fan-out contract as [`ClusterRuntime::set_params_shared`]: each
    /// replica invalidates its own cached blocks for `id` before the
    /// swap becomes visible.
    pub fn register_variant(&mut self, id: impl Into<String>, params: Arc<ParamStore>) {
        let id = id.into();
        for r in &mut self.replicas {
            r.register_variant(id.clone(), Arc::clone(&params));
        }
    }

    /// Explicit cluster-wide prefix invalidation (`None` = default
    /// variant) — for callers that mutate scoring behaviour outside the
    /// param-swap surface.
    pub fn invalidate_prefix(&self, variant: Option<&str>) {
        for r in &self.replicas {
            r.kv_cache().invalidate(variant);
        }
    }

    /// Reconfigure every replica's KV cache geometry/budget.
    pub fn configure_kv(&self, block_tokens: usize, budget_bytes: usize) {
        for r in &self.replicas {
            r.kv_cache().configure(block_tokens, budget_bytes);
        }
    }

    pub fn has_variant(&self, id: &str) -> bool {
        self.replicas.iter().all(|r| r.has_variant(id))
    }

    /// Open a [`ClusterSession`]: one inner [`ServeSession`] per replica
    /// that can serve (replicas whose workers all failed to build are
    /// skipped, not fatal). Errs only when **no** replica came up.
    pub fn session(&self, opt: SessionOptions) -> Result<ClusterSession<'_>> {
        let mut sessions = Vec::with_capacity(self.replicas.len());
        let mut opened = 0usize;
        for r in &self.replicas {
            match r.session(opt) {
                Ok(s) => {
                    opened += 1;
                    sessions.push(Some(s));
                }
                Err(_) => sessions.push(None),
            }
        }
        if opened == 0 {
            bail!("no cluster replica has serving workers available");
        }
        Ok(ClusterSession {
            cluster: self,
            sessions,
            migrations: AtomicU64::new(0),
            migrated_tokens: AtomicU64::new(0),
            max_migrations: DEFAULT_MAX_MIGRATIONS,
        })
    }
}

/// A client's handle on the cluster: the [`ServeSession`] surface
/// (submit / wait_all / stats) plus replica routing and in-flight
/// migration. One inner session per live replica shares this session's
/// options; per-replica admission caps apply independently (the
/// fall-through in `submit` is what "shed to the least loaded" means at
/// cluster scope).
pub struct ClusterSession<'c> {
    cluster: &'c ClusterRuntime,
    sessions: Vec<Option<ServeSession<'c>>>,
    migrations: AtomicU64,
    migrated_tokens: AtomicU64,
    max_migrations: u32,
}

impl<'c> ClusterSession<'c> {
    /// Override the per-ticket migration budget (default
    /// [`DEFAULT_MAX_MIGRATIONS`]); 0 disables migration entirely.
    pub fn max_migrations(mut self, n: u32) -> ClusterSession<'c> {
        self.max_migrations = n;
        self
    }

    /// Healthy replicas in routing order: least queue depth first, then
    /// fewest recorded failures, then lowest index (fully deterministic
    /// for a given cluster state). Replicas with no live workers, or
    /// whose session never opened, are not candidates.
    fn route_order(&self, exclude: Option<usize>) -> Vec<usize> {
        let mut scored: Vec<(usize, usize, usize)> = Vec::new();
        for (i, slot) in self.sessions.iter().enumerate() {
            if exclude == Some(i) {
                continue;
            }
            let Some(sess) = slot.as_ref() else { continue };
            let Some(rt) = self.cluster.replica(i) else { continue };
            if rt.live_workers() == 0 {
                continue;
            }
            scored.push((sess.queue_depth(), rt.failure_count(), i));
        }
        scored.sort_unstable();
        scored.into_iter().map(|(_, _, i)| i).collect()
    }

    /// Enqueue one request on the least-loaded healthy replica. Falls
    /// through to the next replica when a submit is refused under
    /// admission pressure; the error of the *last* candidate surfaces
    /// when every replica refuses.
    pub fn submit(
        &self,
        tokens: Vec<u32>,
        opt: SubmitOptions,
    ) -> Result<ClusterTicket<'_, 'c>, SubmitError> {
        let order = self.route_order(None);
        if order.is_empty() {
            return Err(SubmitError::Shutdown);
        }
        let mut last_err = SubmitError::Shutdown;
        for ri in order {
            let Some(sess) = self.sessions.get(ri).and_then(|s| s.as_ref()) else { continue };
            match sess.submit(tokens.clone(), opt.clone()) {
                Ok(t) => return Ok(self.wrap(ri, t, tokens, opt)),
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Pin a request to one replica (deterministic tests, diagnostics) —
    /// no routing, but the returned ticket still migrates on failure.
    pub fn submit_to(
        &self,
        replica: usize,
        tokens: Vec<u32>,
        opt: SubmitOptions,
    ) -> Result<ClusterTicket<'_, 'c>, SubmitError> {
        let Some(sess) = self.sessions.get(replica).and_then(|s| s.as_ref()) else {
            return Err(SubmitError::Shutdown);
        };
        let t = sess.submit(tokens.clone(), opt.clone())?;
        Ok(self.wrap(replica, t, tokens, opt))
    }

    fn wrap(
        &self,
        replica: usize,
        inner: Ticket,
        tokens: Vec<u32>,
        opt: SubmitOptions,
    ) -> ClusterTicket<'_, 'c> {
        let now = Instant::now();
        ClusterTicket {
            session: self,
            inner: RefCell::new(inner),
            replica: Cell::new(replica),
            tokens,
            abs_deadline: opt.deadline.and_then(|d| now.checked_add(d)),
            opt,
            submitted: now,
            vals: RefCell::new(Vec::new()),
            cached: Cell::new(0),
            hops: Cell::new(0),
            terminated: Cell::new(false),
        }
    }

    /// Re-place a failed ticket's remainder: healthiest replica other
    /// than the one that just failed, falling back to *any* healthy
    /// replica (the failed one may have live workers left), via the
    /// resume path so no token is re-emitted. `None` when no replica
    /// accepted the migrant.
    fn resubmit(
        &self,
        from: usize,
        tokens: &[u32],
        opt: &SubmitOptions,
        remaining: Option<Duration>,
        resume: &ResumeState,
    ) -> Option<(usize, Ticket)> {
        let mut order = self.route_order(Some(from));
        if order.is_empty() {
            order = self.route_order(None);
        }
        for ri in order {
            let Some(sess) = self.sessions.get(ri).and_then(|s| s.as_ref()) else { continue };
            let mut o = opt.clone();
            o.deadline = remaining;
            if let Ok(t) = sess.submit_resume(tokens.to_vec(), o, resume.clone()) {
                return Some((ri, t));
            }
        }
        None
    }

    /// Resolve tickets in submission order (the 1:1 in-order reply
    /// contract, cluster-shaped).
    pub fn wait_all(&self, tickets: Vec<ClusterTicket<'_, 'c>>) -> Vec<Response> {
        tickets.into_iter().map(|t| t.recv()).collect()
    }

    /// Requests of this session waiting in replica queues, summed.
    pub fn queue_depth(&self) -> usize {
        self.sessions.iter().flatten().map(|s| s.queue_depth()).sum()
    }

    /// In-flight migrations completed by this session's tickets.
    pub fn migration_count(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    /// Tokens that had already streamed when their request migrated
    /// (work the resume path saved from re-decoding).
    pub fn migrated_tokens(&self) -> u64 {
        self.migrated_tokens.load(Ordering::Relaxed)
    }

    /// Merged cluster statistics: per-replica columns (each replica's
    /// own [`ServeSession::stats`] plus its health row) and counter
    /// totals. Replica attribution cannot bleed — each runtime's
    /// cache/kernel/KV movement is counted by its own thread-attached
    /// sinks.
    pub fn stats(&self) -> ClusterStats {
        let rows: Vec<ReplicaStats> = self
            .sessions
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                self.replica_row(i, slot.as_ref().map(|s| s.stats()).unwrap_or_default())
            })
            .collect();
        ClusterStats::merge(rows, self.migration_count(), self.migrated_tokens())
    }

    /// [`ClusterSession::stats`] over the window since the last drain,
    /// compacting consumed samples on every replica (see
    /// [`ServeSession::drain_stats`]).
    pub fn drain_stats(&mut self) -> ClusterStats {
        let mut rows = Vec::with_capacity(self.sessions.len());
        for i in 0..self.sessions.len() {
            let stats = match self.sessions[i].as_mut() {
                Some(s) => s.drain_stats(),
                None => SessionStats::default(),
            };
            rows.push(self.replica_row(i, stats));
        }
        ClusterStats::merge(rows, self.migration_count(), self.migrated_tokens())
    }

    fn replica_row(&self, i: usize, stats: SessionStats) -> ReplicaStats {
        let health = match self.cluster.replica(i) {
            Some(r) => ReplicaHealth {
                replica: i,
                workers: r.workers(),
                live_workers: r.live_workers(),
                failures: r.failure_count(),
                iterations: r.iterations(),
            },
            None => ReplicaHealth { replica: i, ..ReplicaHealth::default() },
        };
        ReplicaStats { health, stats }
    }
}

/// Which terminal errors migrate: worker-side losses only. A request
/// the *client* resolved (cancel), the clock resolved (deadline), or
/// admission resolved (shed) must surface as-is on any replica.
fn migratable(err: &ResponseError) -> bool {
    matches!(err, ResponseError::WorkerFailure(_) | ResponseError::Shutdown)
}

/// Handle for one cluster request: the [`Ticket`] event-stream surface
/// with transparent failover. Tokens stream through unchanged (their
/// values are also accumulated as the migration resume state); a
/// migratable terminal error triggers a resubmit instead of surfacing,
/// so the client observes contiguous token indices and exactly one
/// terminal event no matter how many replicas served the request.
pub struct ClusterTicket<'s, 'c> {
    session: &'s ClusterSession<'c>,
    inner: RefCell<Ticket>,
    replica: Cell<usize>,
    tokens: Vec<u32>,
    opt: SubmitOptions,
    /// Absolute deadline fixed at first submission — migration carries
    /// the *remaining* budget, it never restarts the clock.
    abs_deadline: Option<Instant>,
    submitted: Instant,
    /// Every value streamed to the client so far (cached + fresh, index
    /// order) — exactly the [`ResumeState`] a migration needs.
    vals: RefCell<Vec<f32>>,
    cached: Cell<usize>,
    hops: Cell<u32>,
    terminated: Cell<bool>,
}

impl ClusterTicket<'_, '_> {
    /// Replica currently serving (or last to serve) this request.
    pub fn replica(&self) -> usize {
        self.replica.get()
    }

    /// Completed migrations for this ticket.
    pub fn migrations(&self) -> u32 {
        self.hops.get()
    }

    fn failed_response(&self, err: ResponseError) -> Response {
        Response {
            mean_nll: f32::NAN,
            queue_ms: 0.0,
            total_ms: self.submitted.elapsed().as_secs_f64() * 1e3,
            variant: self.opt.variant.clone(),
            error: Some(err),
            first_token_ms: None,
            tokens_streamed: self.vals.borrow().len() as u32,
            cached_tokens: self.cached.get() as u32,
        }
    }

    /// Block for the next event — [`Ticket::next_event`] semantics, with
    /// migratable terminals intercepted. Yields each `Token` in position
    /// order (indices stay contiguous across migrations because the
    /// resumed job decodes from `pos = vals.len()`), then exactly one
    /// terminal, then `None` forever.
    pub fn next_event(&self) -> Option<TokenEvent> {
        if self.terminated.get() {
            return None;
        }
        loop {
            let ev = self.inner.borrow().next_event();
            match ev {
                Some(TokenEvent::Token { index, nll, cached }) => {
                    {
                        let mut vals = self.vals.borrow_mut();
                        if index == vals.len() {
                            vals.push(nll);
                            if cached {
                                self.cached.set(self.cached.get() + 1);
                            }
                        }
                    }
                    return Some(TokenEvent::Token { index, nll, cached });
                }
                Some(TokenEvent::Done(r)) => {
                    self.terminated.set(true);
                    return Some(TokenEvent::Done(r));
                }
                Some(TokenEvent::Error(err)) => {
                    if !migratable(&err) || self.hops.get() >= self.session.max_migrations {
                        self.terminated.set(true);
                        return Some(TokenEvent::Error(err));
                    }
                    // A migration must not outlive the request's clock:
                    // an expired deadline surfaces as the deadline, not
                    // as the worker failure that happened to come first.
                    let now = Instant::now();
                    if self.abs_deadline.is_some_and(|d| d <= now) {
                        self.terminated.set(true);
                        return Some(TokenEvent::Error(ResponseError::DeadlineExceeded));
                    }
                    let remaining = self.abs_deadline.map(|d| d.saturating_duration_since(now));
                    let resume = ResumeState {
                        vals: self.vals.borrow().clone(),
                        cached_tokens: self.cached.get(),
                    };
                    let streamed = resume.vals.len() as u64;
                    match self.session.resubmit(
                        self.replica.get(),
                        &self.tokens,
                        &self.opt,
                        remaining,
                        &resume,
                    ) {
                        Some((ri, ticket)) => {
                            self.hops.set(self.hops.get() + 1);
                            self.session.migrations.fetch_add(1, Ordering::Relaxed);
                            self.session.migrated_tokens.fetch_add(streamed, Ordering::Relaxed);
                            self.replica.set(ri);
                            *self.inner.borrow_mut() = ticket;
                            // Loop: keep streaming from the new replica.
                        }
                        None => {
                            self.terminated.set(true);
                            return Some(TokenEvent::Error(err));
                        }
                    }
                }
                None => {
                    self.terminated.set(true);
                    return None;
                }
            }
        }
    }

    /// Block until the request resolves, discarding streamed tokens.
    pub fn recv(self) -> Response {
        loop {
            match self.next_event() {
                Some(TokenEvent::Done(r)) => return r,
                Some(TokenEvent::Error(e)) => return self.failed_response(e),
                Some(TokenEvent::Token { .. }) => continue,
                None => return self.failed_response(ResponseError::Shutdown),
            }
        }
    }

    /// Best-effort cancellation on the replica currently holding the
    /// request. A cancel observed after a migration started still
    /// resolves: `Cancelled` is not migratable.
    pub fn cancel(&self) -> bool {
        self.inner.borrow().cancel()
    }
}
