//! Aggregated cluster observability: per-replica health + serving
//! columns and a merged totals row.
//!
//! Attribution discipline: every [`WorkerRuntime`] counts its own
//! cache/kernel/KV movement through thread-attached sinks, so a
//! replica's column is exactly what *its* workers did — merging here is
//! pure read-side arithmetic and can never bleed one replica's traffic
//! into another's. Scalar counters sum exactly; latency percentiles
//! cannot be re-derived from per-replica percentiles, so the totals row
//! takes the **max** (a conservative cluster-wide bound) and documents
//! it as such.
//!
//! [`WorkerRuntime`]: super::super::server::WorkerRuntime

use crate::coordinator::server::SessionStats;

/// Point-in-time routing/health inputs for one replica, as the cluster
/// router sees them.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaHealth {
    pub replica: usize,
    /// Worker threads the replica was built with.
    pub workers: usize,
    /// Worker threads still running (0 = the replica is dead and is
    /// excluded from routing).
    pub live_workers: usize,
    /// Worker failures recorded since the replica started.
    pub failures: usize,
    /// Successful decode iterations since start — the liveness
    /// heartbeat: a replica whose heartbeat stalls while its queue is
    /// non-empty is wedged even if its threads are alive.
    pub iterations: u64,
}

impl ReplicaHealth {
    /// A replica is routable while any worker thread survives.
    pub fn is_live(&self) -> bool {
        self.live_workers > 0
    }
}

/// One replica's column in a [`ClusterStats`] snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaStats {
    pub health: ReplicaHealth,
    /// That replica's own session window — cache/kernel/KV sub-stats
    /// are per-runtime attributed and intentionally *not* merged into
    /// [`ClusterStats::totals`].
    pub stats: SessionStats,
}

/// Merged statistics for one [`ClusterSession`](super::ClusterSession)
/// window: the per-replica columns plus a totals row and the
/// cluster-only counters (migrations).
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    /// Per-replica columns, index order (dead replicas keep their
    /// column: zeros + `live_workers == 0`).
    pub replicas: Vec<ReplicaStats>,
    /// In-flight requests moved off a failed replica and resumed
    /// elsewhere.
    pub migrations: u64,
    /// Tokens already streamed at migration time (decode work the
    /// resume path did not repeat).
    pub migrated_tokens: u64,
    /// Cluster rollup: scalar counters summed exactly; `p50/p95/mean`
    /// and first-token latencies are the **max** over replicas (an
    /// upper bound — exact percentiles need the raw samples, which stay
    /// replica-local); `window_secs` is the max (windows overlap in
    /// wall-clock, they don't concatenate); `cache`/`kernel_paths`/`kv`
    /// stay zeroed here — read them per replica, where attribution is
    /// exact.
    pub totals: SessionStats,
}

impl ClusterStats {
    /// Merge replica columns into a snapshot (see field docs for the
    /// exact-vs-bound rules).
    pub fn merge(replicas: Vec<ReplicaStats>, migrations: u64, migrated_tokens: u64) -> ClusterStats {
        let mut t = SessionStats::default();
        for r in &replicas {
            let s = &r.stats;
            t.submitted += s.submitted;
            t.served += s.served;
            t.failed += s.failed;
            t.expired += s.expired;
            t.cancelled += s.cancelled;
            t.shed += s.shed;
            t.rejected += s.rejected;
            t.requeued += s.requeued;
            t.batches += s.batches;
            t.variant_swaps += s.variant_swaps;
            t.tokens_streamed += s.tokens_streamed;
            t.cached_tokens += s.cached_tokens;
            t.in_queue += s.in_queue;
            t.max_queue_depth = t.max_queue_depth.max(s.max_queue_depth);
            t.p50_ms = t.p50_ms.max(s.p50_ms);
            t.p95_ms = t.p95_ms.max(s.p95_ms);
            t.mean_ms = t.mean_ms.max(s.mean_ms);
            t.first_token_p50_ms = t.first_token_p50_ms.max(s.first_token_p50_ms);
            t.first_token_p95_ms = t.first_token_p95_ms.max(s.first_token_p95_ms);
            t.window_secs = t.window_secs.max(s.window_secs);
        }
        t.throughput_rps = if t.window_secs > 0.0 { t.served as f64 / t.window_secs } else { 0.0 };
        ClusterStats { replicas, migrations, migrated_tokens, totals: t }
    }

    /// Replicas still routable in this snapshot.
    pub fn live_replicas(&self) -> usize {
        self.replicas.iter().filter(|r| r.health.is_live()).count()
    }

    /// Worker failures recorded across all replicas.
    pub fn total_failures(&self) -> usize {
        self.replicas.iter().map(|r| r.health.failures).sum()
    }

    /// One compact line per replica plus the totals row — the cluster
    /// analogue of a server report table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "replica  live  fail  iters     served  failed  requeued  tokens    p95_ms\n",
        );
        for r in &self.replicas {
            let h = &r.health;
            let s = &r.stats;
            out.push_str(&format!(
                "{:<7}  {}/{}   {:<4}  {:<8}  {:<6}  {:<6}  {:<8}  {:<8}  {:.2}\n",
                h.replica,
                h.live_workers,
                h.workers,
                h.failures,
                h.iterations,
                s.served,
                s.failed,
                s.requeued,
                s.tokens_streamed,
                s.p95_ms,
            ));
        }
        let t = &self.totals;
        out.push_str(&format!(
            "total    {}r    {:<4}  migrations={} (tokens saved {})  served={} failed={} tokens={} p95<={:.2}ms\n",
            self.live_replicas(),
            self.total_failures(),
            self.migrations,
            self.migrated_tokens,
            t.served,
            t.failed,
            t.tokens_streamed,
            t.p95_ms,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(replica: usize, served: u64, p95: f64, live: usize) -> ReplicaStats {
        let mut s = SessionStats::default();
        s.served = served;
        s.submitted = served;
        s.tokens_streamed = served * 3;
        s.p95_ms = p95;
        s.window_secs = 2.0;
        ReplicaStats {
            health: ReplicaHealth {
                replica,
                workers: 4,
                live_workers: live,
                failures: if live < 4 { 4 - live } else { 0 },
                iterations: served,
            },
            stats: s,
        }
    }

    #[test]
    fn merge_sums_counters_and_bounds_percentiles() {
        let merged = ClusterStats::merge(vec![col(0, 10, 5.0, 4), col(1, 6, 9.0, 0)], 3, 12);
        assert_eq!(merged.totals.served, 16);
        assert_eq!(merged.totals.tokens_streamed, 48);
        assert_eq!(merged.totals.p95_ms, 9.0, "totals p95 is the max over replicas");
        assert_eq!(merged.migrations, 3);
        assert_eq!(merged.migrated_tokens, 12);
        assert_eq!(merged.live_replicas(), 1);
        assert_eq!(merged.total_failures(), 4);
        // Throughput recomputed from merged counters, not summed rates.
        assert!((merged.totals.throughput_rps - 8.0).abs() < 1e-9);
        // Per-replica columns survive untouched.
        assert_eq!(merged.replicas[1].stats.served, 6);
        assert!(!merged.replicas[1].health.is_live());
    }

    #[test]
    fn render_has_one_row_per_replica_plus_totals() {
        let merged = ClusterStats::merge(vec![col(0, 1, 1.0, 4), col(1, 2, 2.0, 4)], 0, 0);
        let table = merged.render();
        assert_eq!(table.lines().count(), 4, "header + 2 replicas + totals");
        assert!(table.contains("migrations=0"));
    }
}
