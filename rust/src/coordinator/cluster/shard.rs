//! Layer-range sharding: pipeline a model too large for one runtime
//! across several stage workers connected by bounded
//! [`Handoff`] conduits.
//!
//! A [`ShardPlan`] names contiguous, covering layer ranges
//! (`"0-5,6-11"`); [`ShardPlan::split_params`] partitions a full
//! [`ParamStore`] into per-shard stores by the `layers.{l}.` name
//! prefix (embedding rides shard 0, the head/final norm rides the last
//! shard). A [`ShardPipeline`] spawns one thread per shard, each owning
//! a [`ShardStage`] built on that thread (backends may be
//! thread-confined, same contract as serving scorers), and streams
//! [`ActivationBatch`]es stage-to-stage. Conduits are bounded, so at
//! most `capacity` batches buffer between any two stages — activation
//! memory stays flat no matter how deep the wave.
//!
//! Weight swaps reuse the serving handoff discipline: each stage has a
//! param slot guarded by a generation counter
//! ([`ShardPipeline::set_shard_params`] bumps it); the stage re-applies
//! its shard's weights *between* batches, never mid-forward.
//!
//! [`ShardedScorer`] adapts a pipeline to the serving [`Scorer`] trait,
//! so an oversized model serves through the ordinary
//! [`WorkerRuntime`](crate::coordinator::server::WorkerRuntime) —
//! continuous batching, KV prefix reuse, and cluster routing all apply
//! unchanged.

use std::fmt;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::model::ParamStore;
use crate::util::pool::{Handoff, PushError};

use super::super::server::{ScoreRequest, Scorer, ScorerFactory};

/// Row-major activations travelling between pipeline stages.
#[derive(Clone, Debug, PartialEq)]
pub struct ActivationBatch {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl ActivationBatch {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Result<ActivationBatch> {
        if data.len() != rows * cols {
            bail!("activation batch {rows}x{cols} needs {} values, got {}", rows * cols, data.len());
        }
        Ok(ActivationBatch { rows, cols, data })
    }

    /// Seed activations for one decode window: one row, one column per
    /// scored position, each carrying its input token id.
    pub fn from_window(tokens: &[u32], window: Range<usize>) -> ActivationBatch {
        let data: Vec<f32> =
            tokens.iter().skip(window.start).take(window.len()).map(|&t| t as f32).collect();
        ActivationBatch { rows: 1, cols: data.len(), data }
    }
}

/// Contiguous layer ranges, one per shard, covering `0..n_layers`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    ranges: Vec<Range<usize>>,
    n_layers: usize,
}

impl ShardPlan {
    /// Parse a spec like `"0-5,6-11"` (inclusive bounds; a bare `"7"`
    /// is the single layer 7). Ranges must be in order, contiguous,
    /// non-empty, and cover every layer exactly once.
    pub fn parse(spec: &str, n_layers: usize) -> Result<ShardPlan> {
        if n_layers == 0 {
            bail!("shard plan needs a model with at least one layer");
        }
        let mut ranges = Vec::new();
        let mut next = 0usize;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("empty shard range in spec '{spec}'");
            }
            let (lo, hi) = match part.split_once('-') {
                Some((a, b)) => (a.trim().parse::<usize>(), b.trim().parse::<usize>()),
                None => (part.parse::<usize>(), part.parse::<usize>()),
            };
            let (lo, hi) = match (lo, hi) {
                (Ok(l), Ok(h)) => (l, h),
                _ => bail!("unparseable shard range '{part}' in spec '{spec}'"),
            };
            if hi < lo {
                bail!("descending shard range '{part}'");
            }
            if lo != next {
                bail!(
                    "shard ranges must be contiguous from layer 0: expected {next}, got {lo} in '{spec}'"
                );
            }
            next = hi + 1;
            ranges.push(lo..hi + 1);
        }
        if next != n_layers {
            bail!("shard plan '{spec}' covers {next} layers, model has {n_layers}");
        }
        Ok(ShardPlan { ranges, n_layers })
    }

    /// Even split: `n_layers` over `n_shards`, earlier shards take the
    /// remainder (shard sizes differ by at most one layer).
    pub fn even(n_layers: usize, n_shards: usize) -> Result<ShardPlan> {
        if n_layers == 0 {
            bail!("shard plan needs a model with at least one layer");
        }
        let n_shards = n_shards.max(1);
        if n_shards > n_layers {
            bail!("cannot split {n_layers} layers into {n_shards} shards");
        }
        let base = n_layers / n_shards;
        let extra = n_layers % n_shards;
        let mut ranges = Vec::with_capacity(n_shards);
        let mut next = 0usize;
        for i in 0..n_shards {
            let len = base + usize::from(i < extra);
            ranges.push(next..next + len);
            next += len;
        }
        Ok(ShardPlan { ranges, n_layers })
    }

    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn range(&self, shard: usize) -> Option<Range<usize>> {
        self.ranges.get(shard).cloned()
    }

    /// Which shard owns `layer` (`None` past the end of the plan).
    pub fn shard_of(&self, layer: usize) -> Option<usize> {
        self.ranges.iter().position(|r| r.contains(&layer))
    }

    /// Partition a full parameter store into one store per shard by
    /// name: `layers.{l}.*` goes to the shard owning `l`, `embed`
    /// rides the first shard, every other non-layer tensor (final
    /// norm, head) rides the last. Positional `order` is preserved
    /// within each shard. Shard stores are *subsets* — they skip the
    /// full-model manifest contract on purpose.
    pub fn split_params(&self, params: &ParamStore) -> Vec<ParamStore> {
        let n = self.n_shards();
        let mut shards: Vec<ParamStore> =
            (0..n).map(|_| ParamStore { map: Default::default(), order: Vec::new() }).collect();
        for name in &params.order {
            let Some(tensor) = params.map.get(name) else { continue };
            let dest = match layer_of(name) {
                Some(l) => self.shard_of(l).unwrap_or(n - 1),
                None if name == "embed" => 0,
                None => n - 1,
            };
            shards[dest].order.push(name.clone());
            shards[dest].map.insert(name.clone(), tensor.clone());
        }
        shards
    }
}

impl fmt::Display for ShardPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, r) in self.ranges.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{}-{}", r.start, r.end.saturating_sub(1))?;
        }
        Ok(())
    }
}

/// `layers.{l}.…` → `Some(l)`.
fn layer_of(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("layers.")?;
    let (idx, _) = rest.split_once('.')?;
    idx.parse().ok()
}

/// One pipeline stage: forwards activations through its layer range.
/// Built on the stage's own thread (backends may be thread-confined);
/// [`ShardStage::set_params`] is called between batches when the
/// shard's weights were swapped, never mid-forward.
pub trait ShardStage {
    fn forward(&mut self, batch: &mut ActivationBatch) -> Result<()>;
    fn set_params(&mut self, params: &Arc<ParamStore>);
}

/// Builds one [`ShardStage`] per shard, on the stage's own thread:
/// `(shard_index, plan, shard_params)`.
pub type StageFactory =
    Arc<dyn Fn(usize, &ShardPlan, &Arc<ParamStore>) -> Result<Box<dyn ShardStage>> + Send + Sync>;

/// A batch in flight, tagged for reordering at the outlet. Errors ride
/// the conduit too — a failed forward still produces a result, so
/// callers never hang on a lost item.
struct PipeItem {
    seq: u64,
    batch: ActivationBatch,
    err: Option<String>,
}

/// Per-stage weight slot: the serving `Arc` + generation-bump handoff,
/// shard-scoped.
struct StageSlot {
    params: Mutex<Arc<ParamStore>>,
    gen: AtomicU64,
}

/// Threaded layer-range pipeline: shard `i`'s thread pops conduit `i`,
/// forwards through its stage, pushes conduit `i+1`. Bounded conduits
/// cap in-flight activations; FIFO order end-to-end means results leave
/// in submission order within a wave.
pub struct ShardPipeline {
    plan: ShardPlan,
    conduits: Vec<Arc<Handoff<PipeItem>>>,
    slots: Vec<Arc<StageSlot>>,
    threads: Vec<JoinHandle<()>>,
    /// Wave serializer + deterministic sequence base.
    wave_seq: Mutex<u64>,
}

impl ShardPipeline {
    /// Spawn one stage thread per shard of `plan`, splitting `params`
    /// across them. `capacity` bounds each inter-stage conduit (0
    /// promotes to a rendezvous slot). A stage whose factory fails
    /// doesn't kill the pipeline: it stamps the build error on every
    /// batch it sees, so waves resolve with `Err` instead of hanging.
    pub fn new(
        plan: ShardPlan,
        params: &ParamStore,
        capacity: usize,
        factory: StageFactory,
    ) -> ShardPipeline {
        let n = plan.n_shards();
        let conduits: Vec<Arc<Handoff<PipeItem>>> =
            (0..=n).map(|_| Arc::new(Handoff::new(capacity))).collect();
        let slots: Vec<Arc<StageSlot>> = plan
            .split_params(params)
            .into_iter()
            .map(|p| Arc::new(StageSlot { params: Mutex::new(Arc::new(p)), gen: AtomicU64::new(0) }))
            .collect();
        let mut threads = Vec::with_capacity(n);
        for i in 0..n {
            let inlet = Arc::clone(&conduits[i]);
            let outlet = Arc::clone(&conduits[i + 1]);
            let slot = Arc::clone(&slots[i]);
            let plan = plan.clone();
            let factory = Arc::clone(&factory);
            threads.push(std::thread::spawn(move || {
                stage_loop(i, &plan, &inlet, &outlet, &slot, &factory);
            }));
        }
        ShardPipeline { plan, conduits, slots, threads, wave_seq: Mutex::new(0) }
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Swap one shard's weights. Same contract as the serving param
    /// handoff: an `Arc` store plus a generation bump; the stage
    /// re-applies before its next batch, nothing recompiles.
    pub fn set_shard_params(&self, shard: usize, params: Arc<ParamStore>) {
        let Some(slot) = self.slots.get(shard) else { return };
        let mut p = slot.params.lock().unwrap();
        *p = params;
        drop(p);
        slot.gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Swap the *whole* model: split `params` by the plan and hand each
    /// shard its slice.
    pub fn reshard(&self, params: &ParamStore) {
        for (i, p) in self.plan.split_params(params).into_iter().enumerate() {
            self.set_shard_params(i, Arc::new(p));
        }
    }

    /// Run one wave of batches through every stage and return their
    /// results in submission order. Deadlock-free regardless of conduit
    /// capacity: the driver tries to feed the inlet and, whenever the
    /// inlet is full, drains the outlet instead — in-flight items always
    /// have somewhere to go. Waves are serialized (one at a time) so
    /// sequence tags can't interleave across callers.
    pub fn run_wave(&self, batches: Vec<ActivationBatch>) -> Vec<Result<ActivationBatch>> {
        let n = batches.len();
        if n == 0 {
            return Vec::new();
        }
        let mut seq = self.wave_seq.lock().unwrap();
        let base = *seq;
        *seq += n as u64;
        let inlet = &self.conduits[0];
        let outlet = &self.conduits[self.plan.n_shards()];
        let mut feed = batches
            .into_iter()
            .enumerate()
            .map(|(i, b)| PipeItem { seq: base + i as u64, batch: b, err: None });
        let mut out: Vec<Option<Result<ActivationBatch>>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        let mut hold = feed.next();
        while received < n {
            if let Some(item) = hold.take() {
                match inlet.try_push(item) {
                    Ok(()) => {
                        hold = feed.next();
                        continue;
                    }
                    Err(PushError::Full(item)) => hold = Some(item),
                    Err(PushError::Closed(item)) => {
                        // Pipeline shut down: fail this and all unfed items.
                        for it in std::iter::once(item).chain(feed.by_ref()) {
                            let idx = (it.seq - base) as usize;
                            if idx < n && out[idx].is_none() {
                                out[idx] = Some(Err(anyhow!("shard pipeline closed")));
                                received += 1;
                            }
                        }
                        continue;
                    }
                }
            }
            match outlet.pop() {
                Some(item) => {
                    let idx = (item.seq - base) as usize;
                    if idx < n && out[idx].is_none() {
                        out[idx] = Some(match item.err {
                            Some(e) => Err(anyhow!(e)),
                            None => Ok(item.batch),
                        });
                        received += 1;
                    }
                }
                None => {
                    for slot in out.iter_mut() {
                        if slot.is_none() {
                            *slot = Some(Err(anyhow!("shard pipeline closed")));
                            received += 1;
                        }
                    }
                }
            }
        }
        drop(seq);
        out.into_iter()
            .map(|o| match o {
                Some(r) => r,
                None => Err(anyhow!("shard pipeline lost an item")),
            })
            .collect()
    }
}

impl Drop for ShardPipeline {
    fn drop(&mut self) {
        // Close the inlet; each stage drains, closes its outlet, and
        // exits, so the close cascades down the pipe.
        if let Some(first) = self.conduits.first() {
            first.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn stage_loop(
    index: usize,
    plan: &ShardPlan,
    inlet: &Handoff<PipeItem>,
    outlet: &Handoff<PipeItem>,
    slot: &StageSlot,
    factory: &StageFactory,
) {
    let initial = slot.params.lock().unwrap().clone();
    let mut seen_gen = slot.gen.load(Ordering::SeqCst);
    let mut build_err = String::new();
    let mut stage = match factory(index, plan, &initial) {
        Ok(s) => Some(s),
        Err(e) => {
            build_err = format!("shard {index} stage failed to build: {e}");
            None
        }
    };
    while let Some(mut item) = inlet.pop() {
        let gen = slot.gen.load(Ordering::SeqCst);
        if gen != seen_gen {
            seen_gen = gen;
            let fresh = slot.params.lock().unwrap().clone();
            if let Some(s) = stage.as_mut() {
                s.set_params(&fresh);
            }
        }
        if item.err.is_none() {
            match stage.as_mut() {
                Some(s) => {
                    if let Err(e) = s.forward(&mut item.batch) {
                        item.err = Some(format!("shard {index}: {e}"));
                    }
                }
                None => item.err = Some(build_err.clone()),
            }
        }
        if outlet.push(item).is_err() {
            break;
        }
    }
    outlet.close();
}

/// Serving adapter: a [`Scorer`] that forwards each request's decode
/// window through a shared [`ShardPipeline`] and returns the final
/// stage's activations as the NLL row. A full-model param swap from the
/// serving side ([`Scorer::set_params`]) reshards across every stage.
pub struct ShardedScorer {
    pipeline: Arc<ShardPipeline>,
}

impl Scorer for ShardedScorer {
    fn score_window(&mut self, reqs: &[ScoreRequest<'_>]) -> Result<Vec<Vec<f32>>> {
        let batches: Vec<ActivationBatch> = reqs
            .iter()
            .map(|r| ActivationBatch::from_window(r.tokens, r.window.clone()))
            .collect();
        let mut rows = Vec::with_capacity(reqs.len());
        for res in self.pipeline.run_wave(batches) {
            rows.push(res?.data);
        }
        Ok(rows)
    }

    fn set_params(&mut self, params: &Arc<ParamStore>) {
        self.pipeline.reshard(params);
    }
}

/// [`ScorerFactory`] serving one shared pipeline: every worker's scorer
/// feeds the same stage threads, so worker concurrency multiplexes onto
/// the pipeline's bounded conduits.
pub fn sharded_scorer_factory(pipeline: Arc<ShardPipeline>) -> ScorerFactory {
    Arc::new(move |_wid, _params| {
        Ok(Box::new(ShardedScorer { pipeline: Arc::clone(&pipeline) }) as Box<dyn Scorer>)
    })
}

/// Demo/test stage: adds a bias — the first element of the first tensor
/// in its shard's store — to every activation. Zero stores make the
/// pipeline an identity, and a weight swap observably shifts every
/// score, which is exactly what handoff tests need.
pub struct AffineShardStage {
    bias: f32,
}

impl AffineShardStage {
    pub fn from_params(params: &Arc<ParamStore>) -> AffineShardStage {
        AffineShardStage { bias: first_value(params) }
    }
}

fn first_value(params: &Arc<ParamStore>) -> f32 {
    let Some(name) = params.order.first() else { return 0.0 };
    let Some(t) = params.map.get(name) else { return 0.0 };
    t.f32_slice().first().copied().unwrap_or(0.0)
}

impl ShardStage for AffineShardStage {
    fn forward(&mut self, batch: &mut ActivationBatch) -> Result<()> {
        for v in &mut batch.data {
            *v += self.bias;
        }
        Ok(())
    }

    fn set_params(&mut self, params: &Arc<ParamStore>) {
        self.bias = first_value(params);
    }
}

/// [`StageFactory`] of [`AffineShardStage`]s.
pub fn affine_stage_factory() -> StageFactory {
    Arc::new(|_i, _plan, params| Ok(Box::new(AffineShardStage::from_params(params)) as Box<dyn ShardStage>))
}
