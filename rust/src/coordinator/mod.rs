//! L3 coordination: the LieQ pipeline, a threaded calibration scheduler,
//! a batched serving loop on a persistent multi-worker runtime
//! (`server::WorkerRuntime`), and a metrics registry.

pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod server;

pub use metrics::Metrics;
pub use pipeline::{LieqPipeline, PipelineOptions, PipelineResult};
pub use scheduler::WorkQueue;
pub use server::{
    serve, serve_batch, Response, Scorer, ScorerFactory, ServeOptions, ServerReport,
    WorkerRuntime,
};
