//! L3 coordination: the LieQ pipeline, a threaded calibration scheduler,
//! a session-based serving API on a persistent multi-worker runtime
//! (`server::WorkerRuntime` + `server::ServeSession`), and a metrics
//! registry.

pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod server;

pub use metrics::Metrics;
pub use pipeline::{LieqPipeline, PipelineOptions, PipelineResult};
pub use scheduler::WorkQueue;
#[allow(deprecated)]
pub use server::{serve, serve_batch};
pub use server::{
    AdmissionPolicy, Response, ResponseError, Scorer, ScorerFactory, ServeOptions,
    ServeSession, ServerReport, SessionOptions, SessionStats, SubmitError, SubmitOptions,
    Ticket, WorkerRuntime,
};
