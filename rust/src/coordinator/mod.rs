//! L3 coordination: the LieQ pipeline, a threaded calibration scheduler,
//! a streaming session serving API on a persistent multi-worker runtime
//! (`server::WorkerRuntime` + `server::ServeSession`, continuous batching
//! with per-token [`server::TokenEvent`] streams and a prefix-reuse KV
//! cache), a cluster tier routing sessions across replicated/sharded
//! runtimes (`cluster::ClusterRuntime`), and a metrics registry.

pub mod cluster;
pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod server;

pub use cluster::{
    ClusterRuntime, ClusterSession, ClusterStats, ClusterTicket, ReplicaHealth, ReplicaStats,
    ShardPipeline, ShardPlan, ShardStage, StageFactory,
};
pub use metrics::Metrics;
pub use pipeline::{LieqPipeline, PipelineOptions, PipelineResult};
pub use scheduler::WorkQueue;
pub use server::{
    AdmissionPolicy, Response, ResponseError, ResumeState, ScoreRequest, Scorer, ScorerFactory,
    ServeSession, ServerReport, SessionOptions, SessionStats, SubmitError, SubmitOptions,
    Ticket, TokenEvent, TokenEvents, WorkerRuntime,
};
