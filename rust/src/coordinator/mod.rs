//! L3 coordination: the LieQ pipeline, a threaded calibration scheduler,
//! a batched serving loop (multi-worker, on `util::pool`), and a metrics
//! registry.

pub mod metrics;
pub mod pipeline;
pub mod scheduler;
pub mod server;

pub use metrics::Metrics;
pub use pipeline::{LieqPipeline, PipelineOptions, PipelineResult};
pub use scheduler::WorkQueue;
pub use server::{serve, serve_batch, ServeOptions, ServerReport};
