//! Batched serving loop (the edge-deployment story): a request queue fed
//! by client threads, a single model worker that drains the queue into
//! fixed-size batches, scores them through the fwd_nll artifact, and
//! reports latency/throughput.
//!
//! This is deliberately shaped like a miniature vLLM-style router front:
//! dynamic batching window + FIFO queue + per-request latency metrics —
//! the coordination layer a quantized edge model runs under.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::eval::ppl::NllBatcher;
use crate::model::{ModelConfig, ParamStore};

use super::metrics::Metrics;

/// A scoring request: token ids in, mean NLL out.
pub struct Request {
    pub tokens: Vec<u32>,
    pub reply: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub mean_nll: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
}

pub struct ServerReport {
    pub served: usize,
    pub batches: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
}

/// Serve `requests` through a dynamic batcher of width `max_batch`.
/// Returns per-request responses (in completion order) plus a report.
pub fn serve_batch(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    max_batch: usize,
) -> Result<(Vec<Response>, ServerReport)> {
    let batcher = NllBatcher::new(cfg, params)?;
    let metrics = Arc::new(Metrics::new());
    let mask = vec![1.0f32; cfg.n_layers];

    let started = Instant::now();
    let (tx, rx) = mpsc::channel::<Request>();
    // Client side: enqueue everything up front (open-loop load).
    let mut reply_rxs = Vec::with_capacity(requests.len());
    for tokens in requests {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request { tokens, reply: rtx, enqueued: Instant::now() })?;
        reply_rxs.push(rrx);
    }
    drop(tx);

    // Worker: drain into batches.
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut pending: Vec<Request> = Vec::new();
    loop {
        // Fill a batch window.
        while pending.len() < max_batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        if pending.is_empty() {
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => break, // all clients done
            }
            continue;
        }
        let batch: Vec<Request> = pending.drain(..pending.len().min(max_batch)).collect();
        let t0 = Instant::now();
        let passages: Vec<Vec<u32>> = batch.iter().map(|r| r.tokens.clone()).collect();
        let rows = batcher.nll_rows(&passages, &mask)?;
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        metrics.observe_ms("batch_exec", exec_ms);
        batches += 1;
        for (req, row) in batch.into_iter().zip(rows) {
            let mean = row.iter().sum::<f32>() / row.len().max(1) as f32;
            let total_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            let queue_ms = total_ms - exec_ms;
            metrics.observe_ms("request_total", total_ms);
            let _ = req.reply.send(Response {
                mean_nll: mean,
                queue_ms: queue_ms.max(0.0),
                total_ms,
            });
            served += 1;
        }
    }

    let responses: Vec<Response> =
        reply_rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
    let (p50, p95, _) = metrics.latency_summary("request_total").unwrap_or((0.0, 0.0, 0.0));
    let secs = started.elapsed().as_secs_f64();
    Ok((
        responses,
        ServerReport {
            served,
            batches,
            p50_ms: p50,
            p95_ms: p95,
            throughput_rps: served as f64 / secs,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration (needs artifacts): batching amortizes — fewer batches
    /// than requests, all requests answered.
    #[test]
    fn serves_all_requests() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..13)
            .map(|i| (0..50u32).map(|t| (t * 3 + i) % 512).collect())
            .collect();
        let (resps, report) = serve_batch(&cfg, &params, reqs, 8).unwrap();
        assert_eq!(resps.len(), 13);
        assert_eq!(report.served, 13);
        assert!(report.batches < 13, "batching never engaged");
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }
}
