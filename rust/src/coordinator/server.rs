//! Session-based serving on a persistent worker runtime (the
//! edge-deployment story): long-lived model workers run **decode
//! iterations over a mutable running batch** (iteration-level /
//! continuous batching), stream per-position [`TokenEvent`]s back to
//! their tickets, and report latency/throughput/queue-depth — while
//! clients talk to the runtime through [`ServeSession`]s.
//!
//! This is deliberately shaped like a miniature vLLM-style router front:
//! streaming enqueue + bounded admission + a deadline-aware priority
//! queue + per-token streaming + a prefix-reuse cache — the coordination
//! layer a quantized edge model runs under.
//!
//! # The session API
//!
//! [`WorkerRuntime`] is the reusable substrate: worker threads are
//! spawned once, each builds its own [`Scorer`] (an `NllBatcher`, so PJRT
//! stays thread-confined and each thread's engine compile-cache stays
//! warm). Clients open a [`ServeSession`] and stream requests in:
//!
//! ```text
//! let mut runtime = WorkerRuntime::new(&cfg, &params, workers);
//! runtime.register_variant("w2", Arc::new(q2_params));
//! let session = runtime.session(SessionOptions::new().decode_chunk(16))?;
//! let t = session.submit(tokens, SubmitOptions::new().deadline(d))?;
//! for ev in t.events() { ... }       // TokenEvent::{Token, Done, Error}
//! let stats = session.stats();       // SessionStats
//! ```
//!
//! * **Continuous batching** — a worker's unit of work is one *decode
//!   iteration* (`SessionOptions::decode_chunk` positions per running
//!   request), not one whole request. Between iterations, finished
//!   requests leave the running batch and compatible queued requests
//!   join ([`crate::util::TaskQueue::try_pop_scan`]) — a short request
//!   submitted behind a long one starts and finishes while the long one
//!   is still decoding, instead of waiting for the whole batch ahead of
//!   it (no FIFO head-of-line blocking).
//! * **Token streaming** — every scored position is sent to the ticket
//!   as [`TokenEvent::Token`] the iteration it decodes; the stream ends
//!   with exactly one terminal event ([`TokenEvent::Done`] carrying the
//!   final [`Response`], or [`TokenEvent::Error`]). [`Ticket::recv`]
//!   keeps its resolve-to-final-`Response` contract by draining events;
//!   [`Ticket::next_event`] / [`Ticket::events`] expose the stream.
//! * **EDF batch formation** — within a priority class the queue orders
//!   by earliest deadline (deadline-less requests rank last and stay
//!   FIFO among themselves); across classes, higher priority still pops
//!   first. Expiry stays lazy: a request whose deadline passes while
//!   queued or mid-stream resolves with
//!   [`ResponseError::DeadlineExceeded`] at the next iteration boundary.
//! * **Prefix reuse** — completed requests publish their per-position
//!   scores to the runtime's block-based
//!   [`crate::runtime::KvBlockCache`]; a new request whose token prefix
//!   is cached (same variant) replays those positions as
//!   `TokenEvent::Token { cached: true }` without scoring them.
//!   Hit/miss/evict counters surface in [`SessionStats::kv`],
//!   [`ServerReport::kv`], and `lieq serve` output.
//! * **Bounded admission** — `SessionOptions { queue_cap, admission }`
//!   bounds how many of the session's requests may wait in the runtime
//!   queue: [`AdmissionPolicy::Block`] applies back-pressure,
//!   [`AdmissionPolicy::Reject`] refuses with
//!   [`SubmitError::QueueFull`], [`AdmissionPolicy::ShedOldest`] drops
//!   the session's lowest-priority, oldest queued request (its ticket
//!   resolves with [`ResponseError::QueueFull`]) to admit the new one.
//! * **Multi-variant A/B routing** — [`WorkerRuntime::register_variant`]
//!   publishes additional parameter sets (quantized variants) on the
//!   same warm runtime; `SubmitOptions { variant, .. }` routes each
//!   request. Running batches never mix sessions or variants, and
//!   workers apply the generation-bumped variant map before each
//!   iteration — the same `Arc` handoff as
//!   [`WorkerRuntime::set_params`], so an FP16↔2/3/4-bit A/B comparison
//!   shares one set of compiled artifacts.
//!
//! **Reply contract:** every submitted [`Ticket`] resolves — with a
//! score, or with a typed [`ResponseError`] — and
//! [`ServeSession::wait_all`] returns responses in submission order. A
//! worker that fails mid-iteration re-queues its running requests (with
//! their decode position preserved, so no token is re-emitted) for the
//! surviving workers (`requeued` in [`SessionStats`]); requests that
//! exhaust their retry budget, or drain after the last worker exits, get
//! a terminal error event rather than being silently dropped.
//!
//! **Scheduling trade-off:** joins are utilization-first — a worker
//! scans past queued requests that are incompatible with its running
//! batch (different session/variant) unless they outrank it, so
//! same-priority incompatible work waits for a free worker rather than
//! preempting. Higher-priority queued work is never overtaken by a
//! lower-priority join.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::eval::ppl::NllBatcher;
use crate::kernels::{self, KernelPathSink, KernelPathStats};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::cache::{self as runtime_cache, CacheCounterSink, CacheStats};
use crate::runtime::kvcache::{KvBlockCache, KvCacheStats};
use crate::util::pool::ScanDecision;
use crate::util::{pool, TaskQueue};

use super::metrics::Metrics;

/// Retries a request gets after batch-scoring failures before it is
/// error-replied.
const MAX_ATTEMPTS: u32 = 3;
/// Consecutive scoring failures after which a worker assumes its scorer
/// is broken and exits (its batches re-queue onto surviving workers).
const MAX_CONSECUTIVE_FAILURES: u32 = 2;
/// Failure messages kept for diagnostics (older entries are dropped).
const MAX_RECORDED_FAILURES: usize = 32;

/// Why a request resolved without a score. Every variant maps 1:1 onto a
/// serving outcome, so callers can branch without string matching.
/// Non-exhaustive: new serving outcomes may be added without a semver
/// break, so downstream matches need a wildcard arm.
#[non_exhaustive]
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResponseError {
    /// Scoring failed (retry budget exhausted, every worker exited, or a
    /// scorer build/batch error); the message carries the diagnostics.
    WorkerFailure(String),
    /// The request's deadline passed before a worker picked it up
    /// (expiry is checked lazily at batch-formation time).
    DeadlineExceeded,
    /// [`Ticket::cancel`] resolved the request before scoring.
    Cancelled,
    /// The request was shed from a full queue
    /// ([`AdmissionPolicy::ShedOldest`]).
    QueueFull,
    /// The runtime shut down with the request still unresolved.
    Shutdown,
}

impl ResponseError {
    /// Session counter this outcome lands in.
    fn counter(&self) -> &'static str {
        match self {
            ResponseError::WorkerFailure(_) | ResponseError::Shutdown => "failed",
            ResponseError::DeadlineExceeded => "expired",
            ResponseError::Cancelled => "cancelled",
            ResponseError::QueueFull => "shed",
        }
    }
}

impl std::fmt::Display for ResponseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResponseError::WorkerFailure(msg) => write!(f, "worker failure: {msg}"),
            ResponseError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ResponseError::Cancelled => write!(f, "cancelled"),
            ResponseError::QueueFull => write!(f, "shed from full queue"),
            ResponseError::Shutdown => write!(f, "runtime shut down"),
        }
    }
}

impl std::error::Error for ResponseError {}

/// Why [`ServeSession::submit`] refused a request (no [`Ticket`] was
/// created; nothing entered the queue). Non-exhaustive: new refusal
/// modes may be added without a semver break.
#[non_exhaustive]
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The session's queue is at capacity under
    /// [`AdmissionPolicy::Reject`].
    QueueFull { cap: usize },
    /// `SubmitOptions::variant` names an id that was never registered.
    UnknownVariant(String),
    /// The runtime's queue closed (shutdown race).
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => {
                write!(f, "session queue full (capacity {cap})")
            }
            SubmitError::UnknownVariant(id) => write!(f, "unknown variant {id:?}"),
            SubmitError::Shutdown => write!(f, "runtime shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<SubmitError> for ResponseError {
    fn from(e: SubmitError) -> ResponseError {
        match e {
            SubmitError::QueueFull { .. } => ResponseError::QueueFull,
            SubmitError::UnknownVariant(id) => {
                ResponseError::WorkerFailure(format!("unknown variant {id:?}"))
            }
            SubmitError::Shutdown => ResponseError::Shutdown,
        }
    }
}

/// What happens when a submit finds the session's queue at capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the submitter until a slot frees (back-pressure).
    Block,
    /// Refuse the new request with [`SubmitError::QueueFull`].
    Reject,
    /// Drop the session's lowest-priority queued request — oldest within
    /// that priority level (typed [`ResponseError::QueueFull`] on its
    /// ticket) — and admit the new one. A newcomer outranked by
    /// everything queued is itself refused ([`SubmitError::QueueFull`])
    /// instead of evicting higher-priority work.
    ShedOldest,
}

impl AdmissionPolicy {
    pub fn from_name(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "block" => Some(AdmissionPolicy::Block),
            "reject" => Some(AdmissionPolicy::Reject),
            "shed" | "shed-oldest" | "shed_oldest" => Some(AdmissionPolicy::ShedOldest),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::ShedOldest => "shed-oldest",
        }
    }
}

/// Per-session knobs (see [`WorkerRuntime::session`]). Construct with
/// the chainable builder: `SessionOptions::new().queue_cap(64)
/// .admission(AdmissionPolicy::ShedOldest).decode_chunk(16)`.
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// Max requests in a worker's running batch (the continuous-batching
    /// slot count; joins refill up to this between iterations).
    pub max_batch: usize,
    /// Max requests of this session waiting in the runtime queue;
    /// 0 = unbounded (requests in running batches don't count against
    /// it).
    pub queue_cap: usize,
    /// What `submit` does when the cap is reached.
    pub admission: AdmissionPolicy,
    /// Positions scored per request per decode iteration; `0` (the
    /// default) scores each request's whole remainder in one iteration.
    /// Smaller chunks stream tokens sooner and create more join/leave
    /// points, but the `fwd_nll` artifact keeps no activation state
    /// across calls, so each iteration re-scores the prefix — chunked
    /// decode trades extra compute (~`L/chunk` prefix passes) for
    /// first-token latency and scheduling granularity.
    pub decode_chunk: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            max_batch: 8,
            queue_cap: 0,
            admission: AdmissionPolicy::Block,
            decode_chunk: 0,
        }
    }
}

impl SessionOptions {
    pub fn new() -> SessionOptions {
        SessionOptions::default()
    }

    pub fn max_batch(mut self, n: usize) -> SessionOptions {
        self.max_batch = n;
        self
    }

    pub fn queue_cap(mut self, n: usize) -> SessionOptions {
        self.queue_cap = n;
        self
    }

    pub fn admission(mut self, policy: AdmissionPolicy) -> SessionOptions {
        self.admission = policy;
        self
    }

    pub fn decode_chunk(mut self, positions: usize) -> SessionOptions {
        self.decode_chunk = positions;
        self
    }
}

/// Per-request knobs for [`ServeSession::submit`].
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Drop the request (typed [`ResponseError::DeadlineExceeded`]) if no
    /// worker picks it up within this budget from submission. Checked
    /// lazily at batch-formation time.
    pub deadline: Option<Duration>,
    /// Route to a registered parameter variant
    /// ([`WorkerRuntime::register_variant`]); `None` = the runtime's
    /// default parameters.
    pub variant: Option<String>,
    /// Queue priority: higher pops first; within a level the queue is
    /// EDF (earliest deadline first, deadline-less last, FIFO among
    /// equals). Default 0; non-positive values clamp to 0.
    pub priority: i32,
}

impl SubmitOptions {
    pub fn new() -> SubmitOptions {
        SubmitOptions::default()
    }

    pub fn deadline(mut self, d: Duration) -> SubmitOptions {
        self.deadline = Some(d);
        self
    }

    pub fn variant(mut self, id: impl Into<String>) -> SubmitOptions {
        self.variant = Some(id.into());
        self
    }

    pub fn priority(mut self, p: i32) -> SubmitOptions {
        self.priority = p;
        self
    }
}

/// One element of a ticket's event stream. A request emits zero or more
/// `Token` events (one per scored position, in position order) followed
/// by **exactly one** terminal event: `Done` with the final [`Response`]
/// on success, or `Error` when the request resolved without a score.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// Position `index` decoded: the NLL of token `index + 1` given the
    /// prefix. `cached` marks positions replayed from the prefix-reuse
    /// cache rather than scored.
    Token { index: usize, nll: f32, cached: bool },
    /// Terminal: the request scored to completion.
    Done(Response),
    /// Terminal: the request resolved without a score (the matching
    /// [`Ticket::recv`] Response carries the same error).
    Error(ResponseError),
}

impl TokenEvent {
    /// `Done` and `Error` end the stream.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, TokenEvent::Token { .. })
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub mean_nll: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// Variant that scored (or would have scored) this request; `None`
    /// for the runtime's default parameters.
    pub variant: Option<String>,
    /// `Some(err)` when the request could not be scored. `mean_nll` is
    /// NaN then.
    pub error: Option<ResponseError>,
    /// Latency to the first streamed token (same clock as `total_ms`);
    /// `None` when nothing streamed (errors, zero-position requests).
    pub first_token_ms: Option<f64>,
    /// Token events this request emitted (cached replays included).
    pub tokens_streamed: u32,
    /// How many of those were replayed from the prefix-reuse cache.
    pub cached_tokens: u32,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    fn failed(err: ResponseError, since: Instant) -> Response {
        Response {
            mean_nll: f32::NAN,
            queue_ms: 0.0,
            total_ms: since.elapsed().as_secs_f64() * 1e3,
            variant: None,
            error: Some(err),
            first_token_ms: None,
            tokens_streamed: 0,
            cached_tokens: 0,
        }
    }
}

/// Summary shape for [`ServeSession::report`] and CLI output;
/// [`SessionStats`] is the richer windowed view.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered with a real score.
    pub served: usize,
    /// Requests answered with an error [`Response`] of any kind (never
    /// dropped): worker failures, expiries, cancellations, sheds.
    pub failed: usize,
    /// Requests pushed back to the queue after a worker failed mid-batch.
    pub requeued: usize,
    pub batches: usize,
    /// Configured worker count (see [`ServerReport::ready_workers`] for
    /// how many actually built a scorer).
    pub workers: usize,
    /// Workers still alive when this report was taken.
    pub ready_workers: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
    /// Peak number of requests waiting when a batch was formed.
    pub max_queue_depth: usize,
    /// Time from session open until the first batch was picked up — the
    /// per-call setup cost (≈0 on a warm runtime; scorer build +
    /// artifact compile on a cold one).
    pub setup_ms: f64,
    /// Artifact-cache hits since this runtime was built. Counted on the
    /// runtime's own worker threads (see `runtime::cache::attach_thread_sink`),
    /// so concurrent runtimes/pipelines no longer pollute each other.
    pub cache_hits: u64,
    /// Artifact loads/compiles since this runtime was built (same
    /// per-runtime attribution as `cache_hits`). Stays flat across
    /// repeat sessions on a lone runtime: batchers and executables
    /// persist.
    pub cache_misses: u64,
    /// CPU dq_gemm traffic per kernel path (direct/panel/LUT/A8 calls
    /// with the LUT split into nibble/byte flavors, residual panel
    /// unpacks, LUT builds, `lane_builds` — lazy planes→lanes
    /// conversions, 0 when weights were loaded from a lane-persisting
    /// `.lieq` v2 archive — the `outlier_fused_calls`/`outlier_cols`
    /// counters for GEMMs that fused a sparse fp16 outlier sidecar into
    /// the dense pass, and the `simd_*_calls` per-tier attribution:
    /// how many of each path's calls ran on a SIMD tier rather than the
    /// scalar reference) since this runtime was built — counted on the
    /// runtime's own worker threads. Zero when scoring runs entirely
    /// through PJRT artifacts.
    pub kernel_paths: KernelPathStats,
    /// Prefix-reuse cache counters since this runtime was built (the
    /// cache is per-runtime, shared by all of its sessions).
    pub kv: KvCacheStats,
    /// p95 latency to first streamed token over this session's retained
    /// samples.
    pub first_token_p95_ms: f64,
}

/// One sequence's share of a decode iteration: score `window` positions
/// of `tokens`, where position `i` is the NLL of `tokens[i + 1]` given
/// the prefix `tokens[..=i]`. `window.end <= tokens.len() - 1` always
/// holds.
pub struct ScoreRequest<'a> {
    pub tokens: &'a [u32],
    pub window: std::ops::Range<usize>,
}

/// What a serving worker runs per decode iteration. The production impl
/// wraps [`NllBatcher`]; tests and benches inject synthetic scorers to
/// exercise the runtime (failure paths, param swaps, timing) without
/// artifacts.
pub trait Scorer {
    /// One row per request, each exactly `window.len()` values (the
    /// worker treats any other shape as a scoring failure so every
    /// ticket still resolves).
    fn score_window(&mut self, reqs: &[ScoreRequest<'_>]) -> Result<Vec<Vec<f32>>>;
    /// Swap in a new parameter set (quantized-variant handoff).
    fn set_params(&mut self, params: &Arc<ParamStore>);
}

/// Builds one [`Scorer`] per worker, *on the worker's own thread* (PJRT
/// engines are thread-confined). Receives the worker index and the
/// current shared parameters.
pub type ScorerFactory =
    Arc<dyn Fn(usize, &Arc<ParamStore>) -> Result<Box<dyn Scorer>> + Send + Sync>;

struct NllScorer {
    batcher: NllBatcher,
    mask: Vec<f32>,
}

impl Scorer for NllScorer {
    fn score_window(&mut self, reqs: &[ScoreRequest<'_>]) -> Result<Vec<Vec<f32>>> {
        // The fwd_nll artifact scores whole prefixes: a window `[s, e)`
        // is served by scoring `tokens[..=e]` and slicing the row. The
        // artifact keeps no activation state across calls, so chunked
        // decode re-pays the prefix each iteration — the prefix-reuse
        // cache one layer up is what amortizes *repeated* prompts.
        let passages: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| r.tokens[..(r.window.end + 1).min(r.tokens.len())].to_vec())
            .collect();
        let rows = self.batcher.nll_rows(&passages, &self.mask)?;
        anyhow::ensure!(
            rows.len() == reqs.len(),
            "nll_rows returned {} rows for {} passages",
            rows.len(),
            reqs.len()
        );
        reqs.iter()
            .zip(rows)
            .map(|(r, row)| {
                anyhow::ensure!(
                    row.len() >= r.window.end,
                    "nll_rows returned {} positions, window ends at {}",
                    row.len(),
                    r.window.end
                );
                Ok(row[r.window.start..r.window.end].to_vec())
            })
            .collect()
    }

    fn set_params(&mut self, params: &Arc<ParamStore>) {
        self.batcher.set_params_shared(Arc::clone(params));
    }
}

/// Per-session state shared by that session's jobs, the submitting
/// thread, and the workers scoring its batches.
struct SessionCtx {
    metrics: Metrics,
    /// First-batch pickup time: request latency/throughput are measured
    /// from `max(enqueued, begin)` so scorer/artifact setup is not
    /// billed to requests.
    begin: Mutex<Option<Instant>>,
    max_batch: usize,
    /// Positions per request per decode iteration; 0 = whole remainder.
    decode_chunk: usize,
    /// 0 = unbounded.
    queue_cap: usize,
    admission: AdmissionPolicy,
    /// This session's requests currently *waiting* in the runtime queue
    /// (in-flight batches excluded) — the quantity the admission cap
    /// bounds.
    queued: Mutex<usize>,
    /// Signalled whenever `queued` drops (pop/shed/cancel/drain), waking
    /// `Block`-policy submitters.
    space_cv: Condvar,
}

impl SessionCtx {
    fn note_dequeued(&self, n: usize) {
        let mut q = self.queued.lock().unwrap();
        *q = q.saturating_sub(n);
        drop(q);
        self.space_cv.notify_all();
    }

    fn note_requeued(&self) {
        *self.queued.lock().unwrap() += 1;
    }
}

/// One request, both while queued and while in a worker's running batch
/// (the decode-state fields travel with it, so a failure-path re-queue
/// resumes at `pos` instead of re-emitting tokens).
struct Job {
    tokens: Vec<u32>,
    reply: mpsc::Sender<TokenEvent>,
    enqueued: Instant,
    deadline: Option<Instant>,
    variant: Option<String>,
    priority: i32,
    cancelled: Arc<AtomicBool>,
    attempts: u32,
    call: Arc<SessionCtx>,
    /// Next position to decode (== tokens emitted so far).
    pos: usize,
    /// Running sum of emitted NLLs (f64: long streams of f32 values).
    nll_sum: f64,
    /// Every emitted value, for the prefix-cache insert at completion.
    vals: Vec<f32>,
    /// Positions replayed from the prefix cache.
    cached_tokens: usize,
    /// First admission into a running batch (queue_ms boundary).
    started: Option<Instant>,
    /// Latency to the first emitted token, once one exists.
    first_token_ms: Option<f64>,
}

impl Job {
    /// Positions this request decodes: position `i` scores token `i+1`,
    /// so an `L`-token request has `L - 1` of them (0 for a single
    /// token — such requests complete immediately with mean 0).
    fn n_pos(&self) -> usize {
        self.tokens.len().saturating_sub(1)
    }

    /// Request latency clock origin: submission, but never before the
    /// session's first pickup (scorer/artifact setup is not billed to
    /// requests).
    fn t_in(&self) -> Instant {
        let begin = self.call.begin.lock().unwrap().unwrap_or(self.enqueued);
        self.enqueued.max(begin)
    }

    /// Decode one position: stream the event and advance the state.
    fn emit_token(&mut self, nll: f32, cached: bool) {
        if self.first_token_ms.is_none() {
            let ms = self.t_in().elapsed().as_secs_f64() * 1e3;
            self.first_token_ms = Some(ms);
            self.call.metrics.observe_ms("first_token", ms);
        }
        self.call.metrics.incr("tokens_streamed", 1);
        if cached {
            self.call.metrics.incr("cached_tokens", 1);
        }
        let index = self.pos;
        self.pos += 1;
        self.nll_sum += nll as f64;
        self.vals.push(nll);
        let _ = self.reply.send(TokenEvent::Token { index, nll, cached });
    }

    /// Terminal success: publish the row to the prefix cache, record the
    /// latency sample, send `Done`.
    fn finish_ok(self, shared: &Shared) {
        debug_assert!(self.pos >= self.n_pos());
        shared.kv.insert(self.variant.as_deref(), &self.tokens, &self.vals);
        let t_in = self.t_in();
        let total_ms = t_in.elapsed().as_secs_f64() * 1e3;
        let queue_ms = self
            .started
            .map(|s| s.saturating_duration_since(t_in).as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        self.call.metrics.observe_ms("request_total", total_ms);
        self.call.metrics.incr("served", 1);
        let n = self.n_pos();
        let mean = if n == 0 { 0.0 } else { (self.nll_sum / n as f64) as f32 };
        let _ = self.reply.send(TokenEvent::Done(Response {
            mean_nll: mean,
            queue_ms,
            total_ms,
            variant: self.variant.clone(),
            error: None,
            first_token_ms: self.first_token_ms,
            tokens_streamed: self.pos as u32,
            cached_tokens: self.cached_tokens as u32,
        }));
    }

    /// Terminal error: bump the matching session counter and send the
    /// single `Error` event (the 1:1 contract — a job never just
    /// disappears, and a partially-streamed job still terminates exactly
    /// once).
    fn finish_error(self, err: ResponseError) {
        self.call.metrics.incr(err.counter(), 1);
        let _ = self.reply.send(TokenEvent::Error(err));
    }
}

/// Queue rank for newly submitted work: strict priority first, earliest
/// deadline within a class; deadline-less requests rank last (infinite
/// deadline) and stay FIFO among themselves (`push_by` inserts before
/// the first item this returns true against).
fn edf_goes_before(a_pri: i32, a_dl: Option<Instant>, b_pri: i32, b_dl: Option<Instant>) -> bool {
    a_pri > b_pri
        || (a_pri == b_pri
            && match (a_dl, b_dl) {
                (Some(x), Some(y)) => x < y,
                (Some(_), None) => true,
                _ => false,
            })
}

/// Retry rank: like [`edf_goes_before`] but ties insert *before*, so a
/// re-queued request re-enters at the front of its (priority, deadline)
/// standing instead of paying the queue again — without overtaking
/// strictly better-ranked work.
fn edf_retry_goes_before(
    a_pri: i32,
    a_dl: Option<Instant>,
    b_pri: i32,
    b_dl: Option<Instant>,
) -> bool {
    a_pri > b_pri
        || (a_pri == b_pri
            && match (a_dl, b_dl) {
                (Some(x), Some(y)) => x <= y,
                (None, Some(_)) => false,
                _ => true,
            })
}

struct WorkerState {
    /// Workers whose scorer build resolved (successfully or not).
    started: usize,
    /// Workers that built a scorer and are still running.
    running: usize,
    /// Workers that ever built a scorer successfully.
    ready: usize,
}

struct Shared {
    queue: TaskQueue<Job>,
    /// Default weights; bumping `params_gen` makes every worker re-apply
    /// its variant from here / `variants` before its next batch.
    params: Mutex<Arc<ParamStore>>,
    /// Registered A/B variants (id -> weights), routed per request.
    variants: Mutex<BTreeMap<String, Arc<ParamStore>>>,
    params_gen: AtomicU64,
    state: Mutex<WorkerState>,
    state_cv: Condvar,
    failures: Mutex<Vec<String>>,
    workers: usize,
    /// Successful decode iterations across all workers — the liveness
    /// heartbeat a cluster router reads: a replica whose workers are
    /// alive but wedged stops advancing this while `running` stays up.
    iterations: AtomicU64,
    /// Per-runtime counter attribution: worker threads attach these at
    /// start, so cache/kernel traffic is billed to *this* runtime even
    /// with other runtimes or pipelines live in the process.
    cache_sink: Arc<CacheCounterSink>,
    kernel_sink: Arc<KernelPathSink>,
    /// Prefix-reuse cache, shared by all workers/sessions of this
    /// runtime; invalidated per variant on parameter swaps.
    kv: KvBlockCache,
}

impl Shared {
    fn current_params(&self) -> (u64, Arc<ParamStore>) {
        let p = self.params.lock().unwrap();
        (self.params_gen.load(Ordering::SeqCst), Arc::clone(&p))
    }

    /// Parameters for a variant id (`None` = default), with the map
    /// generation observed *before* the lookup (a concurrent bump makes
    /// the worker re-apply next batch — never miss an update).
    fn params_for(&self, variant: Option<&str>) -> Option<(u64, Arc<ParamStore>)> {
        let gen = self.params_gen.load(Ordering::SeqCst);
        let params = match variant {
            None => Some(Arc::clone(&self.params.lock().unwrap())),
            Some(id) => self.variants.lock().unwrap().get(id).cloned(),
        };
        params.map(|p| (gen, p))
    }

    fn has_variant(&self, id: &str) -> bool {
        self.variants.lock().unwrap().contains_key(id)
    }

    fn push_failure(&self, msg: String) {
        log::warn!("serving: {msg}");
        let mut f = self.failures.lock().unwrap();
        // Keep the tail only: a long-lived runtime with a flaky scorer
        // must not accumulate one string per failed batch forever.
        if f.len() >= MAX_RECORDED_FAILURES {
            f.remove(0);
        }
        f.push(msg);
    }

    fn failure_summary(&self) -> String {
        let f = self.failures.lock().unwrap();
        if f.is_empty() {
            "unknown".to_string()
        } else {
            f.join("; ")
        }
    }

    /// True once no worker is running and none can still come up.
    fn no_capacity_left(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.started == self.workers && s.running == 0
    }

    /// Error-reply every queued job (all-workers-dead path), releasing
    /// each job's session-queue slot so blocked submitters wake.
    fn drain_with_errors(&self, err: &ResponseError) {
        for job in self.queue.drain() {
            job.call.note_dequeued(1);
            job.finish_error(err.clone());
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "panic".to_string())
}

/// Decrements `running` (and error-drains the queue when the last worker
/// goes away) on *every* worker exit path, including unwinds from a
/// panicking `Scorer::set_params` or metrics call — without this,
/// submitted tickets could block forever on a reply that can no longer
/// come.
struct RunningGuard {
    shared: Arc<Shared>,
}

impl Drop for RunningGuard {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.running -= 1;
        drop(st);
        self.shared.state_cv.notify_all();
        if self.shared.no_capacity_left() {
            self.shared.drain_with_errors(&ResponseError::WorkerFailure(
                "all serving workers exited".to_string(),
            ));
        }
    }
}

fn worker_loop(wid: usize, shared: Arc<Shared>, factory: ScorerFactory) {
    // Per-runtime counter attribution (see `Shared::cache_sink`).
    runtime_cache::attach_thread_sink(&shared.cache_sink);
    kernels::attach_thread_sink(&shared.kernel_sink);

    let (mut local_gen, params) = shared.current_params();
    // A panicking factory must still resolve this worker's build —
    // otherwise session()/wait_ready() would wait on `started` forever.
    let built = catch_unwind(AssertUnwindSafe(|| factory(wid, &params)))
        .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer build panicked: {}", panic_msg(&*p))));
    let mut scorer = match built {
        Ok(s) => {
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            st.running += 1;
            st.ready += 1;
            drop(st);
            shared.state_cv.notify_all();
            s
        }
        Err(e) => {
            shared.push_failure(format!("worker {wid} scorer build failed: {e:#}"));
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            drop(st);
            shared.state_cv.notify_all();
            if shared.no_capacity_left() {
                shared.drain_with_errors(&ResponseError::WorkerFailure(
                    "no serving workers available".to_string(),
                ));
            }
            return;
        }
    };

    let _guard = RunningGuard { shared: Arc::clone(&shared) };
    // Variant whose parameters this worker's scorer currently holds
    // (`None` = the runtime default). The scorer was just built from the
    // default params.
    let mut applied_variant: Option<String> = None;
    let mut consecutive_failures = 0u32;
    // The mutable running batch: all jobs share one session and variant
    // (metrics/window are per-session; one set_params per iteration).
    let mut running: Vec<Job> = Vec::new();
    loop {
        // ---- admission: refill the running batch ----
        if running.is_empty() {
            // Blocking pop — this is the only point a worker waits, so a
            // worker holding live requests never stalls on the queue.
            let Some((batch, depth)) = shared.queue.pop_batch(
                |first| first.call.max_batch,
                |first, next| Arc::ptr_eq(&first.call, &next.call) && first.variant == next.variant,
            ) else {
                break; // closed and empty
            };
            let call = Arc::clone(&batch[0].call);
            call.note_dequeued(batch.len());
            call.begin.lock().unwrap().get_or_insert_with(Instant::now);
            call.metrics.observe("queue_depth", depth as f64);
            admit(&shared, batch, &mut running);
            if running.is_empty() {
                continue; // everything was cancelled/expired/fully cached
            }
        } else {
            let free = running[0].call.max_batch.saturating_sub(running.len());
            if free > 0 {
                // Mid-flight join: pull compatible queued requests into
                // the free slots without blocking. Incompatible requests
                // are skipped (utilization-first) unless they outrank
                // the running batch — a lower-priority join must never
                // overtake queued higher-priority work.
                let head_ctx = Arc::clone(&running[0].call);
                let head_variant = running[0].variant.clone();
                let floor = running.iter().map(|j| j.priority).max().unwrap_or(0);
                let joined = shared.queue.try_pop_scan(free, |j: &Job| {
                    if Arc::ptr_eq(&j.call, &head_ctx) && j.variant == head_variant {
                        ScanDecision::Take
                    } else if j.priority > floor {
                        ScanDecision::Stop
                    } else {
                        ScanDecision::Skip
                    }
                });
                if !joined.is_empty() {
                    head_ctx.note_dequeued(joined.len());
                    admit(&shared, joined, &mut running);
                }
            }
        }

        // ---- iteration-boundary cancel/deadline sweep ----
        // Mid-stream cancellations and expiries resolve here with one
        // terminal Error event; already-emitted tokens stand.
        let now = Instant::now();
        let mut i = 0;
        while i < running.len() {
            if running[i].cancelled.load(Ordering::SeqCst) {
                running.remove(i).finish_error(ResponseError::Cancelled);
            } else if running[i].deadline.is_some_and(|d| d <= now) {
                running.remove(i).finish_error(ResponseError::DeadlineExceeded);
            } else {
                i += 1;
            }
        }
        if running.is_empty() {
            continue;
        }

        // ---- param handoff ----
        // A pending set_params/register_variant bump, or a running batch
        // routed to a different variant than the last one this worker
        // scored. One atomic load on the fast path.
        let call = Arc::clone(&running[0].call);
        let want = running[0].variant.clone();
        if shared.params_gen.load(Ordering::SeqCst) != local_gen || applied_variant != want {
            match shared.params_for(want.as_deref()) {
                Some((gen, params)) => {
                    if applied_variant != want {
                        call.metrics.incr("variant_swaps", 1);
                    }
                    scorer.set_params(&params);
                    local_gen = gen;
                    applied_variant = want.clone();
                }
                None => {
                    // Unregistered id — submit validates, so this is a
                    // defensive path; resolve rather than hang.
                    let msg = format!("unknown variant {:?}", want.as_deref().unwrap_or(""));
                    for job in running.drain(..) {
                        job.finish_error(ResponseError::WorkerFailure(msg.clone()));
                    }
                    continue;
                }
            }
        }

        // ---- one decode iteration ----
        let chunk = call.decode_chunk;
        let t0 = Instant::now();
        let scored = {
            let reqs: Vec<ScoreRequest<'_>> = running
                .iter()
                .map(|j| {
                    let end =
                        if chunk == 0 { j.n_pos() } else { (j.pos + chunk).min(j.n_pos()) };
                    ScoreRequest { tokens: &j.tokens, window: j.pos..end }
                })
                .collect();
            catch_unwind(AssertUnwindSafe(|| scorer.score_window(&reqs)))
                .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer panicked: {}", panic_msg(&*p))))
                .and_then(|rows| {
                    // A malformed shape would desync job decode state;
                    // treat it as a scoring failure so every job still
                    // resolves.
                    anyhow::ensure!(
                        rows.len() == reqs.len(),
                        "scorer returned {} rows for {} sequences",
                        rows.len(),
                        reqs.len()
                    );
                    for (req, row) in reqs.iter().zip(&rows) {
                        anyhow::ensure!(
                            row.len() == req.window.len(),
                            "scorer returned {} values for a {}-position window",
                            row.len(),
                            req.window.len()
                        );
                    }
                    Ok(rows)
                })
        };
        match scored {
            Ok(rows) => {
                consecutive_failures = 0;
                shared.iterations.fetch_add(1, Ordering::Relaxed);
                call.metrics.observe_ms("batch_exec", t0.elapsed().as_secs_f64() * 1e3);
                call.metrics.incr("batches", 1);
                for (job, row) in running.iter_mut().zip(&rows) {
                    for &nll in row {
                        job.emit_token(nll, false);
                    }
                }
                // Finished requests leave the running batch.
                let mut i = 0;
                while i < running.len() {
                    if running[i].pos >= running[i].n_pos() {
                        running.remove(i).finish_ok(&shared);
                    } else {
                        i += 1;
                    }
                }
            }
            Err(e) => {
                consecutive_failures += 1;
                let msg = format!("{e:#}");
                shared.push_failure(format!("worker {wid} iteration failed: {msg}"));
                // Re-queue the running batch at the front of each job's
                // own rank (reverse order restores relative order);
                // decode state travels with the job, so a surviving
                // worker resumes at `pos` without re-emitting tokens.
                // The shared queue is unbounded, so the ranked insert
                // cannot block this worker.
                let evicted: Vec<Job> = running.drain(..).collect();
                for mut job in evicted.into_iter().rev() {
                    job.attempts += 1;
                    if job.attempts >= MAX_ATTEMPTS {
                        job.finish_error(ResponseError::WorkerFailure(msg.clone()));
                    } else {
                        job.call.metrics.incr("requeued", 1);
                        job.call.note_requeued();
                        if let Err(job) = shared.queue.push_by(job, |a, b| {
                            edf_retry_goes_before(a.priority, a.deadline, b.priority, b.deadline)
                        }) {
                            // Queue closed under us: reply, don't drop.
                            job.call.note_dequeued(1);
                            job.finish_error(ResponseError::Shutdown);
                        }
                    }
                }
                if consecutive_failures >= MAX_CONSECUTIVE_FAILURES {
                    log::warn!(
                        "serving worker {wid}: {consecutive_failures} consecutive scoring \
                         failures, exiting"
                    );
                    break;
                }
            }
        }
    }

    // `_guard` drops here: running--, notify waiters, drain if last.
    // (`running` is always empty on both exit paths: the blocking pop
    // only runs with an empty batch, and the failure path drains it.)
}

/// Move popped jobs into the running batch: resolve cancelled/expired
/// ones, stamp first-admission time, and replay any cached prefix —
/// fully-cached requests (and zero-position single-token requests)
/// complete right here without ever occupying a slot.
fn admit(shared: &Shared, jobs: Vec<Job>, running: &mut Vec<Job>) {
    let now = Instant::now();
    for mut job in jobs {
        if job.cancelled.load(Ordering::SeqCst) {
            job.finish_error(ResponseError::Cancelled);
        } else if job.deadline.is_some_and(|d| d <= now) {
            job.finish_error(ResponseError::DeadlineExceeded);
        } else {
            if job.started.is_none() {
                job.started = Some(now);
            }
            // Prefix lookup only on first admission (a re-queued retry
            // resumes at `pos` and must not re-emit its prefix).
            if job.pos == 0 && job.n_pos() > 0 {
                if let Some(hit) = shared.kv.lookup(job.variant.as_deref(), &job.tokens) {
                    job.cached_tokens = hit.vals.len();
                    for nll in hit.vals {
                        job.emit_token(nll, true);
                    }
                }
            }
            if job.pos >= job.n_pos() {
                job.finish_ok(shared);
            } else {
                running.push(job);
            }
        }
    }
}

/// Persistent serving runtime: long-lived workers, each owning a
/// [`Scorer`] built on its own thread, shared weights behind an `Arc`, a
/// registered-variant map for A/B routing, and a FIFO+priority queue
/// with a dynamic batching window. Clients talk to it through
/// [`WorkerRuntime::session`]; see the module docs.
pub struct WorkerRuntime {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerRuntime {
    /// Production runtime: one [`NllBatcher`]-backed scorer per worker.
    /// Workers build eagerly in the background; the first session waits
    /// for capacity.
    pub fn new(cfg: &ModelConfig, params: &ParamStore, workers: usize) -> WorkerRuntime {
        let cfg = cfg.clone();
        let factory: ScorerFactory = Arc::new(move |_wid, params| {
            let batcher = NllBatcher::new_shared(&cfg, Arc::clone(params))?;
            let mask = vec![1.0f32; cfg.n_layers];
            Ok(Box::new(NllScorer { batcher, mask }) as Box<dyn Scorer>)
        });
        Self::with_scorer_factory(workers, Arc::new(params.clone()), factory)
    }

    /// Runtime with an injected scorer factory (tests, benches, custom
    /// model backends). `workers == 0` sizes from the process-wide thread
    /// configuration.
    pub fn with_scorer_factory(
        workers: usize,
        params: Arc<ParamStore>,
        factory: ScorerFactory,
    ) -> WorkerRuntime {
        let workers = if workers == 0 { pool::global_threads() } else { workers };
        let shared = Arc::new(Shared {
            queue: TaskQueue::new(),
            params: Mutex::new(params),
            variants: Mutex::new(BTreeMap::new()),
            params_gen: AtomicU64::new(0),
            state: Mutex::new(WorkerState { started: 0, running: 0, ready: 0 }),
            state_cv: Condvar::new(),
            failures: Mutex::new(Vec::new()),
            workers,
            iterations: AtomicU64::new(0),
            cache_sink: Arc::new(CacheCounterSink::default()),
            kernel_sink: Arc::new(KernelPathSink::default()),
            kv: KvBlockCache::default(),
        });
        let handles = (0..workers)
            .filter_map(|wid| {
                let shared_w = Arc::clone(&shared);
                let factory = Arc::clone(&factory);
                let spawned = std::thread::Builder::new()
                    .name(format!("lieq-serve-{wid}"))
                    .spawn(move || worker_loop(wid, shared_w, factory));
                match spawned {
                    Ok(h) => Some(h),
                    Err(e) => {
                        // Degrade like a failed scorer build: the slot
                        // counts as started-but-never-ready so
                        // wait_ready()/session() don't block on it, and
                        // the failure surfaces in the report.
                        shared.push_failure(format!("worker {wid} thread spawn failed: {e}"));
                        let mut st = shared.state.lock().unwrap();
                        st.started += 1;
                        drop(st);
                        shared.state_cv.notify_all();
                        if shared.no_capacity_left() {
                            shared.drain_with_errors(&ResponseError::WorkerFailure(
                                "no serving workers available".to_string(),
                            ));
                        }
                        None
                    }
                }
            })
            .collect();
        WorkerRuntime { shared, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Block until every worker's scorer build has resolved; returns how
    /// many workers ever came up successfully (a worker that built and
    /// later exited still counts — this measures build success, not
    /// current liveness).
    pub fn wait_ready(&self) -> usize {
        let mut st = self.shared.state.lock().unwrap();
        while st.started < self.workers {
            st = self.shared.state_cv.wait(st).unwrap();
        }
        st.ready
    }

    /// Artifact-cache counter movement since this runtime was created,
    /// counted on this runtime's own worker threads — concurrent
    /// runtimes/pipelines in the same process do **not** show up here.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache_sink.stats()
    }

    /// CPU kernel-path counter movement since this runtime was created
    /// (same per-runtime thread attribution as
    /// [`WorkerRuntime::cache_stats`]).
    pub fn kernel_stats(&self) -> KernelPathStats {
        self.shared.kernel_sink.stats()
    }

    /// This runtime's prefix-reuse cache — reconfigure its geometry and
    /// byte budget with [`KvBlockCache::configure`] (budget 0 disables
    /// it), or flush it between workloads.
    pub fn kv_cache(&self) -> &KvBlockCache {
        &self.shared.kv
    }

    /// Prefix-cache counters since this runtime was created.
    pub fn kv_stats(&self) -> KvCacheStats {
        self.shared.kv.stats()
    }

    /// Workers currently alive (built a scorer and still in their loop).
    /// Unlike [`WorkerRuntime::wait_ready`]'s return this measures *now*:
    /// a worker that exited after repeated scoring failures no longer
    /// counts. The cluster router's primary health signal.
    pub fn live_workers(&self) -> usize {
        self.shared.state.lock().unwrap().running
    }

    /// Recorded worker failures (scorer-build errors, iteration
    /// failures), capped at a bounded tail — a rate-free badness signal
    /// for health scoring.
    pub fn failure_count(&self) -> usize {
        self.shared.failures.lock().unwrap().len()
    }

    /// Successful decode iterations across all workers since the runtime
    /// was created — the batch-iteration liveness heartbeat: a runtime
    /// whose threads are up but not advancing stops moving this.
    pub fn iterations(&self) -> u64 {
        self.shared.iterations.load(Ordering::Relaxed)
    }

    /// Swap the *default* serving weights (e.g. a quantized variant).
    /// Cheap: an `Arc` store plus a generation bump; workers apply it
    /// before their next batch, nothing recompiles, no weights are
    /// copied per worker. Takes `&mut self` so a swap cannot race an
    /// open session.
    pub fn set_params(&mut self, params: &ParamStore) {
        self.set_params_shared(Arc::new(params.clone()));
    }

    /// Zero-copy variant of [`WorkerRuntime::set_params`]. Cached prefix
    /// scores for the default variant are invalidated — they were
    /// computed under the old weights.
    pub fn set_params_shared(&mut self, params: Arc<ParamStore>) {
        let mut p = self.shared.params.lock().unwrap();
        *p = params;
        drop(p);
        self.shared.params_gen.fetch_add(1, Ordering::SeqCst);
        self.shared.kv.invalidate(None);
    }

    /// Publish an additional parameter set under `id` for per-request
    /// A/B routing (`SubmitOptions::variant`). Same `Arc` + generation
    /// handoff as [`WorkerRuntime::set_params`]: workers apply the
    /// variant map before each batch, nothing recompiles. Re-registering
    /// an id swaps that variant's weights. Takes `&mut self` so a swap
    /// cannot race an open session.
    pub fn register_variant(&mut self, id: impl Into<String>, params: Arc<ParamStore>) {
        let id = id.into();
        self.shared.kv.invalidate(Some(&id));
        self.shared.variants.lock().unwrap().insert(id, params);
        self.shared.params_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Registered variant ids, sorted.
    pub fn variant_ids(&self) -> Vec<String> {
        self.shared.variants.lock().unwrap().keys().cloned().collect()
    }

    pub fn has_variant(&self, id: &str) -> bool {
        self.shared.has_variant(id)
    }

    /// Open a [`ServeSession`]. Blocks until at least one worker is up
    /// (the cold-start path — folded into the session's `setup_ms`, not
    /// request latency); errs only when no worker ever became ready.
    pub fn session(&self, opt: SessionOptions) -> Result<ServeSession<'_>> {
        let opened = Instant::now();
        let ready = {
            let mut st = self.shared.state.lock().unwrap();
            while st.ready == 0 && st.started < self.workers {
                st = self.shared.state_cv.wait(st).unwrap();
            }
            st.ready
        };
        if ready == 0 {
            bail!("no serving workers available: {}", self.shared.failure_summary());
        }
        let ctx = Arc::new(SessionCtx {
            metrics: Metrics::new(),
            begin: Mutex::new(None),
            max_batch: opt.max_batch.max(1),
            decode_chunk: opt.decode_chunk,
            queue_cap: opt.queue_cap,
            admission: opt.admission,
            queued: Mutex::new(0),
            space_cv: Condvar::new(),
        });
        let mut session = ServeSession {
            runtime: self,
            ctx,
            opened,
            open_mark: StatsMark::zero(opened),
            drain_mark: StatsMark::zero(opened),
        };
        let mark = session.mark();
        session.open_mark = mark;
        session.drain_mark = mark;
        Ok(session)
    }

}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Anything still queued (tickets outliving their session) must
        // resolve: workers exited without popping these.
        self.shared.drain_with_errors(&ResponseError::Shutdown);
    }
}

/// Handle for one submitted request: a stream of [`TokenEvent`]s ending
/// in exactly one terminal event. [`Ticket::recv`] keeps the classic
/// resolve-to-final-[`Response`] contract by draining the stream;
/// [`Ticket::next_event`] / [`Ticket::events`] consume it token by
/// token.
pub struct Ticket {
    rx: mpsc::Receiver<TokenEvent>,
    cancelled: Arc<AtomicBool>,
    shared: Arc<Shared>,
    ctx: Arc<SessionCtx>,
    submitted: Instant,
    variant: Option<String>,
    /// Set once a terminal event has been handed out (or synthesized on
    /// disconnect): the stream then yields `None` forever.
    terminated: std::cell::Cell<bool>,
}

impl Ticket {
    fn failed_response(&self, err: ResponseError) -> Response {
        let mut r = Response::failed(err, self.submitted);
        r.variant = self.variant.clone();
        r
    }

    /// Block for the next event. Yields each `Token` in position order,
    /// then the single terminal `Done`/`Error`, then `None`. A worker
    /// side vanishing without a terminal event (runtime dropped)
    /// synthesizes `Error(Shutdown)` exactly once.
    pub fn next_event(&self) -> Option<TokenEvent> {
        if self.terminated.get() {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => {
                if ev.is_terminal() {
                    self.terminated.set(true);
                }
                Some(ev)
            }
            Err(_) => {
                self.terminated.set(true);
                Some(TokenEvent::Error(ResponseError::Shutdown))
            }
        }
    }

    /// Non-blocking [`Ticket::next_event`]: `None` when no event is
    /// ready yet *or* the stream already terminated.
    pub fn try_next_event(&self) -> Option<TokenEvent> {
        if self.terminated.get() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => {
                if ev.is_terminal() {
                    self.terminated.set(true);
                }
                Some(ev)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.terminated.set(true);
                Some(TokenEvent::Error(ResponseError::Shutdown))
            }
        }
    }

    /// Consume the ticket as a blocking event iterator (ends after the
    /// terminal event).
    pub fn events(self) -> TokenEvents {
        TokenEvents { ticket: self }
    }

    /// Block until the request resolves, discarding streamed tokens:
    /// the final [`Response`] on `Done`, or an error response carrying
    /// the terminal [`ResponseError`].
    pub fn recv(self) -> Response {
        loop {
            match self.next_event() {
                Some(TokenEvent::Done(r)) => return r,
                Some(TokenEvent::Error(e)) => return self.failed_response(e),
                Some(TokenEvent::Token { .. }) => continue,
                None => return self.failed_response(ResponseError::Shutdown),
            }
        }
    }

    /// Non-blocking poll for the *final* response: `None` while the
    /// request is still in flight (streamed tokens are drained and
    /// discarded — use [`Ticket::try_next_event`] to observe them).
    pub fn try_recv(&self) -> Option<Response> {
        loop {
            match self.try_next_event() {
                Some(TokenEvent::Done(r)) => return Some(r),
                Some(TokenEvent::Error(e)) => return Some(self.failed_response(e)),
                Some(TokenEvent::Token { .. }) => continue,
                None => return None,
            }
        }
    }

    /// Best-effort cancellation. Returns `true` when the request was
    /// still queued and resolved to [`ResponseError::Cancelled`] right
    /// here; `false` when a worker had already popped it — it then
    /// either resolves `Cancelled` at batch formation (flag observed) or
    /// completes normally.
    pub fn cancel(&self) -> bool {
        self.cancelled.store(true, Ordering::SeqCst);
        let victims = self
            .shared
            .queue
            .remove_where(|j: &Job| Arc::ptr_eq(&j.cancelled, &self.cancelled), 1);
        let removed = !victims.is_empty();
        for job in victims {
            self.ctx.note_dequeued(1);
            job.finish_error(ResponseError::Cancelled);
        }
        removed
    }

    /// When this request was submitted.
    pub fn submitted_at(&self) -> Instant {
        self.submitted
    }
}

/// Blocking event iterator over a [`Ticket`] (see [`Ticket::events`]):
/// yields every `Token`, then the terminal event, then ends.
pub struct TokenEvents {
    ticket: Ticket,
}

impl Iterator for TokenEvents {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        self.ticket.next_event()
    }
}

impl TokenEvents {
    /// The underlying ticket (e.g. to cancel mid-iteration).
    pub fn ticket(&self) -> &Ticket {
        &self.ticket
    }
}

/// Cumulative + per-drain serving statistics for one [`ServeSession`]
/// (counter deltas against a watermark; see [`ServeSession::stats`] /
/// [`ServeSession::drain_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Tickets created (submit-time rejections are *not* included — see
    /// `rejected`).
    pub submitted: u64,
    /// Requests answered with a real score.
    pub served: u64,
    /// Worker-failure / shutdown error replies.
    pub failed: u64,
    /// Deadline-expired error replies.
    pub expired: u64,
    /// Cancelled error replies.
    pub cancelled: u64,
    /// Tickets shed under [`AdmissionPolicy::ShedOldest`].
    pub shed: u64,
    /// Submits refused with [`SubmitError::QueueFull`] (no ticket).
    pub rejected: u64,
    /// Requests pushed back after a worker failed mid-iteration.
    pub requeued: u64,
    /// Decode iterations scored for this session (each covers up to
    /// `max_batch` requests × `decode_chunk` positions).
    pub batches: u64,
    /// Variant changes applied by workers for this session's batches.
    pub variant_swaps: u64,
    /// Token events streamed to this session's tickets (cached replays
    /// included).
    pub tokens_streamed: u64,
    /// Streamed positions replayed from the prefix-reuse cache.
    pub cached_tokens: u64,
    /// This session's requests waiting in the runtime queue right now.
    pub in_queue: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    /// Latency to first streamed token, p50/p95 over this window.
    pub first_token_p50_ms: f64,
    pub first_token_p95_ms: f64,
    /// Peak runtime-queue depth observed when this session's batches
    /// were formed.
    pub max_queue_depth: usize,
    /// Wall-clock covered by this snapshot.
    pub window_secs: f64,
    pub throughput_rps: f64,
    /// Artifact-cache movement in this window (per-runtime attribution).
    pub cache: CacheStats,
    /// Kernel-path movement in this window (per-runtime attribution).
    pub kernel_paths: KernelPathStats,
    /// Prefix-reuse cache movement in this window (counter deltas;
    /// residency gauges are end-of-window). The cache is per-runtime, so
    /// with several concurrent sessions this window sees their combined
    /// traffic — `cached_tokens` above is the session-local view.
    pub kv: KvCacheStats,
}

impl SessionStats {
    /// Tickets that have resolved (scored or error-replied).
    pub fn resolved(&self) -> u64 {
        self.served + self.failed + self.expired + self.cancelled + self.shed
    }

    /// Tickets still in flight (queued or being scored).
    pub fn outstanding(&self) -> u64 {
        self.submitted.saturating_sub(self.resolved())
    }

    /// All error replies (the compat `ServerReport::failed` rollup).
    pub fn error_replies(&self) -> u64 {
        self.failed + self.expired + self.cancelled + self.shed
    }
}

/// Counter watermark for cumulative-vs-drain snapshots.
#[derive(Clone, Copy, Debug)]
struct StatsMark {
    at: Instant,
    lat_len: usize,
    depth_len: usize,
    ft_len: usize,
    counters: CounterMark,
    cache: CacheStats,
    kernel: KernelPathStats,
    kv: KvCacheStats,
}

impl StatsMark {
    fn zero(at: Instant) -> StatsMark {
        StatsMark {
            at,
            lat_len: 0,
            depth_len: 0,
            ft_len: 0,
            counters: CounterMark::default(),
            cache: CacheStats::default(),
            kernel: KernelPathStats::default(),
            kv: KvCacheStats::default(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct CounterMark {
    submitted: u64,
    served: u64,
    failed: u64,
    expired: u64,
    cancelled: u64,
    shed: u64,
    rejected: u64,
    requeued: u64,
    batches: u64,
    variant_swaps: u64,
    tokens_streamed: u64,
    cached_tokens: u64,
}

impl CounterMark {
    fn read(m: &Metrics) -> CounterMark {
        CounterMark {
            submitted: m.counter("submitted"),
            served: m.counter("served"),
            failed: m.counter("failed"),
            expired: m.counter("expired"),
            cancelled: m.counter("cancelled"),
            shed: m.counter("shed"),
            rejected: m.counter("rejected"),
            requeued: m.counter("requeued"),
            batches: m.counter("batches"),
            variant_swaps: m.counter("variant_swaps"),
            tokens_streamed: m.counter("tokens_streamed"),
            cached_tokens: m.counter("cached_tokens"),
        }
    }
}

/// Decode state carried into [`ServeSession::submit_resume`]: everything
/// a request's previous runtime already emitted, so the new runtime
/// resumes at `vals.len()` instead of re-decoding (and re-streaming)
/// the prefix. `vals` must hold every emitted value — cached and fresh,
/// in index order — because the prefix-cache insert at completion
/// publishes the full row; a truncated vector would poison the cache.
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    /// Every NLL value emitted so far, index order (cached + fresh).
    pub vals: Vec<f32>,
    /// How many of `vals` were replayed from a prefix cache.
    pub cached_tokens: usize,
}

/// A client's handle on the runtime: streaming submits, bounded
/// admission, and cumulative/per-drain statistics. Sessions borrow the
/// runtime, so the runtime (and its workers) outlive every session;
/// tickets may outlive the session that created them.
pub struct ServeSession<'rt> {
    runtime: &'rt WorkerRuntime,
    ctx: Arc<SessionCtx>,
    opened: Instant,
    open_mark: StatsMark,
    drain_mark: StatsMark,
}

impl ServeSession<'_> {
    /// Enqueue one request under this session's admission policy.
    /// Returns a [`Ticket`] that always resolves, or a typed
    /// [`SubmitError`] when the request was never admitted.
    pub fn submit(&self, tokens: Vec<u32>, opt: SubmitOptions) -> Result<Ticket, SubmitError> {
        self.submit_inner(tokens, opt, None)
    }

    /// Enqueue a request that already streamed part of its decode on
    /// another runtime (cluster migration). The job enters at
    /// `resume.vals.len()`: no token is re-emitted, the prefix-cache
    /// replay in `admit` is structurally skipped (`pos > 0`), and the
    /// queue placement uses the retry rank so the migrant re-enters at
    /// the front of its (priority, deadline) class instead of paying the
    /// queue again.
    pub fn submit_resume(
        &self,
        tokens: Vec<u32>,
        opt: SubmitOptions,
        resume: ResumeState,
    ) -> Result<Ticket, SubmitError> {
        self.submit_inner(tokens, opt, Some(resume))
    }

    fn submit_inner(
        &self,
        tokens: Vec<u32>,
        opt: SubmitOptions,
        resume: Option<ResumeState>,
    ) -> Result<Ticket, SubmitError> {
        let shared = &self.runtime.shared;
        if let Some(v) = &opt.variant {
            if !shared.has_variant(v) {
                return Err(SubmitError::UnknownVariant(v.clone()));
            }
        }

        // Non-positive priorities clamp to the FIFO class: the queue
        // then only ever holds priorities >= 0, which keeps the plain
        // append below exactly equivalent to a ranked insert for
        // priority-0 requests (no O(queue) scan on the FIFO fast path).
        let priority = opt.priority.max(0);

        // Admission under the session's queued-count lock (lock order:
        // ctx.queued -> queue; workers take them in sequence, never
        // nested the other way).
        let cap = self.ctx.queue_cap;
        {
            let mut queued = self.ctx.queued.lock().unwrap();
            if cap > 0 && *queued >= cap {
                match self.ctx.admission {
                    AdmissionPolicy::Reject => {
                        self.ctx.metrics.incr("rejected", 1);
                        return Err(SubmitError::QueueFull { cap });
                    }
                    AdmissionPolicy::Block => {
                        while *queued >= cap {
                            queued = self.ctx.space_cv.wait(queued).unwrap();
                        }
                    }
                    AdmissionPolicy::ShedOldest => {
                        while *queued >= cap {
                            // Victim: this session's lowest-priority
                            // queued request, oldest within that level —
                            // but never one outranking the newcomer (a
                            // flood of low-priority submits must not
                            // evict admitted high-priority work).
                            let victim = shared.queue.remove_best_where(
                                |j: &Job| {
                                    Arc::ptr_eq(&j.call, &self.ctx) && j.priority <= priority
                                },
                                |cand, best| cand.priority < best.priority,
                            );
                            if let Some(job) = victim {
                                *queued = queued.saturating_sub(1);
                                job.finish_error(ResponseError::QueueFull);
                                continue;
                            }
                            let queued_here = shared
                                .queue
                                .count_where(|j: &Job| Arc::ptr_eq(&j.call, &self.ctx));
                            if queued_here > 0 {
                                // Everything queued outranks the
                                // newcomer: the newcomer is the shed
                                // victim itself, refused at submit time.
                                self.ctx.metrics.incr("rejected", 1);
                                return Err(SubmitError::QueueFull { cap });
                            }
                            // Raced with a worker mid-pop: its
                            // note_dequeued will free space.
                            queued = self.ctx.space_cv.wait(queued).unwrap();
                        }
                    }
                }
            }
            *queued += 1;
            self.ctx.metrics.incr("submitted", 1);
        }

        let now = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        let (rtx, rrx) = mpsc::channel();
        let variant = opt.variant.clone();
        let resumed = resume.is_some();
        let (pos, nll_sum, vals, cached_tokens) = match resume {
            Some(r) => {
                let sum: f64 = r.vals.iter().map(|&v| v as f64).sum();
                (r.vals.len(), sum, r.vals, r.cached_tokens)
            }
            None => (0, 0.0, Vec::new(), 0),
        };
        let job = Job {
            tokens,
            reply: rtx,
            enqueued: now,
            deadline: opt.deadline.and_then(|d| now.checked_add(d)),
            variant: opt.variant,
            priority,
            cancelled: Arc::clone(&cancelled),
            attempts: 0,
            call: Arc::clone(&self.ctx),
            pos,
            nll_sum,
            vals,
            cached_tokens,
            started: None,
            first_token_ms: None,
        };
        // EDF placement. Deadline-less priority-0 requests rank last of
        // the last class, so a plain append is exactly the ranked insert
        // without the O(queue) scan (the clamp above keeps the queue
        // free of negative priorities). Migrated requests use the retry
        // rank — they already waited once.
        let pushed = if resumed {
            shared.queue.push_by(job, |a, b| {
                edf_retry_goes_before(a.priority, a.deadline, b.priority, b.deadline)
            })
        } else if priority == 0 && job.deadline.is_none() {
            shared.queue.push(job)
        } else {
            shared.queue.push_by(job, |a, b| {
                edf_goes_before(a.priority, a.deadline, b.priority, b.deadline)
            })
        };
        if pushed.is_err() {
            // Only Drop closes the queue; sessions borrow the runtime,
            // so this is a defensive path.
            self.ctx.note_dequeued(1);
            return Err(SubmitError::Shutdown);
        }
        // If the last worker exited between the session's capacity check
        // and this enqueue, nobody will pop: error-drain so the ticket
        // resolves.
        if shared.no_capacity_left() {
            shared.drain_with_errors(&ResponseError::WorkerFailure(
                "all serving workers exited".to_string(),
            ));
        }
        Ok(Ticket {
            rx: rrx,
            cancelled,
            shared: Arc::clone(shared),
            ctx: Arc::clone(&self.ctx),
            submitted: now,
            variant,
            terminated: std::cell::Cell::new(false),
        })
    }

    /// Resolve `tickets` in submission order (the 1:1 in-order reply
    /// contract of the old open-loop API, ticket-shaped).
    pub fn wait_all(&self, tickets: Vec<Ticket>) -> Vec<Response> {
        tickets.into_iter().map(|t| t.recv()).collect()
    }

    /// This session's requests currently waiting in the runtime queue.
    pub fn queue_depth(&self) -> usize {
        *self.ctx.queued.lock().unwrap()
    }

    /// Cumulative statistics since the session opened. Counters cover
    /// the whole session lifetime; the percentile/peak fields cover the
    /// samples retained since the last [`ServeSession::drain_stats`]
    /// compaction (a session that never drains retains everything).
    pub fn stats(&self) -> SessionStats {
        self.stats_window(&self.open_mark, &self.mark())
    }

    /// Statistics for the window since the previous `drain_stats` call
    /// (or since open) — the per-drain snapshot for round-based callers.
    /// The window closes at a single end-snapshot, so samples recorded
    /// concurrently land in the *next* drain rather than vanishing.
    /// Consumed samples are then compacted away so an
    /// indefinitely-streaming session holds a bounded sample history
    /// (counters stay exact for the session's lifetime).
    pub fn drain_stats(&mut self) -> SessionStats {
        let mut mark = self.mark();
        let s = self.stats_window(&self.drain_mark, &mark);
        let m = &self.ctx.metrics;
        // Workers only *append* concurrently, so dropping exactly the
        // prefix captured in `mark` is race-free; both watermarks rebase
        // onto the truncated series.
        let dropped_lat = m.compact_series("request_total", mark.lat_len);
        let dropped_depth = m.compact_series("queue_depth", mark.depth_len);
        let dropped_ft = m.compact_series("first_token", mark.ft_len);
        m.compact_series("batch_exec", usize::MAX);
        mark.lat_len -= dropped_lat;
        mark.depth_len -= dropped_depth;
        mark.ft_len -= dropped_ft;
        self.open_mark.lat_len = self.open_mark.lat_len.saturating_sub(dropped_lat);
        self.open_mark.depth_len = self.open_mark.depth_len.saturating_sub(dropped_depth);
        self.open_mark.ft_len = self.open_mark.ft_len.saturating_sub(dropped_ft);
        self.drain_mark = mark;
        s
    }

    /// Compat [`ServerReport`] view of the cumulative session state
    /// (cache/kernel columns are runtime-lifetime, per-runtime
    /// attributed).
    pub fn report(&self) -> ServerReport {
        let s = self.stats();
        let begin = *self.ctx.begin.lock().unwrap();
        let secs = begin.map(|b| b.elapsed().as_secs_f64()).unwrap_or(f64::EPSILON);
        let setup_ms = begin
            .and_then(|b| b.checked_duration_since(self.opened))
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let cache = self.runtime.cache_stats();
        let ready_now = self.runtime.shared.state.lock().unwrap().running;
        ServerReport {
            served: s.served as usize,
            failed: s.error_replies() as usize,
            requeued: s.requeued as usize,
            batches: s.batches as usize,
            workers: self.runtime.workers,
            ready_workers: ready_now,
            p50_ms: s.p50_ms,
            p95_ms: s.p95_ms,
            throughput_rps: s.served as f64 / secs.max(f64::EPSILON),
            max_queue_depth: s.max_queue_depth,
            setup_ms,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            kernel_paths: self.runtime.kernel_stats(),
            kv: self.runtime.kv_stats(),
            first_token_p95_ms: s.first_token_p95_ms,
        }
    }

    fn mark(&self) -> StatsMark {
        let m = &self.ctx.metrics;
        StatsMark {
            at: Instant::now(),
            lat_len: m.series_len("request_total"),
            depth_len: m.series_len("queue_depth"),
            ft_len: m.series_len("first_token"),
            counters: CounterMark::read(m),
            cache: self.runtime.cache_stats(),
            kernel: self.runtime.kernel_stats(),
            kv: self.runtime.kv_stats(),
        }
    }

    /// Counter deltas and sample summaries over the half-open window
    /// `[from, to)` — both edges are coherent snapshots, so every sample
    /// lands in exactly one drain window.
    fn stats_window(&self, from: &StatsMark, to: &StatsMark) -> SessionStats {
        let m = &self.ctx.metrics;
        let c = &to.counters;
        let b = &from.counters;
        let (p50, p95, mean) = m
            .latency_summary_range("request_total", from.lat_len, to.lat_len)
            .unwrap_or((0.0, 0.0, 0.0));
        let (ft_p50, ft_p95, _) = m
            .latency_summary_range("first_token", from.ft_len, to.ft_len)
            .unwrap_or((0.0, 0.0, 0.0));
        let max_depth = m
            .series_max_range("queue_depth", from.depth_len, to.depth_len)
            .unwrap_or(0.0) as usize;
        let window = to.at.saturating_duration_since(from.at).as_secs_f64();
        let served = c.served.saturating_sub(b.served);
        SessionStats {
            submitted: c.submitted.saturating_sub(b.submitted),
            served,
            failed: c.failed.saturating_sub(b.failed),
            expired: c.expired.saturating_sub(b.expired),
            cancelled: c.cancelled.saturating_sub(b.cancelled),
            shed: c.shed.saturating_sub(b.shed),
            rejected: c.rejected.saturating_sub(b.rejected),
            requeued: c.requeued.saturating_sub(b.requeued),
            batches: c.batches.saturating_sub(b.batches),
            variant_swaps: c.variant_swaps.saturating_sub(b.variant_swaps),
            tokens_streamed: c.tokens_streamed.saturating_sub(b.tokens_streamed),
            cached_tokens: c.cached_tokens.saturating_sub(b.cached_tokens),
            in_queue: *self.ctx.queued.lock().unwrap(),
            p50_ms: p50,
            p95_ms: p95,
            mean_ms: mean,
            first_token_p50_ms: ft_p50,
            first_token_p95_ms: ft_p95,
            max_queue_depth: max_depth,
            window_secs: window,
            throughput_rps: served as f64 / window.max(f64::EPSILON),
            cache: to.cache.delta_from(from.cache),
            kernel_paths: to.kernel.delta_from(from.kernel),
            kv: to.kv.delta_from(from.kv),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_policy_names_round_trip() {
        for p in [AdmissionPolicy::Block, AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
            assert_eq!(AdmissionPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::from_name("shed"), Some(AdmissionPolicy::ShedOldest));
        assert_eq!(AdmissionPolicy::from_name("nope"), None);
    }

    #[test]
    fn response_error_counters_map_outcomes() {
        assert_eq!(ResponseError::WorkerFailure("x".into()).counter(), "failed");
        assert_eq!(ResponseError::Shutdown.counter(), "failed");
        assert_eq!(ResponseError::DeadlineExceeded.counter(), "expired");
        assert_eq!(ResponseError::Cancelled.counter(), "cancelled");
        assert_eq!(ResponseError::QueueFull.counter(), "shed");
    }

    #[test]
    fn submit_error_converts_to_response_error() {
        assert_eq!(
            ResponseError::from(SubmitError::QueueFull { cap: 4 }),
            ResponseError::QueueFull
        );
        assert_eq!(ResponseError::from(SubmitError::Shutdown), ResponseError::Shutdown);
        assert!(matches!(
            ResponseError::from(SubmitError::UnknownVariant("w2".into())),
            ResponseError::WorkerFailure(_)
        ));
    }

    #[test]
    fn session_options_default_is_unbounded_block() {
        let o = SessionOptions::default();
        assert_eq!(o.max_batch, 8);
        assert_eq!(o.queue_cap, 0);
        assert_eq!(o.admission, AdmissionPolicy::Block);
        assert_eq!(o.decode_chunk, 0);
    }

    #[test]
    fn options_builders_chain() {
        let o = SessionOptions::new()
            .max_batch(4)
            .queue_cap(64)
            .admission(AdmissionPolicy::ShedOldest)
            .decode_chunk(16);
        assert_eq!(o.max_batch, 4);
        assert_eq!(o.queue_cap, 64);
        assert_eq!(o.admission, AdmissionPolicy::ShedOldest);
        assert_eq!(o.decode_chunk, 16);
        let s = SubmitOptions::new()
            .deadline(Duration::from_millis(250))
            .variant("w2")
            .priority(3);
        assert_eq!(s.deadline, Some(Duration::from_millis(250)));
        assert_eq!(s.variant.as_deref(), Some("w2"));
        assert_eq!(s.priority, 3);
    }

    #[test]
    fn edf_ranks_priority_then_deadline() {
        let now = Instant::now();
        let soon = Some(now + Duration::from_millis(10));
        let late = Some(now + Duration::from_millis(500));
        // Priority dominates.
        assert!(edf_goes_before(1, None, 0, soon));
        assert!(!edf_goes_before(0, soon, 1, None));
        // Within a class: earlier deadline first; deadline beats none.
        assert!(edf_goes_before(0, soon, 0, late));
        assert!(!edf_goes_before(0, late, 0, soon));
        assert!(edf_goes_before(0, late, 0, None));
        assert!(!edf_goes_before(0, None, 0, late));
        // FIFO among equals (strict ordering: ties insert after).
        assert!(!edf_goes_before(0, None, 0, None));
        assert!(!edf_goes_before(0, soon, 0, soon));
        // Retry rank: ties insert *before* instead.
        assert!(edf_retry_goes_before(0, None, 0, None));
        assert!(edf_retry_goes_before(0, soon, 0, soon));
        assert!(edf_retry_goes_before(0, soon, 0, late));
        assert!(!edf_retry_goes_before(0, None, 0, late));
        assert!(!edf_retry_goes_before(0, late, 1, None));
    }

    /// Integration (needs artifacts): all requests answered through the
    /// session API, iterations amortize across requests.
    #[test]
    fn serves_all_requests() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let runtime = WorkerRuntime::new(&cfg, &params, 1);
        let session = runtime.session(SessionOptions::new().max_batch(8)).unwrap();
        let tickets: Vec<Ticket> = (0..13)
            .map(|i| {
                let tokens: Vec<u32> = (0..50u32).map(|t| (t * 3 + i) % 512).collect();
                session.submit(tokens, SubmitOptions::default()).unwrap()
            })
            .collect();
        let resps = session.wait_all(tickets);
        let s = session.stats();
        assert_eq!(resps.len(), 13);
        assert_eq!(s.served, 13);
        assert!(s.batches <= 13);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
        assert_eq!(s.tokens_streamed, 13 * 49);

        // Chunked decode streams token events ahead of the final
        // response, and the repeated prompt replays from the prefix
        // cache.
        let streaming = runtime.session(SessionOptions::new().decode_chunk(16)).unwrap();
        let tokens: Vec<u32> = (0..50u32).map(|t| (t * 3) % 512).collect();
        let events: Vec<TokenEvent> = streaming
            .submit(tokens.clone(), SubmitOptions::default())
            .unwrap()
            .events()
            .collect();
        assert_eq!(events.len(), 50, "49 token events + Done");
        assert!(matches!(events.last(), Some(TokenEvent::Done(r)) if r.is_ok()));
        let replay = streaming.submit(tokens, SubmitOptions::default()).unwrap().recv();
        assert!(replay.cached_tokens > 0, "second pass should hit the prefix cache");
    }

    /// Multi-worker drain (needs artifacts): same answers, all served —
    /// through the session API.
    #[test]
    fn multi_worker_serves_all() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let runtime = WorkerRuntime::new(&cfg, &params, 3);
        let session = runtime
            .session(SessionOptions { max_batch: 4, ..SessionOptions::default() })
            .unwrap();
        let tickets: Vec<Ticket> = (0..17)
            .map(|i| {
                let tokens: Vec<u32> = (0..40u32).map(|t| (t * 5 + i) % 512).collect();
                session.submit(tokens, SubmitOptions::default()).unwrap()
            })
            .collect();
        let resps = session.wait_all(tickets);
        let s = session.stats();
        assert_eq!(resps.len(), 17);
        assert_eq!(s.served, 17);
        assert_eq!(s.submitted, 17);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }
}
