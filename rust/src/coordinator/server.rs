//! Session-based serving on a persistent worker runtime (the
//! edge-deployment story): long-lived model workers drain a shared
//! request queue in dynamic batches, score them through the fwd_nll
//! artifact, and report latency/throughput/queue-depth — while clients
//! talk to the runtime through [`ServeSession`]s.
//!
//! This is deliberately shaped like a miniature vLLM-style router front:
//! streaming enqueue + bounded admission + FIFO queue with priorities +
//! per-request deadlines — the coordination layer a quantized edge model
//! runs under.
//!
//! # The session API
//!
//! [`WorkerRuntime`] is the reusable substrate: worker threads are
//! spawned once, each builds its own [`Scorer`] (an `NllBatcher`, so PJRT
//! stays thread-confined and each thread's engine compile-cache stays
//! warm). Clients open a [`ServeSession`] and stream requests in:
//!
//! ```text
//! let mut runtime = WorkerRuntime::new(&cfg, &params, workers);
//! runtime.register_variant("w2", Arc::new(q2_params));
//! let session = runtime.session(SessionOptions::default())?;
//! let t = session.submit(tokens, SubmitOptions::default())?;   // Ticket
//! let response = t.recv();                                     // Response
//! let stats = session.stats();                                 // SessionStats
//! ```
//!
//! * **Streaming enqueue** — [`ServeSession::submit`] hands back a
//!   [`Ticket`] immediately; requests interleave with result collection
//!   ([`Ticket::recv`] / [`Ticket::try_recv`] /
//!   [`ServeSession::wait_all`]). No more all-at-once `Vec<Vec<u32>>`.
//! * **Bounded admission** — `SessionOptions { queue_cap, admission }`
//!   bounds how many of the session's requests may wait in the runtime
//!   queue: [`AdmissionPolicy::Block`] applies back-pressure,
//!   [`AdmissionPolicy::Reject`] refuses with
//!   [`SubmitError::QueueFull`], [`AdmissionPolicy::ShedOldest`] drops
//!   the session's lowest-priority, oldest queued request (its ticket
//!   resolves with [`ResponseError::QueueFull`]) to admit the new one.
//! * **Deadlines + cancellation** — `SubmitOptions { deadline, .. }`
//!   expires lazily at batch-formation time (a typed
//!   [`ResponseError::DeadlineExceeded`], no scoring spent);
//!   [`Ticket::cancel`] removes a still-queued request eagerly.
//! * **Multi-variant A/B routing** — [`WorkerRuntime::register_variant`]
//!   publishes additional parameter sets (quantized variants) on the
//!   same warm runtime; `SubmitOptions { variant, .. }` routes each
//!   request. Batches never mix variants, and workers apply the
//!   generation-bumped variant map before each batch — the same `Arc`
//!   handoff as [`WorkerRuntime::set_params`], so an FP16↔2/3/4-bit A/B
//!   comparison shares one set of compiled artifacts.
//!
//! **Reply contract:** every submitted [`Ticket`] resolves — with a
//! score, or with a typed [`ResponseError`] — and
//! [`ServeSession::wait_all`] returns responses in submission order. A
//! worker that fails mid-batch re-queues the popped requests for the
//! surviving workers (`requeued` in [`SessionStats`]); requests that
//! exhaust their retry budget, or drain after the last worker exits, get
//! an error [`Response`] rather than being silently dropped.
//!
//! The pre-session entry points ([`WorkerRuntime::serve`], [`serve`],
//! [`serve_batch`]) remain as deprecated thin shims over a session.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::eval::ppl::NllBatcher;
use crate::kernels::{self, KernelPathSink, KernelPathStats};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::cache::{self as runtime_cache, CacheCounterSink, CacheStats};
use crate::util::{pool, TaskQueue};

use super::metrics::Metrics;

/// Retries a request gets after batch-scoring failures before it is
/// error-replied.
const MAX_ATTEMPTS: u32 = 3;
/// Consecutive scoring failures after which a worker assumes its scorer
/// is broken and exits (its batches re-queue onto surviving workers).
const MAX_CONSECUTIVE_FAILURES: u32 = 2;
/// Failure messages kept for diagnostics (older entries are dropped).
const MAX_RECORDED_FAILURES: usize = 32;

/// Why a request resolved without a score. Every variant maps 1:1 onto a
/// serving outcome, so callers can branch without string matching.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResponseError {
    /// Scoring failed (retry budget exhausted, every worker exited, or a
    /// scorer build/batch error); the message carries the diagnostics.
    WorkerFailure(String),
    /// The request's deadline passed before a worker picked it up
    /// (expiry is checked lazily at batch-formation time).
    DeadlineExceeded,
    /// [`Ticket::cancel`] resolved the request before scoring.
    Cancelled,
    /// The request was shed from a full queue
    /// ([`AdmissionPolicy::ShedOldest`]).
    QueueFull,
    /// The runtime shut down with the request still unresolved.
    Shutdown,
}

impl ResponseError {
    /// Session counter this outcome lands in.
    fn counter(&self) -> &'static str {
        match self {
            ResponseError::WorkerFailure(_) | ResponseError::Shutdown => "failed",
            ResponseError::DeadlineExceeded => "expired",
            ResponseError::Cancelled => "cancelled",
            ResponseError::QueueFull => "shed",
        }
    }
}

impl std::fmt::Display for ResponseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResponseError::WorkerFailure(msg) => write!(f, "worker failure: {msg}"),
            ResponseError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ResponseError::Cancelled => write!(f, "cancelled"),
            ResponseError::QueueFull => write!(f, "shed from full queue"),
            ResponseError::Shutdown => write!(f, "runtime shut down"),
        }
    }
}

impl std::error::Error for ResponseError {}

/// Why [`ServeSession::submit`] refused a request (no [`Ticket`] was
/// created; nothing entered the queue).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The session's queue is at capacity under
    /// [`AdmissionPolicy::Reject`].
    QueueFull { cap: usize },
    /// `SubmitOptions::variant` names an id that was never registered.
    UnknownVariant(String),
    /// The runtime's queue closed (shutdown race).
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => {
                write!(f, "session queue full (capacity {cap})")
            }
            SubmitError::UnknownVariant(id) => write!(f, "unknown variant {id:?}"),
            SubmitError::Shutdown => write!(f, "runtime shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<SubmitError> for ResponseError {
    fn from(e: SubmitError) -> ResponseError {
        match e {
            SubmitError::QueueFull { .. } => ResponseError::QueueFull,
            SubmitError::UnknownVariant(id) => {
                ResponseError::WorkerFailure(format!("unknown variant {id:?}"))
            }
            SubmitError::Shutdown => ResponseError::Shutdown,
        }
    }
}

/// What happens when a submit finds the session's queue at capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the submitter until a slot frees (back-pressure).
    Block,
    /// Refuse the new request with [`SubmitError::QueueFull`].
    Reject,
    /// Drop the session's lowest-priority queued request — oldest within
    /// that priority level (typed [`ResponseError::QueueFull`] on its
    /// ticket) — and admit the new one. A newcomer outranked by
    /// everything queued is itself refused ([`SubmitError::QueueFull`])
    /// instead of evicting higher-priority work.
    ShedOldest,
}

impl AdmissionPolicy {
    pub fn from_name(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "block" => Some(AdmissionPolicy::Block),
            "reject" => Some(AdmissionPolicy::Reject),
            "shed" | "shed-oldest" | "shed_oldest" => Some(AdmissionPolicy::ShedOldest),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::ShedOldest => "shed-oldest",
        }
    }
}

/// Per-session knobs (see [`WorkerRuntime::session`]).
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// Dynamic batching window (max requests per scored batch).
    pub max_batch: usize,
    /// Max requests of this session waiting in the runtime queue;
    /// 0 = unbounded (in-flight batches don't count against it).
    pub queue_cap: usize,
    /// What `submit` does when the cap is reached.
    pub admission: AdmissionPolicy,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions { max_batch: 8, queue_cap: 0, admission: AdmissionPolicy::Block }
    }
}

/// Per-request knobs for [`ServeSession::submit`].
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Drop the request (typed [`ResponseError::DeadlineExceeded`]) if no
    /// worker picks it up within this budget from submission. Checked
    /// lazily at batch-formation time.
    pub deadline: Option<Duration>,
    /// Route to a registered parameter variant
    /// ([`WorkerRuntime::register_variant`]); `None` = the runtime's
    /// default parameters.
    pub variant: Option<String>,
    /// Queue priority: higher pops first, FIFO within a level. Default
    /// 0; non-positive values clamp to 0 (the FIFO class).
    pub priority: i32,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub mean_nll: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// Variant that scored (or would have scored) this request; `None`
    /// for the runtime's default parameters.
    pub variant: Option<String>,
    /// `Some(err)` when the request could not be scored. `mean_nll` is
    /// NaN then.
    pub error: Option<ResponseError>,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    fn failed(err: ResponseError, since: Instant) -> Response {
        Response {
            mean_nll: f32::NAN,
            queue_ms: 0.0,
            total_ms: since.elapsed().as_secs_f64() * 1e3,
            variant: None,
            error: Some(err),
        }
    }
}

/// Compat report shape for the deprecated open-loop entry points and CLI
/// summaries; [`SessionStats`] is the richer session-native view.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered with a real score.
    pub served: usize,
    /// Requests answered with an error [`Response`] of any kind (never
    /// dropped): worker failures, expiries, cancellations, sheds.
    pub failed: usize,
    /// Requests pushed back to the queue after a worker failed mid-batch.
    pub requeued: usize,
    pub batches: usize,
    /// Configured worker count (see [`ServerReport::ready_workers`] for
    /// how many actually built a scorer).
    pub workers: usize,
    /// Workers still alive when this report was taken.
    pub ready_workers: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
    /// Peak number of requests waiting when a batch was formed.
    pub max_queue_depth: usize,
    /// Time from session open until the first batch was picked up — the
    /// per-call setup cost (≈0 on a warm runtime; scorer build +
    /// artifact compile on a cold one).
    pub setup_ms: f64,
    /// Artifact-cache hits since this runtime was built. Counted on the
    /// runtime's own worker threads (see `runtime::cache::attach_thread_sink`),
    /// so concurrent runtimes/pipelines no longer pollute each other.
    pub cache_hits: u64,
    /// Artifact loads/compiles since this runtime was built (same
    /// per-runtime attribution as `cache_hits`). Stays flat across
    /// repeat sessions on a lone runtime: batchers and executables
    /// persist.
    pub cache_misses: u64,
    /// CPU dq_gemm traffic per kernel path (direct/panel/LUT calls with
    /// the LUT split into nibble/byte flavors, residual panel unpacks,
    /// LUT builds, and `lane_builds` — lazy planes→lanes conversions,
    /// 0 when weights were loaded from a lane-persisting `.lieq` v2
    /// archive) since this runtime was built — counted on the runtime's
    /// own worker threads. Zero when scoring runs entirely through PJRT
    /// artifacts.
    pub kernel_paths: KernelPathStats,
}

/// Serving knobs for the deprecated one-shot [`serve`]: batch window
/// width + model worker count.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    pub max_batch: usize,
    /// 0 = size from the process-wide thread configuration
    /// (`--threads` / `LIEQ_THREADS` / auto).
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 8, workers: 0 }
    }
}

/// What a serving worker runs per batch. The production impl wraps
/// [`NllBatcher`]; tests and benches inject synthetic scorers to
/// exercise the runtime (failure paths, param swaps) without artifacts.
pub trait Scorer {
    /// Per-token NLL rows, one per passage (row order = passage order).
    fn score(&mut self, passages: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;
    /// Swap in a new parameter set (quantized-variant handoff).
    fn set_params(&mut self, params: &Arc<ParamStore>);
}

/// Builds one [`Scorer`] per worker, *on the worker's own thread* (PJRT
/// engines are thread-confined). Receives the worker index and the
/// current shared parameters.
pub type ScorerFactory =
    Arc<dyn Fn(usize, &Arc<ParamStore>) -> Result<Box<dyn Scorer>> + Send + Sync>;

struct NllScorer {
    batcher: NllBatcher,
    mask: Vec<f32>,
}

impl Scorer for NllScorer {
    fn score(&mut self, passages: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.batcher.nll_rows(passages, &self.mask)
    }

    fn set_params(&mut self, params: &Arc<ParamStore>) {
        self.batcher.set_params_shared(Arc::clone(params));
    }
}

/// Per-session state shared by that session's jobs, the submitting
/// thread, and the workers scoring its batches.
struct SessionCtx {
    metrics: Metrics,
    /// First-batch pickup time: request latency/throughput are measured
    /// from `max(enqueued, begin)` so scorer/artifact setup is not
    /// billed to requests.
    begin: Mutex<Option<Instant>>,
    max_batch: usize,
    /// 0 = unbounded.
    queue_cap: usize,
    admission: AdmissionPolicy,
    /// This session's requests currently *waiting* in the runtime queue
    /// (in-flight batches excluded) — the quantity the admission cap
    /// bounds.
    queued: Mutex<usize>,
    /// Signalled whenever `queued` drops (pop/shed/cancel/drain), waking
    /// `Block`-policy submitters.
    space_cv: Condvar,
}

impl SessionCtx {
    fn note_dequeued(&self, n: usize) {
        let mut q = self.queued.lock().unwrap();
        *q = q.saturating_sub(n);
        drop(q);
        self.space_cv.notify_all();
    }

    fn note_requeued(&self) {
        *self.queued.lock().unwrap() += 1;
    }
}

/// One queued request.
struct Job {
    tokens: Vec<u32>,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
    deadline: Option<Instant>,
    variant: Option<String>,
    priority: i32,
    cancelled: Arc<AtomicBool>,
    attempts: u32,
    call: Arc<SessionCtx>,
}

impl Job {
    /// Resolve this request with a typed error: bump the matching
    /// session counter and send the reply (the 1:1 contract — a job
    /// never just disappears).
    fn resolve_error(self, err: ResponseError) {
        self.call.metrics.incr(err.counter(), 1);
        let _ = self.reply.send(Response {
            mean_nll: f32::NAN,
            queue_ms: 0.0,
            total_ms: self.enqueued.elapsed().as_secs_f64() * 1e3,
            variant: self.variant,
            error: Some(err),
        });
    }
}

struct WorkerState {
    /// Workers whose scorer build resolved (successfully or not).
    started: usize,
    /// Workers that built a scorer and are still running.
    running: usize,
    /// Workers that ever built a scorer successfully.
    ready: usize,
}

struct Shared {
    queue: TaskQueue<Job>,
    /// Default weights; bumping `params_gen` makes every worker re-apply
    /// its variant from here / `variants` before its next batch.
    params: Mutex<Arc<ParamStore>>,
    /// Registered A/B variants (id -> weights), routed per request.
    variants: Mutex<BTreeMap<String, Arc<ParamStore>>>,
    params_gen: AtomicU64,
    state: Mutex<WorkerState>,
    state_cv: Condvar,
    failures: Mutex<Vec<String>>,
    workers: usize,
    /// Per-runtime counter attribution: worker threads attach these at
    /// start, so cache/kernel traffic is billed to *this* runtime even
    /// with other runtimes or pipelines live in the process.
    cache_sink: Arc<CacheCounterSink>,
    kernel_sink: Arc<KernelPathSink>,
}

impl Shared {
    fn current_params(&self) -> (u64, Arc<ParamStore>) {
        let p = self.params.lock().unwrap();
        (self.params_gen.load(Ordering::SeqCst), Arc::clone(&p))
    }

    /// Parameters for a variant id (`None` = default), with the map
    /// generation observed *before* the lookup (a concurrent bump makes
    /// the worker re-apply next batch — never miss an update).
    fn params_for(&self, variant: Option<&str>) -> Option<(u64, Arc<ParamStore>)> {
        let gen = self.params_gen.load(Ordering::SeqCst);
        let params = match variant {
            None => Some(Arc::clone(&self.params.lock().unwrap())),
            Some(id) => self.variants.lock().unwrap().get(id).cloned(),
        };
        params.map(|p| (gen, p))
    }

    fn has_variant(&self, id: &str) -> bool {
        self.variants.lock().unwrap().contains_key(id)
    }

    fn push_failure(&self, msg: String) {
        log::warn!("serving: {msg}");
        let mut f = self.failures.lock().unwrap();
        // Keep the tail only: a long-lived runtime with a flaky scorer
        // must not accumulate one string per failed batch forever.
        if f.len() >= MAX_RECORDED_FAILURES {
            f.remove(0);
        }
        f.push(msg);
    }

    fn failure_summary(&self) -> String {
        let f = self.failures.lock().unwrap();
        if f.is_empty() {
            "unknown".to_string()
        } else {
            f.join("; ")
        }
    }

    /// True once no worker is running and none can still come up.
    fn no_capacity_left(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.started == self.workers && s.running == 0
    }

    /// Error-reply every queued job (all-workers-dead path), releasing
    /// each job's session-queue slot so blocked submitters wake.
    fn drain_with_errors(&self, err: &ResponseError) {
        for job in self.queue.drain() {
            job.call.note_dequeued(1);
            job.resolve_error(err.clone());
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "panic".to_string())
}

/// Decrements `running` (and error-drains the queue when the last worker
/// goes away) on *every* worker exit path, including unwinds from a
/// panicking `Scorer::set_params` or metrics call — without this,
/// submitted tickets could block forever on a reply that can no longer
/// come.
struct RunningGuard {
    shared: Arc<Shared>,
}

impl Drop for RunningGuard {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.running -= 1;
        drop(st);
        self.shared.state_cv.notify_all();
        if self.shared.no_capacity_left() {
            self.shared.drain_with_errors(&ResponseError::WorkerFailure(
                "all serving workers exited".to_string(),
            ));
        }
    }
}

fn worker_loop(wid: usize, shared: Arc<Shared>, factory: ScorerFactory) {
    // Per-runtime counter attribution (see `Shared::cache_sink`).
    runtime_cache::attach_thread_sink(&shared.cache_sink);
    kernels::attach_thread_sink(&shared.kernel_sink);

    let (mut local_gen, params) = shared.current_params();
    // A panicking factory must still resolve this worker's build —
    // otherwise session()/wait_ready() would wait on `started` forever.
    let built = catch_unwind(AssertUnwindSafe(|| factory(wid, &params)))
        .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer build panicked: {}", panic_msg(&*p))));
    let mut scorer = match built {
        Ok(s) => {
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            st.running += 1;
            st.ready += 1;
            drop(st);
            shared.state_cv.notify_all();
            s
        }
        Err(e) => {
            shared.push_failure(format!("worker {wid} scorer build failed: {e:#}"));
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            drop(st);
            shared.state_cv.notify_all();
            if shared.no_capacity_left() {
                shared.drain_with_errors(&ResponseError::WorkerFailure(
                    "no serving workers available".to_string(),
                ));
            }
            return;
        }
    };

    let _guard = RunningGuard { shared: Arc::clone(&shared) };
    // Variant whose parameters this worker's scorer currently holds
    // (`None` = the runtime default). The scorer was just built from the
    // default params.
    let mut applied_variant: Option<String> = None;
    let mut consecutive_failures = 0u32;
    while let Some((batch, depth)) = shared.queue.pop_batch(
        |first| first.call.max_batch,
        // Batches never span sessions (metrics/window are per-session)
        // or variants (one set_params per batch).
        |first, next| Arc::ptr_eq(&first.call, &next.call) && first.variant == next.variant,
    ) {
        let call = Arc::clone(&batch[0].call);
        call.note_dequeued(batch.len());

        // Lazy deadline/cancellation resolution at batch-formation time:
        // expired or cancelled requests reply a typed error and consume
        // no scoring.
        let now = Instant::now();
        let mut live: Vec<Job> = Vec::with_capacity(batch.len());
        for job in batch {
            if job.cancelled.load(Ordering::SeqCst) {
                job.resolve_error(ResponseError::Cancelled);
            } else if job.deadline.is_some_and(|d| d <= now) {
                job.resolve_error(ResponseError::DeadlineExceeded);
            } else {
                live.push(job);
            }
        }
        if live.is_empty() {
            continue;
        }

        // Param handoff: a pending set_params/register_variant bump, or
        // a batch routed to a different variant than the last one this
        // worker scored. One atomic load on the fast path.
        let want = live[0].variant.clone();
        if shared.params_gen.load(Ordering::SeqCst) != local_gen || applied_variant != want {
            match shared.params_for(want.as_deref()) {
                Some((gen, params)) => {
                    if applied_variant != want {
                        call.metrics.incr("variant_swaps", 1);
                    }
                    scorer.set_params(&params);
                    local_gen = gen;
                    applied_variant = want.clone();
                }
                None => {
                    // Unregistered id — submit validates, so this is a
                    // defensive path; resolve rather than hang.
                    let msg = format!("unknown variant {:?}", want.as_deref().unwrap_or(""));
                    for job in live {
                        job.resolve_error(ResponseError::WorkerFailure(msg.clone()));
                    }
                    continue;
                }
            }
        }

        call.begin.lock().unwrap().get_or_insert_with(Instant::now);
        call.metrics.observe("queue_depth", depth as f64);

        let t0 = Instant::now();
        let passages: Vec<Vec<u32>> = live.iter().map(|j| j.tokens.clone()).collect();
        let scored = catch_unwind(AssertUnwindSafe(|| scorer.score(&passages)))
            .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer panicked: {}", panic_msg(&*p))))
            .and_then(|rows| {
                // A short row vec would leave replies unsent; treat it as
                // a scoring failure so every job resolves.
                anyhow::ensure!(
                    rows.len() == live.len(),
                    "scorer returned {} rows for {} passages",
                    rows.len(),
                    live.len()
                );
                Ok(rows)
            });
        match scored {
            Ok(rows) => {
                consecutive_failures = 0;
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                call.metrics.observe_ms("batch_exec", exec_ms);
                call.metrics.incr("batches", 1);
                let begin = call.begin.lock().unwrap().unwrap_or(t0);
                for (job, row) in live.into_iter().zip(rows) {
                    let mean = row.iter().sum::<f32>() / row.len().max(1) as f32;
                    let t_in = job.enqueued.max(begin);
                    let total_ms = t_in.elapsed().as_secs_f64() * 1e3;
                    let queue_ms = (total_ms - exec_ms).max(0.0);
                    call.metrics.observe_ms("request_total", total_ms);
                    call.metrics.incr("served", 1);
                    let _ = job.reply.send(Response {
                        mean_nll: mean,
                        queue_ms,
                        total_ms,
                        variant: job.variant.clone(),
                        error: None,
                    });
                }
            }
            Err(e) => {
                consecutive_failures += 1;
                let msg = format!("{e:#}");
                shared.push_failure(format!("worker {wid} batch failed: {msg}"));
                // Re-queue at the front of each job's own priority band
                // (reverse order restores the batch's relative order):
                // retries go ahead of their class but never jump queued
                // higher-priority work. The shared queue is unbounded,
                // so the ranked insert cannot block this worker.
                for mut job in live.into_iter().rev() {
                    job.attempts += 1;
                    if job.attempts >= MAX_ATTEMPTS {
                        job.resolve_error(ResponseError::WorkerFailure(msg.clone()));
                    } else {
                        job.call.metrics.incr("requeued", 1);
                        job.call.note_requeued();
                        if let Err(job) =
                            shared.queue.push_by(job, |a, b| a.priority >= b.priority)
                        {
                            // Queue closed under us: reply, don't drop.
                            job.call.note_dequeued(1);
                            job.resolve_error(ResponseError::Shutdown);
                        }
                    }
                }
                if consecutive_failures >= MAX_CONSECUTIVE_FAILURES {
                    log::warn!(
                        "serving worker {wid}: {consecutive_failures} consecutive scoring \
                         failures, exiting"
                    );
                    break;
                }
            }
        }
    }

    // `_guard` drops here: running--, notify waiters, drain if last.
}

/// Persistent serving runtime: long-lived workers, each owning a
/// [`Scorer`] built on its own thread, shared weights behind an `Arc`, a
/// registered-variant map for A/B routing, and a FIFO+priority queue
/// with a dynamic batching window. Clients talk to it through
/// [`WorkerRuntime::session`]; see the module docs.
pub struct WorkerRuntime {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerRuntime {
    /// Production runtime: one [`NllBatcher`]-backed scorer per worker.
    /// Workers build eagerly in the background; the first session waits
    /// for capacity.
    pub fn new(cfg: &ModelConfig, params: &ParamStore, workers: usize) -> WorkerRuntime {
        let cfg = cfg.clone();
        let factory: ScorerFactory = Arc::new(move |_wid, params| {
            let batcher = NllBatcher::new_shared(&cfg, Arc::clone(params))?;
            let mask = vec![1.0f32; cfg.n_layers];
            Ok(Box::new(NllScorer { batcher, mask }) as Box<dyn Scorer>)
        });
        Self::with_scorer_factory(workers, Arc::new(params.clone()), factory)
    }

    /// Runtime with an injected scorer factory (tests, benches, custom
    /// model backends). `workers == 0` sizes from the process-wide thread
    /// configuration.
    pub fn with_scorer_factory(
        workers: usize,
        params: Arc<ParamStore>,
        factory: ScorerFactory,
    ) -> WorkerRuntime {
        let workers = if workers == 0 { pool::global_threads() } else { workers };
        let shared = Arc::new(Shared {
            queue: TaskQueue::new(),
            params: Mutex::new(params),
            variants: Mutex::new(BTreeMap::new()),
            params_gen: AtomicU64::new(0),
            state: Mutex::new(WorkerState { started: 0, running: 0, ready: 0 }),
            state_cv: Condvar::new(),
            failures: Mutex::new(Vec::new()),
            workers,
            cache_sink: Arc::new(CacheCounterSink::default()),
            kernel_sink: Arc::new(KernelPathSink::default()),
        });
        let handles = (0..workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                let factory = Arc::clone(&factory);
                std::thread::Builder::new()
                    .name(format!("lieq-serve-{wid}"))
                    .spawn(move || worker_loop(wid, shared, factory))
                    .expect("spawn serving worker")
            })
            .collect();
        WorkerRuntime { shared, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Block until every worker's scorer build has resolved; returns how
    /// many workers ever came up successfully (a worker that built and
    /// later exited still counts — this measures build success, not
    /// current liveness).
    pub fn wait_ready(&self) -> usize {
        let mut st = self.shared.state.lock().unwrap();
        while st.started < self.workers {
            st = self.shared.state_cv.wait(st).unwrap();
        }
        st.ready
    }

    /// Artifact-cache counter movement since this runtime was created,
    /// counted on this runtime's own worker threads — concurrent
    /// runtimes/pipelines in the same process do **not** show up here.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache_sink.stats()
    }

    /// CPU kernel-path counter movement since this runtime was created
    /// (same per-runtime thread attribution as
    /// [`WorkerRuntime::cache_stats`]).
    pub fn kernel_stats(&self) -> KernelPathStats {
        self.shared.kernel_sink.stats()
    }

    /// Swap the *default* serving weights (e.g. a quantized variant).
    /// Cheap: an `Arc` store plus a generation bump; workers apply it
    /// before their next batch, nothing recompiles, no weights are
    /// copied per worker. Takes `&mut self` so a swap cannot race an
    /// open session.
    pub fn set_params(&mut self, params: &ParamStore) {
        self.set_params_shared(Arc::new(params.clone()));
    }

    /// Zero-copy variant of [`WorkerRuntime::set_params`].
    pub fn set_params_shared(&mut self, params: Arc<ParamStore>) {
        let mut p = self.shared.params.lock().unwrap();
        *p = params;
        drop(p);
        self.shared.params_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Publish an additional parameter set under `id` for per-request
    /// A/B routing (`SubmitOptions::variant`). Same `Arc` + generation
    /// handoff as [`WorkerRuntime::set_params`]: workers apply the
    /// variant map before each batch, nothing recompiles. Re-registering
    /// an id swaps that variant's weights. Takes `&mut self` so a swap
    /// cannot race an open session.
    pub fn register_variant(&mut self, id: impl Into<String>, params: Arc<ParamStore>) {
        self.shared.variants.lock().unwrap().insert(id.into(), params);
        self.shared.params_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Registered variant ids, sorted.
    pub fn variant_ids(&self) -> Vec<String> {
        self.shared.variants.lock().unwrap().keys().cloned().collect()
    }

    pub fn has_variant(&self, id: &str) -> bool {
        self.shared.has_variant(id)
    }

    /// Open a [`ServeSession`]. Blocks until at least one worker is up
    /// (the cold-start path — folded into the session's `setup_ms`, not
    /// request latency); errs only when no worker ever became ready.
    pub fn session(&self, opt: SessionOptions) -> Result<ServeSession<'_>> {
        let opened = Instant::now();
        let ready = {
            let mut st = self.shared.state.lock().unwrap();
            while st.ready == 0 && st.started < self.workers {
                st = self.shared.state_cv.wait(st).unwrap();
            }
            st.ready
        };
        if ready == 0 {
            bail!("no serving workers available: {}", self.shared.failure_summary());
        }
        let ctx = Arc::new(SessionCtx {
            metrics: Metrics::new(),
            begin: Mutex::new(None),
            max_batch: opt.max_batch.max(1),
            queue_cap: opt.queue_cap,
            admission: opt.admission,
            queued: Mutex::new(0),
            space_cv: Condvar::new(),
        });
        let mut session = ServeSession {
            runtime: self,
            ctx,
            opened,
            open_mark: StatsMark::zero(opened),
            drain_mark: StatsMark::zero(opened),
        };
        let mark = session.mark();
        session.open_mark = mark;
        session.drain_mark = mark;
        Ok(session)
    }

    /// Serve `requests` open-loop through a one-shot session. Returns
    /// per-request responses **aligned 1:1, in request order** plus a
    /// report. Errs only when no worker ever became ready.
    #[deprecated(note = "use WorkerRuntime::session + ServeSession::submit")]
    pub fn serve(
        &self,
        requests: Vec<Vec<u32>>,
        max_batch: usize,
    ) -> Result<(Vec<Response>, ServerReport)> {
        let session = self.session(SessionOptions { max_batch, ..SessionOptions::default() })?;
        let opened = session.opened;
        let tickets: Vec<Result<Ticket, SubmitError>> = requests
            .into_iter()
            .map(|tokens| session.submit(tokens, SubmitOptions::default()))
            .collect();
        let responses: Vec<Response> = tickets
            .into_iter()
            .map(|t| match t {
                Ok(ticket) => ticket.recv(),
                // Unbounded default session: only a shutdown race lands
                // here; reply rather than drop so the vec stays 1:1.
                Err(e) => Response::failed(e.into(), opened),
            })
            .collect();
        let report = session.report();
        let m = &session.ctx.metrics;
        m.set_counter("compile_cache_hits", report.cache_hits);
        m.set_counter("compile_cache_misses", report.cache_misses);
        // The per-call Metrics registry (counters + latency series) is
        // observable via RUST_LOG.
        log::debug!("serve call metrics:\n{}", m.report());
        Ok((responses, report))
    }
}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Anything still queued (tickets outliving their session) must
        // resolve: workers exited without popping these.
        self.shared.drain_with_errors(&ResponseError::Shutdown);
    }
}

/// Handle for one submitted request: resolves exactly once to a
/// [`Response`] — a score or a typed [`ResponseError`].
pub struct Ticket {
    rx: mpsc::Receiver<Response>,
    cancelled: Arc<AtomicBool>,
    shared: Arc<Shared>,
    ctx: Arc<SessionCtx>,
    submitted: Instant,
}

impl Ticket {
    /// Block until the request resolves.
    pub fn recv(self) -> Response {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Response::failed(ResponseError::Shutdown, self.submitted),
        }
    }

    /// Non-blocking poll: `None` while the request is still in flight.
    /// A returned response consumes the resolution — a later
    /// [`Ticket::recv`] reports `Shutdown`.
    pub fn try_recv(&self) -> Option<Response> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Response::failed(ResponseError::Shutdown, self.submitted))
            }
        }
    }

    /// Best-effort cancellation. Returns `true` when the request was
    /// still queued and resolved to [`ResponseError::Cancelled`] right
    /// here; `false` when a worker had already popped it — it then
    /// either resolves `Cancelled` at batch formation (flag observed) or
    /// completes normally.
    pub fn cancel(&self) -> bool {
        self.cancelled.store(true, Ordering::SeqCst);
        let victims = self
            .shared
            .queue
            .remove_where(|j: &Job| Arc::ptr_eq(&j.cancelled, &self.cancelled), 1);
        let removed = !victims.is_empty();
        for job in victims {
            self.ctx.note_dequeued(1);
            job.resolve_error(ResponseError::Cancelled);
        }
        removed
    }

    /// When this request was submitted.
    pub fn submitted_at(&self) -> Instant {
        self.submitted
    }
}

/// Cumulative + per-drain serving statistics for one [`ServeSession`]
/// (counter deltas against a watermark; see [`ServeSession::stats`] /
/// [`ServeSession::drain_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// Tickets created (submit-time rejections are *not* included — see
    /// `rejected`).
    pub submitted: u64,
    /// Requests answered with a real score.
    pub served: u64,
    /// Worker-failure / shutdown error replies.
    pub failed: u64,
    /// Deadline-expired error replies.
    pub expired: u64,
    /// Cancelled error replies.
    pub cancelled: u64,
    /// Tickets shed under [`AdmissionPolicy::ShedOldest`].
    pub shed: u64,
    /// Submits refused with [`SubmitError::QueueFull`] (no ticket).
    pub rejected: u64,
    /// Requests pushed back after a worker failed mid-batch.
    pub requeued: u64,
    pub batches: u64,
    /// Variant changes applied by workers for this session's batches.
    pub variant_swaps: u64,
    /// This session's requests waiting in the runtime queue right now.
    pub in_queue: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    /// Peak runtime-queue depth observed when this session's batches
    /// were formed.
    pub max_queue_depth: usize,
    /// Wall-clock covered by this snapshot.
    pub window_secs: f64,
    pub throughput_rps: f64,
    /// Artifact-cache movement in this window (per-runtime attribution).
    pub cache: CacheStats,
    /// Kernel-path movement in this window (per-runtime attribution).
    pub kernel_paths: KernelPathStats,
}

impl SessionStats {
    /// Tickets that have resolved (scored or error-replied).
    pub fn resolved(&self) -> u64 {
        self.served + self.failed + self.expired + self.cancelled + self.shed
    }

    /// Tickets still in flight (queued or being scored).
    pub fn outstanding(&self) -> u64 {
        self.submitted.saturating_sub(self.resolved())
    }

    /// All error replies (the compat `ServerReport::failed` rollup).
    pub fn error_replies(&self) -> u64 {
        self.failed + self.expired + self.cancelled + self.shed
    }
}

/// Counter watermark for cumulative-vs-drain snapshots.
#[derive(Clone, Copy, Debug)]
struct StatsMark {
    at: Instant,
    lat_len: usize,
    depth_len: usize,
    counters: CounterMark,
    cache: CacheStats,
    kernel: KernelPathStats,
}

impl StatsMark {
    fn zero(at: Instant) -> StatsMark {
        StatsMark {
            at,
            lat_len: 0,
            depth_len: 0,
            counters: CounterMark::default(),
            cache: CacheStats::default(),
            kernel: KernelPathStats::default(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct CounterMark {
    submitted: u64,
    served: u64,
    failed: u64,
    expired: u64,
    cancelled: u64,
    shed: u64,
    rejected: u64,
    requeued: u64,
    batches: u64,
    variant_swaps: u64,
}

impl CounterMark {
    fn read(m: &Metrics) -> CounterMark {
        CounterMark {
            submitted: m.counter("submitted"),
            served: m.counter("served"),
            failed: m.counter("failed"),
            expired: m.counter("expired"),
            cancelled: m.counter("cancelled"),
            shed: m.counter("shed"),
            rejected: m.counter("rejected"),
            requeued: m.counter("requeued"),
            batches: m.counter("batches"),
            variant_swaps: m.counter("variant_swaps"),
        }
    }
}

/// A client's handle on the runtime: streaming submits, bounded
/// admission, and cumulative/per-drain statistics. Sessions borrow the
/// runtime, so the runtime (and its workers) outlive every session;
/// tickets may outlive the session that created them.
pub struct ServeSession<'rt> {
    runtime: &'rt WorkerRuntime,
    ctx: Arc<SessionCtx>,
    opened: Instant,
    open_mark: StatsMark,
    drain_mark: StatsMark,
}

impl ServeSession<'_> {
    /// Enqueue one request under this session's admission policy.
    /// Returns a [`Ticket`] that always resolves, or a typed
    /// [`SubmitError`] when the request was never admitted.
    pub fn submit(&self, tokens: Vec<u32>, opt: SubmitOptions) -> Result<Ticket, SubmitError> {
        let shared = &self.runtime.shared;
        if let Some(v) = &opt.variant {
            if !shared.has_variant(v) {
                return Err(SubmitError::UnknownVariant(v.clone()));
            }
        }

        // Non-positive priorities clamp to the FIFO class: the queue
        // then only ever holds priorities >= 0, which keeps the plain
        // append below exactly equivalent to a ranked insert for
        // priority-0 requests (no O(queue) scan on the FIFO fast path).
        let priority = opt.priority.max(0);

        // Admission under the session's queued-count lock (lock order:
        // ctx.queued -> queue; workers take them in sequence, never
        // nested the other way).
        let cap = self.ctx.queue_cap;
        {
            let mut queued = self.ctx.queued.lock().unwrap();
            if cap > 0 && *queued >= cap {
                match self.ctx.admission {
                    AdmissionPolicy::Reject => {
                        self.ctx.metrics.incr("rejected", 1);
                        return Err(SubmitError::QueueFull { cap });
                    }
                    AdmissionPolicy::Block => {
                        while *queued >= cap {
                            queued = self.ctx.space_cv.wait(queued).unwrap();
                        }
                    }
                    AdmissionPolicy::ShedOldest => {
                        while *queued >= cap {
                            // Victim: this session's lowest-priority
                            // queued request, oldest within that level —
                            // but never one outranking the newcomer (a
                            // flood of low-priority submits must not
                            // evict admitted high-priority work).
                            let victim = shared.queue.remove_best_where(
                                |j: &Job| {
                                    Arc::ptr_eq(&j.call, &self.ctx) && j.priority <= priority
                                },
                                |cand, best| cand.priority < best.priority,
                            );
                            if let Some(job) = victim {
                                *queued = queued.saturating_sub(1);
                                job.resolve_error(ResponseError::QueueFull);
                                continue;
                            }
                            let queued_here = shared
                                .queue
                                .count_where(|j: &Job| Arc::ptr_eq(&j.call, &self.ctx));
                            if queued_here > 0 {
                                // Everything queued outranks the
                                // newcomer: the newcomer is the shed
                                // victim itself, refused at submit time.
                                self.ctx.metrics.incr("rejected", 1);
                                return Err(SubmitError::QueueFull { cap });
                            }
                            // Raced with a worker mid-pop: its
                            // note_dequeued will free space.
                            queued = self.ctx.space_cv.wait(queued).unwrap();
                        }
                    }
                }
            }
            *queued += 1;
            self.ctx.metrics.incr("submitted", 1);
        }

        let now = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        let (rtx, rrx) = mpsc::channel();
        let job = Job {
            tokens,
            reply: rtx,
            enqueued: now,
            deadline: opt.deadline.and_then(|d| now.checked_add(d)),
            variant: opt.variant,
            priority,
            cancelled: Arc::clone(&cancelled),
            attempts: 0,
            call: Arc::clone(&self.ctx),
        };
        let pushed = if priority == 0 {
            shared.queue.push(job)
        } else {
            shared.queue.push_by(job, |a, b| a.priority > b.priority)
        };
        if pushed.is_err() {
            // Only Drop closes the queue; sessions borrow the runtime,
            // so this is a defensive path.
            self.ctx.note_dequeued(1);
            return Err(SubmitError::Shutdown);
        }
        // If the last worker exited between the session's capacity check
        // and this enqueue, nobody will pop: error-drain so the ticket
        // resolves.
        if shared.no_capacity_left() {
            shared.drain_with_errors(&ResponseError::WorkerFailure(
                "all serving workers exited".to_string(),
            ));
        }
        Ok(Ticket {
            rx: rrx,
            cancelled,
            shared: Arc::clone(shared),
            ctx: Arc::clone(&self.ctx),
            submitted: now,
        })
    }

    /// Resolve `tickets` in submission order (the 1:1 in-order reply
    /// contract of the old open-loop API, ticket-shaped).
    pub fn wait_all(&self, tickets: Vec<Ticket>) -> Vec<Response> {
        tickets.into_iter().map(|t| t.recv()).collect()
    }

    /// This session's requests currently waiting in the runtime queue.
    pub fn queue_depth(&self) -> usize {
        *self.ctx.queued.lock().unwrap()
    }

    /// Cumulative statistics since the session opened. Counters cover
    /// the whole session lifetime; the percentile/peak fields cover the
    /// samples retained since the last [`ServeSession::drain_stats`]
    /// compaction (a session that never drains retains everything).
    pub fn stats(&self) -> SessionStats {
        self.stats_window(&self.open_mark, &self.mark())
    }

    /// Statistics for the window since the previous `drain_stats` call
    /// (or since open) — the per-drain snapshot for round-based callers.
    /// The window closes at a single end-snapshot, so samples recorded
    /// concurrently land in the *next* drain rather than vanishing.
    /// Consumed samples are then compacted away so an
    /// indefinitely-streaming session holds a bounded sample history
    /// (counters stay exact for the session's lifetime).
    pub fn drain_stats(&mut self) -> SessionStats {
        let mut mark = self.mark();
        let s = self.stats_window(&self.drain_mark, &mark);
        let m = &self.ctx.metrics;
        // Workers only *append* concurrently, so dropping exactly the
        // prefix captured in `mark` is race-free; both watermarks rebase
        // onto the truncated series.
        let dropped_lat = m.compact_series("request_total", mark.lat_len);
        let dropped_depth = m.compact_series("queue_depth", mark.depth_len);
        m.compact_series("batch_exec", usize::MAX);
        mark.lat_len -= dropped_lat;
        mark.depth_len -= dropped_depth;
        self.open_mark.lat_len = self.open_mark.lat_len.saturating_sub(dropped_lat);
        self.open_mark.depth_len = self.open_mark.depth_len.saturating_sub(dropped_depth);
        self.drain_mark = mark;
        s
    }

    /// Compat [`ServerReport`] view of the cumulative session state
    /// (cache/kernel columns are runtime-lifetime, per-runtime
    /// attributed).
    pub fn report(&self) -> ServerReport {
        let s = self.stats();
        let begin = *self.ctx.begin.lock().unwrap();
        let secs = begin.map(|b| b.elapsed().as_secs_f64()).unwrap_or(f64::EPSILON);
        let setup_ms = begin
            .and_then(|b| b.checked_duration_since(self.opened))
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let cache = self.runtime.cache_stats();
        let ready_now = self.runtime.shared.state.lock().unwrap().running;
        ServerReport {
            served: s.served as usize,
            failed: s.error_replies() as usize,
            requeued: s.requeued as usize,
            batches: s.batches as usize,
            workers: self.runtime.workers,
            ready_workers: ready_now,
            p50_ms: s.p50_ms,
            p95_ms: s.p95_ms,
            throughput_rps: s.served as f64 / secs.max(f64::EPSILON),
            max_queue_depth: s.max_queue_depth,
            setup_ms,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            kernel_paths: self.runtime.kernel_stats(),
        }
    }

    fn mark(&self) -> StatsMark {
        let m = &self.ctx.metrics;
        StatsMark {
            at: Instant::now(),
            lat_len: m.series_len("request_total"),
            depth_len: m.series_len("queue_depth"),
            counters: CounterMark::read(m),
            cache: self.runtime.cache_stats(),
            kernel: self.runtime.kernel_stats(),
        }
    }

    /// Counter deltas and sample summaries over the half-open window
    /// `[from, to)` — both edges are coherent snapshots, so every sample
    /// lands in exactly one drain window.
    fn stats_window(&self, from: &StatsMark, to: &StatsMark) -> SessionStats {
        let m = &self.ctx.metrics;
        let c = &to.counters;
        let b = &from.counters;
        let (p50, p95, mean) = m
            .latency_summary_range("request_total", from.lat_len, to.lat_len)
            .unwrap_or((0.0, 0.0, 0.0));
        let max_depth = m
            .series_max_range("queue_depth", from.depth_len, to.depth_len)
            .unwrap_or(0.0) as usize;
        let window = to.at.saturating_duration_since(from.at).as_secs_f64();
        let served = c.served.saturating_sub(b.served);
        SessionStats {
            submitted: c.submitted.saturating_sub(b.submitted),
            served,
            failed: c.failed.saturating_sub(b.failed),
            expired: c.expired.saturating_sub(b.expired),
            cancelled: c.cancelled.saturating_sub(b.cancelled),
            shed: c.shed.saturating_sub(b.shed),
            rejected: c.rejected.saturating_sub(b.rejected),
            requeued: c.requeued.saturating_sub(b.requeued),
            batches: c.batches.saturating_sub(b.batches),
            variant_swaps: c.variant_swaps.saturating_sub(b.variant_swaps),
            in_queue: *self.ctx.queued.lock().unwrap(),
            p50_ms: p50,
            p95_ms: p95,
            mean_ms: mean,
            max_queue_depth: max_depth,
            window_secs: window,
            throughput_rps: served as f64 / window.max(f64::EPSILON),
            cache: to.cache.delta_from(from.cache),
            kernel_paths: to.kernel.delta_from(from.kernel),
        }
    }
}

/// Back-compat single-worker entry point (see [`serve`]).
#[deprecated(note = "use WorkerRuntime::session + ServeSession::submit")]
#[allow(deprecated)]
pub fn serve_batch(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    max_batch: usize,
) -> Result<(Vec<Response>, ServerReport)> {
    serve(cfg, params, requests, ServeOptions { max_batch, workers: 1 })
}

/// One-shot serving: build a [`WorkerRuntime`], serve, tear down. Callers
/// that serve repeatedly (or A/B quantized variants) should hold a
/// `WorkerRuntime` and open sessions instead — that is what makes setup
/// cost amortize.
#[deprecated(note = "use WorkerRuntime::session + ServeSession::submit")]
#[allow(deprecated)]
pub fn serve(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    opt: ServeOptions,
) -> Result<(Vec<Response>, ServerReport)> {
    let runtime = WorkerRuntime::new(cfg, params, opt.workers);
    runtime.serve(requests, opt.max_batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_policy_names_round_trip() {
        for p in [AdmissionPolicy::Block, AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
            assert_eq!(AdmissionPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::from_name("shed"), Some(AdmissionPolicy::ShedOldest));
        assert_eq!(AdmissionPolicy::from_name("nope"), None);
    }

    #[test]
    fn response_error_counters_map_outcomes() {
        assert_eq!(ResponseError::WorkerFailure("x".into()).counter(), "failed");
        assert_eq!(ResponseError::Shutdown.counter(), "failed");
        assert_eq!(ResponseError::DeadlineExceeded.counter(), "expired");
        assert_eq!(ResponseError::Cancelled.counter(), "cancelled");
        assert_eq!(ResponseError::QueueFull.counter(), "shed");
    }

    #[test]
    fn submit_error_converts_to_response_error() {
        assert_eq!(
            ResponseError::from(SubmitError::QueueFull { cap: 4 }),
            ResponseError::QueueFull
        );
        assert_eq!(ResponseError::from(SubmitError::Shutdown), ResponseError::Shutdown);
        assert!(matches!(
            ResponseError::from(SubmitError::UnknownVariant("w2".into())),
            ResponseError::WorkerFailure(_)
        ));
    }

    #[test]
    fn session_options_default_is_unbounded_block() {
        let o = SessionOptions::default();
        assert_eq!(o.max_batch, 8);
        assert_eq!(o.queue_cap, 0);
        assert_eq!(o.admission, AdmissionPolicy::Block);
    }

    /// Integration (needs artifacts): batching amortizes — fewer batches
    /// than requests, all requests answered. Exercises the deprecated
    /// shim so the compat surface stays covered.
    #[test]
    #[allow(deprecated)]
    fn serves_all_requests() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..13)
            .map(|i| (0..50u32).map(|t| (t * 3 + i) % 512).collect())
            .collect();
        let (resps, report) = serve_batch(&cfg, &params, reqs, 8).unwrap();
        assert_eq!(resps.len(), 13);
        assert_eq!(report.served, 13);
        assert!(report.batches < 13, "batching never engaged");
        assert!(report.max_queue_depth >= 1);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }

    /// Multi-worker drain (needs artifacts): same answers, all served —
    /// through the session API.
    #[test]
    fn multi_worker_serves_all() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let runtime = WorkerRuntime::new(&cfg, &params, 3);
        let session = runtime
            .session(SessionOptions { max_batch: 4, ..SessionOptions::default() })
            .unwrap();
        let tickets: Vec<Ticket> = (0..17)
            .map(|i| {
                let tokens: Vec<u32> = (0..40u32).map(|t| (t * 5 + i) % 512).collect();
                session.submit(tokens, SubmitOptions::default()).unwrap()
            })
            .collect();
        let resps = session.wait_all(tickets);
        let s = session.stats();
        assert_eq!(resps.len(), 17);
        assert_eq!(s.served, 17);
        assert_eq!(s.submitted, 17);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }
}
