//! Batched serving on a persistent worker runtime (the edge-deployment
//! story): a request queue fed by `serve()` calls, drained by long-lived
//! model workers that pull dynamic batches, score them through the
//! fwd_nll artifact, and report latency/throughput/queue-depth.
//!
//! This is deliberately shaped like a miniature vLLM-style router front:
//! dynamic batching window + FIFO queue + per-request latency metrics —
//! the coordination layer a quantized edge model runs under.
//!
//! [`WorkerRuntime`] is the reusable substrate: worker threads are
//! spawned once, each builds its own [`Scorer`] (an `NllBatcher`, so PJRT
//! stays thread-confined and each thread's engine compile-cache stays
//! warm), and every later `serve()` call reuses them — per-call setup
//! drops from "compile + weight copy per worker" to zero. Quantized
//! variants swap in through [`WorkerRuntime::set_params`], an `Arc`
//! handoff that workers apply before their next batch.
//!
//! **Reply contract:** the responses vec is always aligned 1:1, in order,
//! with the submitted requests. A worker that fails mid-batch re-queues
//! the popped requests for the surviving workers (`report.requeued`
//! counts these); requests that exhaust their retry budget — or drain
//! after the last worker exits — get an error [`Response`] rather than
//! being silently dropped.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::eval::ppl::NllBatcher;
use crate::kernels::{self, KernelPathStats};
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::cache::{self as runtime_cache, CacheStats};
use crate::util::{pool, TaskQueue};

use super::metrics::Metrics;

/// Retries a request gets after batch-scoring failures before it is
/// error-replied.
const MAX_ATTEMPTS: u32 = 3;
/// Consecutive scoring failures after which a worker assumes its scorer
/// is broken and exits (its batches re-queue onto surviving workers).
const MAX_CONSECUTIVE_FAILURES: u32 = 2;
/// Failure messages kept for diagnostics (older entries are dropped).
const MAX_RECORDED_FAILURES: usize = 32;

#[derive(Clone, Debug)]
pub struct Response {
    pub mean_nll: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// `Some(reason)` when the request could not be scored (retry budget
    /// exhausted, or every worker exited). `mean_nll` is NaN then.
    pub error: Option<String>,
}

impl Response {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    fn failed(msg: &str, enqueued: Instant) -> Response {
        Response {
            mean_nll: f32::NAN,
            queue_ms: 0.0,
            total_ms: enqueued.elapsed().as_secs_f64() * 1e3,
            error: Some(msg.to_string()),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Requests answered with a real score.
    pub served: usize,
    /// Requests answered with an error [`Response`] (never dropped).
    pub failed: usize,
    /// Requests pushed back to the queue after a worker failed mid-batch.
    pub requeued: usize,
    pub batches: usize,
    /// Configured worker count (see [`ServerReport::ready_workers`] for
    /// how many actually built a scorer).
    pub workers: usize,
    /// Workers still alive when this call completed (a worker that died
    /// mid-call after serving some batches is not counted).
    pub ready_workers: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
    /// Peak number of requests waiting when a batch was formed.
    pub max_queue_depth: usize,
    /// Time from `serve()` entry until the first batch was picked up —
    /// the per-call setup cost (≈0 on a warm runtime; scorer build +
    /// artifact compile on a cold one).
    pub setup_ms: f64,
    /// Artifact-cache hits since this runtime was built. Counters are
    /// process-wide ([`crate::runtime::cache::stats`]): with a single
    /// live runtime these are its own, but concurrent runtimes/pipelines
    /// show up in each other's deltas.
    pub cache_hits: u64,
    /// Artifact loads/compiles since this runtime was built (same
    /// process-wide caveat as `cache_hits`). Stays flat across repeat
    /// `serve()` calls on a lone runtime: batchers and executables
    /// persist.
    pub cache_misses: u64,
    /// CPU dq_gemm traffic per kernel path (direct/panel/LUT calls,
    /// panel unpacks, LUT builds) since this runtime was built — same
    /// process-wide counter caveat as the cache stats. Zero when scoring
    /// runs entirely through PJRT artifacts.
    pub kernel_paths: KernelPathStats,
}

/// Serving knobs: batch window width + model worker count.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    pub max_batch: usize,
    /// 0 = size from the process-wide thread configuration
    /// (`--threads` / `LIEQ_THREADS` / auto).
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 8, workers: 0 }
    }
}

/// What a serving worker runs per batch. The production impl wraps
/// [`NllBatcher`]; tests and benches inject synthetic scorers to
/// exercise the runtime (failure paths, param swaps) without artifacts.
pub trait Scorer {
    /// Per-token NLL rows, one per passage (row order = passage order).
    fn score(&mut self, passages: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;
    /// Swap in a new parameter set (quantized-variant handoff).
    fn set_params(&mut self, params: &Arc<ParamStore>);
}

/// Builds one [`Scorer`] per worker, *on the worker's own thread* (PJRT
/// engines are thread-confined). Receives the worker index and the
/// current shared parameters.
pub type ScorerFactory =
    Arc<dyn Fn(usize, &Arc<ParamStore>) -> Result<Box<dyn Scorer>> + Send + Sync>;

struct NllScorer {
    batcher: NllBatcher,
    mask: Vec<f32>,
}

impl Scorer for NllScorer {
    fn score(&mut self, passages: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.batcher.nll_rows(passages, &self.mask)
    }

    fn set_params(&mut self, params: &Arc<ParamStore>) {
        self.batcher.set_params_shared(Arc::clone(params));
    }
}

/// Per-`serve()` context shared by that call's jobs.
struct CallCtx {
    metrics: Metrics,
    /// First-batch pickup time: request latency/throughput are measured
    /// from `max(enqueued, begin)` so scorer setup is not billed to
    /// requests (same accounting as the original per-call serving loop).
    begin: Mutex<Option<Instant>>,
    max_batch: usize,
}

/// One queued request.
struct Job {
    tokens: Vec<u32>,
    reply: mpsc::Sender<Response>,
    enqueued: Instant,
    attempts: u32,
    call: Arc<CallCtx>,
}

struct WorkerState {
    /// Workers whose scorer build resolved (successfully or not).
    started: usize,
    /// Workers that built a scorer and are still running.
    running: usize,
    /// Workers that ever built a scorer successfully.
    ready: usize,
}

struct Shared {
    queue: TaskQueue<Job>,
    /// Current weights; bumping `params_gen` makes every worker
    /// re-`set_params` from here before its next batch.
    params: Mutex<Arc<ParamStore>>,
    params_gen: AtomicU64,
    state: Mutex<WorkerState>,
    state_cv: Condvar,
    failures: Mutex<Vec<String>>,
    workers: usize,
}

impl Shared {
    fn current_params(&self) -> (u64, Arc<ParamStore>) {
        let p = self.params.lock().unwrap();
        (self.params_gen.load(Ordering::SeqCst), Arc::clone(&p))
    }

    fn push_failure(&self, msg: String) {
        log::warn!("serving: {msg}");
        let mut f = self.failures.lock().unwrap();
        // Keep the tail only: a long-lived runtime with a flaky scorer
        // must not accumulate one string per failed batch forever.
        if f.len() >= MAX_RECORDED_FAILURES {
            f.remove(0);
        }
        f.push(msg);
    }

    fn failure_summary(&self) -> String {
        let f = self.failures.lock().unwrap();
        if f.is_empty() {
            "unknown".to_string()
        } else {
            f.join("; ")
        }
    }

    /// True once no worker is running and none can still come up.
    fn no_capacity_left(&self) -> bool {
        let s = self.state.lock().unwrap();
        s.started == self.workers && s.running == 0
    }

    /// Error-reply every queued job (all-workers-dead path).
    fn drain_with_errors(&self, msg: &str) {
        for job in self.queue.drain() {
            job.call.metrics.incr("failed", 1);
            let _ = job.reply.send(Response::failed(msg, job.enqueued));
        }
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "panic".to_string())
}

/// Decrements `running` (and error-drains the queue when the last worker
/// goes away) on *every* worker exit path, including unwinds from a
/// panicking `Scorer::set_params` or metrics call — without this,
/// `serve()` would block forever on a reply that can no longer come.
struct RunningGuard {
    shared: Arc<Shared>,
}

impl Drop for RunningGuard {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.running -= 1;
        drop(st);
        self.shared.state_cv.notify_all();
        if self.shared.no_capacity_left() {
            self.shared.drain_with_errors("all serving workers exited");
        }
    }
}

fn worker_loop(wid: usize, shared: Arc<Shared>, factory: ScorerFactory) {
    let (mut local_gen, params) = shared.current_params();
    // A panicking factory must still resolve this worker's build —
    // otherwise serve()/wait_ready() would wait on `started` forever.
    let built = catch_unwind(AssertUnwindSafe(|| factory(wid, &params)))
        .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer build panicked: {}", panic_msg(&*p))));
    let mut scorer = match built {
        Ok(s) => {
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            st.running += 1;
            st.ready += 1;
            drop(st);
            shared.state_cv.notify_all();
            s
        }
        Err(e) => {
            shared.push_failure(format!("worker {wid} scorer build failed: {e:#}"));
            let mut st = shared.state.lock().unwrap();
            st.started += 1;
            drop(st);
            shared.state_cv.notify_all();
            if shared.no_capacity_left() {
                shared.drain_with_errors("no serving workers available");
            }
            return;
        }
    };

    let _guard = RunningGuard { shared: Arc::clone(&shared) };
    let mut consecutive_failures = 0u32;
    while let Some((batch, depth)) = shared
        .queue
        .pop_batch(|first| first.call.max_batch, |first, next| Arc::ptr_eq(&first.call, &next.call))
    {
        // Cheap param-swap handoff: apply a pending set_params before the
        // next batch (generation check is one atomic load).
        if shared.params_gen.load(Ordering::SeqCst) != local_gen {
            let (gen, params) = shared.current_params();
            scorer.set_params(&params);
            local_gen = gen;
        }

        let call = Arc::clone(&batch[0].call);
        call.begin.lock().unwrap().get_or_insert_with(Instant::now);
        call.metrics.observe("queue_depth", depth as f64);

        let t0 = Instant::now();
        let passages: Vec<Vec<u32>> = batch.iter().map(|j| j.tokens.clone()).collect();
        let scored = catch_unwind(AssertUnwindSafe(|| scorer.score(&passages)))
            .unwrap_or_else(|p| Err(anyhow::anyhow!("scorer panicked: {}", panic_msg(&*p))))
            .and_then(|rows| {
                // A short row vec would leave replies unsent; treat it as
                // a scoring failure so every job resolves.
                anyhow::ensure!(
                    rows.len() == batch.len(),
                    "scorer returned {} rows for {} passages",
                    rows.len(),
                    batch.len()
                );
                Ok(rows)
            });
        match scored {
            Ok(rows) => {
                consecutive_failures = 0;
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                call.metrics.observe_ms("batch_exec", exec_ms);
                call.metrics.incr("batches", 1);
                let begin = call.begin.lock().unwrap().unwrap_or(t0);
                for (job, row) in batch.into_iter().zip(rows) {
                    let mean = row.iter().sum::<f32>() / row.len().max(1) as f32;
                    let t_in = job.enqueued.max(begin);
                    let total_ms = t_in.elapsed().as_secs_f64() * 1e3;
                    let queue_ms = (total_ms - exec_ms).max(0.0);
                    call.metrics.observe_ms("request_total", total_ms);
                    call.metrics.incr("served", 1);
                    let _ = job.reply.send(Response {
                        mean_nll: mean,
                        queue_ms,
                        total_ms,
                        error: None,
                    });
                }
            }
            Err(e) => {
                consecutive_failures += 1;
                let msg = format!("{e:#}");
                shared.push_failure(format!("worker {wid} batch failed: {msg}"));
                // Reverse so push_front restores the original order.
                for mut job in batch.into_iter().rev() {
                    job.attempts += 1;
                    if job.attempts >= MAX_ATTEMPTS {
                        job.call.metrics.incr("failed", 1);
                        let _ = job.reply.send(Response::failed(&msg, job.enqueued));
                    } else {
                        job.call.metrics.incr("requeued", 1);
                        if let Err(job) = shared.queue.push_front(job) {
                            // Queue closed under us: reply rather than drop.
                            job.call.metrics.incr("failed", 1);
                            let _ = job.reply.send(Response::failed(&msg, job.enqueued));
                        }
                    }
                }
                if consecutive_failures >= MAX_CONSECUTIVE_FAILURES {
                    log::warn!(
                        "serving worker {wid}: {consecutive_failures} consecutive scoring \
                         failures, exiting"
                    );
                    break;
                }
            }
        }
    }

    // `_guard` drops here: running--, notify waiters, drain if last.
}

/// Persistent serving runtime: long-lived workers, each owning a
/// [`Scorer`] built on its own thread, shared weights behind an `Arc`,
/// and a FIFO queue with a dynamic batching window. See the module docs.
pub struct WorkerRuntime {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    cache_base: CacheStats,
    kernel_base: KernelPathStats,
}

impl WorkerRuntime {
    /// Production runtime: one [`NllBatcher`]-backed scorer per worker.
    /// Workers build eagerly in the background; the first `serve()` call
    /// waits for capacity.
    pub fn new(cfg: &ModelConfig, params: &ParamStore, workers: usize) -> WorkerRuntime {
        let cfg = cfg.clone();
        let factory: ScorerFactory = Arc::new(move |_wid, params| {
            let batcher = NllBatcher::new_shared(&cfg, Arc::clone(params))?;
            let mask = vec![1.0f32; cfg.n_layers];
            Ok(Box::new(NllScorer { batcher, mask }) as Box<dyn Scorer>)
        });
        Self::with_scorer_factory(workers, Arc::new(params.clone()), factory)
    }

    /// Runtime with an injected scorer factory (tests, benches, custom
    /// model backends). `workers == 0` sizes from the process-wide thread
    /// configuration.
    pub fn with_scorer_factory(
        workers: usize,
        params: Arc<ParamStore>,
        factory: ScorerFactory,
    ) -> WorkerRuntime {
        let workers = if workers == 0 { pool::global_threads() } else { workers };
        let cache_base = runtime_cache::stats();
        let kernel_base = kernels::kernel_path_stats();
        let shared = Arc::new(Shared {
            queue: TaskQueue::new(),
            params: Mutex::new(params),
            params_gen: AtomicU64::new(0),
            state: Mutex::new(WorkerState { started: 0, running: 0, ready: 0 }),
            state_cv: Condvar::new(),
            failures: Mutex::new(Vec::new()),
            workers,
        });
        let handles = (0..workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                let factory = Arc::clone(&factory);
                std::thread::Builder::new()
                    .name(format!("lieq-serve-{wid}"))
                    .spawn(move || worker_loop(wid, shared, factory))
                    .expect("spawn serving worker")
            })
            .collect();
        WorkerRuntime { shared, handles, workers, cache_base, kernel_base }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Block until every worker's scorer build has resolved; returns how
    /// many workers ever came up successfully (a worker that built and
    /// later exited still counts — this measures build success, not
    /// current liveness).
    pub fn wait_ready(&self) -> usize {
        let mut st = self.shared.state.lock().unwrap();
        while st.started < self.workers {
            st = self.shared.state_cv.wait(st).unwrap();
        }
        st.ready
    }

    /// Artifact-cache counter movement since this runtime was created.
    /// The underlying counters are process-wide, so loads triggered by a
    /// concurrently-live runtime or pipeline run are included too; with
    /// one runtime at a time this is exactly its own loads + hits.
    pub fn cache_stats(&self) -> CacheStats {
        runtime_cache::stats().delta_from(self.cache_base)
    }

    /// CPU kernel-path counter movement since this runtime was created
    /// (same process-wide caveat as [`WorkerRuntime::cache_stats`]).
    pub fn kernel_stats(&self) -> KernelPathStats {
        kernels::kernel_path_stats().delta_from(self.kernel_base)
    }

    /// Swap the serving weights (e.g. a quantized variant). Cheap: an
    /// `Arc` store plus a generation bump; workers apply it before their
    /// next batch, nothing recompiles, no weights are copied per worker.
    /// Takes `&mut self` so a swap cannot race an in-flight `serve()`.
    pub fn set_params(&mut self, params: &ParamStore) {
        self.set_params_shared(Arc::new(params.clone()));
    }

    /// Zero-copy variant of [`WorkerRuntime::set_params`].
    pub fn set_params_shared(&mut self, params: Arc<ParamStore>) {
        let mut p = self.shared.params.lock().unwrap();
        *p = params;
        self.shared.params_gen.fetch_add(1, Ordering::SeqCst);
    }

    /// Serve `requests` through the dynamic batcher (window `max_batch`).
    /// Returns per-request responses **aligned 1:1, in request order**
    /// plus a report. Errs only when no worker ever became ready.
    pub fn serve(
        &self,
        requests: Vec<Vec<u32>>,
        max_batch: usize,
    ) -> Result<(Vec<Response>, ServerReport)> {
        let t_entry = Instant::now();
        let call = Arc::new(CallCtx {
            metrics: Metrics::new(),
            begin: Mutex::new(None),
            max_batch: max_batch.max(1),
        });

        // Wait until at least one worker is up (or all builds failed):
        // the cold-start path, folded into setup_ms, not request latency.
        let ready = {
            let mut st = self.shared.state.lock().unwrap();
            while st.ready == 0 && st.started < self.workers {
                st = self.shared.state_cv.wait(st).unwrap();
            }
            st.ready
        };
        if ready == 0 {
            bail!("no serving workers available: {}", self.shared.failure_summary());
        }

        let mut reply_rxs = Vec::with_capacity(requests.len());
        for tokens in requests {
            let (rtx, rrx) = mpsc::channel();
            let job = Job {
                tokens,
                reply: rtx,
                enqueued: Instant::now(),
                attempts: 0,
                call: Arc::clone(&call),
            };
            if let Err(job) = self.shared.queue.push(job) {
                // Only Drop closes the queue; reply rather than drop.
                let _ = job.reply.send(Response::failed("serving queue closed", job.enqueued));
            }
            reply_rxs.push(rrx);
        }
        // If the last worker exited between the capacity check and the
        // enqueue, nobody will pop: error-drain so every reply resolves.
        if self.shared.no_capacity_left() {
            self.shared.drain_with_errors("all serving workers exited");
        }

        let responses: Vec<Response> = reply_rxs
            .into_iter()
            .map(|rx| {
                rx.recv().unwrap_or_else(|_| {
                    Response::failed("reply channel closed", t_entry)
                })
            })
            .collect();

        let m = &call.metrics;
        let (p50, p95, _) = m.latency_summary("request_total").unwrap_or((0.0, 0.0, 0.0));
        let begin = *call.begin.lock().unwrap();
        let secs = begin.map(|b| b.elapsed().as_secs_f64()).unwrap_or(f64::EPSILON);
        let setup_ms = begin
            .and_then(|b| b.checked_duration_since(t_entry))
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        let served = m.counter("served") as usize;
        let cache = self.cache_stats();
        m.set_counter("compile_cache_hits", cache.hits);
        m.set_counter("compile_cache_misses", cache.misses);
        let kernel_paths = self.kernel_stats();
        m.set_counter("kernel_direct_calls", kernel_paths.direct_calls);
        m.set_counter("kernel_panel_calls", kernel_paths.panel_calls);
        m.set_counter("kernel_lut_calls", kernel_paths.lut_calls);
        m.set_counter("kernel_panel_unpacks", kernel_paths.panel_unpacks);
        m.set_counter("kernel_lut_builds", kernel_paths.lut_builds);
        // The per-call Metrics registry (counters + latency series incl.
        // the compile-cache numbers above) is observable via RUST_LOG.
        log::debug!("serve call metrics:\n{}", m.report());
        let ready_now = self.shared.state.lock().unwrap().running;
        Ok((
            responses,
            ServerReport {
                served,
                failed: m.counter("failed") as usize,
                requeued: m.counter("requeued") as usize,
                batches: m.counter("batches") as usize,
                workers: self.workers,
                ready_workers: ready_now,
                p50_ms: p50,
                p95_ms: p95,
                throughput_rps: served as f64 / secs.max(f64::EPSILON),
                max_queue_depth: m.series_max("queue_depth").unwrap_or(0.0) as usize,
                setup_ms,
                cache_hits: cache.hits,
                cache_misses: cache.misses,
                kernel_paths,
            },
        ))
    }
}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        self.shared.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Back-compat single-worker entry point (see [`serve`]).
pub fn serve_batch(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    max_batch: usize,
) -> Result<(Vec<Response>, ServerReport)> {
    serve(cfg, params, requests, ServeOptions { max_batch, workers: 1 })
}

/// One-shot serving: build a [`WorkerRuntime`], serve, tear down. Callers
/// that serve repeatedly (or swap quantized variants) should hold a
/// `WorkerRuntime` instead — that is what makes setup cost amortize.
pub fn serve(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    opt: ServeOptions,
) -> Result<(Vec<Response>, ServerReport)> {
    let runtime = WorkerRuntime::new(cfg, params, opt.workers);
    runtime.serve(requests, opt.max_batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration (needs artifacts): batching amortizes — fewer batches
    /// than requests, all requests answered.
    #[test]
    fn serves_all_requests() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..13)
            .map(|i| (0..50u32).map(|t| (t * 3 + i) % 512).collect())
            .collect();
        let (resps, report) = serve_batch(&cfg, &params, reqs, 8).unwrap();
        assert_eq!(resps.len(), 13);
        assert_eq!(report.served, 13);
        assert!(report.batches < 13, "batching never engaged");
        assert!(report.max_queue_depth >= 1);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }

    /// Multi-worker drain (needs artifacts): same answers, all served.
    #[test]
    fn multi_worker_serves_all() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..17)
            .map(|i| (0..40u32).map(|t| (t * 5 + i) % 512).collect())
            .collect();
        let (resps, report) =
            serve(&cfg, &params, reqs, ServeOptions { max_batch: 4, workers: 3 }).unwrap();
        assert_eq!(resps.len(), 17);
        assert_eq!(report.served, 17);
        assert_eq!(report.workers, 3);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }
}
