//! Batched serving loop (the edge-deployment story): a request queue fed
//! by client threads, drained by a configurable pool of model workers
//! that pull fixed-size batches, score them through the fwd_nll artifact,
//! and report latency/throughput/queue-depth.
//!
//! This is deliberately shaped like a miniature vLLM-style router front:
//! dynamic batching window + FIFO queue + per-request latency metrics —
//! the coordination layer a quantized edge model runs under. Workers run
//! on [`Pool`]; each builds its own `NllBatcher` so PJRT stays
//! thread-confined.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::eval::ppl::NllBatcher;
use crate::model::{ModelConfig, ParamStore};
use crate::util::{pool, Pool};

use super::metrics::Metrics;

/// A scoring request: token ids in, mean NLL out.
pub struct Request {
    pub tokens: Vec<u32>,
    pub reply: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub mean_nll: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
}

pub struct ServerReport {
    pub served: usize,
    pub batches: usize,
    pub workers: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
    /// Peak number of requests waiting when a batch was formed.
    pub max_queue_depth: usize,
}

/// Serving knobs: batch window width + model worker count.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    pub max_batch: usize,
    /// 0 = size from the process-wide thread configuration
    /// (`--threads` / `LIEQ_THREADS` / auto).
    pub workers: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 8, workers: 0 }
    }
}

/// Back-compat single-worker entry point (see [`serve`]).
pub fn serve_batch(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    max_batch: usize,
) -> Result<(Vec<Response>, ServerReport)> {
    serve(cfg, params, requests, ServeOptions { max_batch, workers: 1 })
}

/// Serve `requests` through a dynamic batcher of width `opt.max_batch`
/// with `opt.workers` model workers draining one shared FIFO queue.
/// Returns per-request responses (in request order) plus a report.
pub fn serve(
    cfg: &ModelConfig,
    params: &ParamStore,
    requests: Vec<Vec<u32>>,
    opt: ServeOptions,
) -> Result<(Vec<Response>, ServerReport)> {
    let workers = if opt.workers == 0 { pool::global_threads() } else { opt.workers };
    let max_batch = opt.max_batch.max(1);
    let metrics = Metrics::new();

    // Client side: enqueue everything up front (open-loop load).
    let mut reply_rxs = Vec::with_capacity(requests.len());
    let mut queue = VecDeque::with_capacity(requests.len());
    for tokens in requests {
        let (rtx, rrx) = mpsc::channel();
        queue.push_back(Request { tokens, reply: rtx, enqueued: Instant::now() });
        reply_rxs.push(rrx);
    }
    let queue = Mutex::new(queue);
    let failures: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
    // Serving starts when the first worker has a batcher ready: batcher
    // construction (engine + artifact compile under `pjrt`) must not be
    // billed to request latency/throughput, matching the old single-worker
    // accounting. Requests are measured from max(enqueued, serve_begin).
    let serve_begin: Mutex<Option<Instant>> = Mutex::new(None);

    // Worker side: each pool worker owns a batcher and pulls batches until
    // the queue drains.
    let pool = Pool::new(workers);
    pool.scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let batcher = match NllBatcher::new(cfg, params) {
                    Ok(b) => b,
                    Err(e) => {
                        failures.lock().unwrap().push(e);
                        return;
                    }
                };
                serve_begin.lock().unwrap().get_or_insert_with(Instant::now);
                let mask = vec![1.0f32; cfg.n_layers];
                loop {
                    let batch: Vec<Request> = {
                        let mut q = queue.lock().unwrap();
                        if q.is_empty() {
                            break;
                        }
                        metrics.observe("queue_depth", q.len() as f64);
                        let take = q.len().min(max_batch);
                        q.drain(..take).collect()
                    };
                    let t0 = Instant::now();
                    let passages: Vec<Vec<u32>> =
                        batch.iter().map(|r| r.tokens.clone()).collect();
                    let rows = match batcher.nll_rows(&passages, &mask) {
                        Ok(rows) => rows,
                        Err(e) => {
                            failures.lock().unwrap().push(e);
                            return;
                        }
                    };
                    let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                    metrics.observe_ms("batch_exec", exec_ms);
                    metrics.incr("batches", 1);
                    let begin = serve_begin.lock().unwrap().unwrap_or(t0);
                    for (req, row) in batch.into_iter().zip(rows) {
                        let mean = row.iter().sum::<f32>() / row.len().max(1) as f32;
                        let t_in = req.enqueued.max(begin);
                        let total_ms = t_in.elapsed().as_secs_f64() * 1e3;
                        let queue_ms = total_ms - exec_ms;
                        metrics.observe_ms("request_total", total_ms);
                        metrics.incr("served", 1);
                        let _ = req.reply.send(Response {
                            mean_nll: mean,
                            queue_ms: queue_ms.max(0.0),
                            total_ms,
                        });
                    }
                }
            });
        }
    });

    if let Some(e) = failures.into_inner().unwrap().into_iter().next() {
        return Err(e.context("serving worker failed"));
    }

    let responses: Vec<Response> =
        reply_rxs.into_iter().filter_map(|rx| rx.recv().ok()).collect();
    let (p50, p95, _) = metrics.latency_summary("request_total").unwrap_or((0.0, 0.0, 0.0));
    let secs = serve_begin
        .into_inner()
        .unwrap()
        .map(|t| t.elapsed().as_secs_f64())
        .unwrap_or(f64::EPSILON);
    let served = metrics.counter("served") as usize;
    Ok((
        responses,
        ServerReport {
            served,
            batches: metrics.counter("batches") as usize,
            workers,
            p50_ms: p50,
            p95_ms: p95,
            throughput_rps: served as f64 / secs,
            max_queue_depth: metrics.series_max("queue_depth").unwrap_or(0.0) as usize,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration (needs artifacts): batching amortizes — fewer batches
    /// than requests, all requests answered.
    #[test]
    fn serves_all_requests() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..13)
            .map(|i| (0..50u32).map(|t| (t * 3 + i) % 512).collect())
            .collect();
        let (resps, report) = serve_batch(&cfg, &params, reqs, 8).unwrap();
        assert_eq!(resps.len(), 13);
        assert_eq!(report.served, 13);
        assert!(report.batches < 13, "batching never engaged");
        assert!(report.max_queue_depth >= 1);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }

    /// Multi-worker drain (needs artifacts): same answers, all served.
    #[test]
    fn multi_worker_serves_all() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let reqs: Vec<Vec<u32>> = (0..17)
            .map(|i| (0..40u32).map(|t| (t * 5 + i) % 512).collect())
            .collect();
        let (resps, report) =
            serve(&cfg, &params, reqs, ServeOptions { max_batch: 4, workers: 3 }).unwrap();
        assert_eq!(resps.len(), 17);
        assert_eq!(report.served, 17);
        assert_eq!(report.workers, 3);
        assert!(resps.iter().all(|r| r.mean_nll.is_finite()));
    }
}
