//! `lieq` CLI — the L3 coordinator entry point.
//!
//! Subcommands (see README for details):
//!   train     — train a config from init via the AOT train_step artifact
//!   diagnose  — layer-wise diagnostic triplet + scores for a model
//!   quantize  — run the LieQ pipeline and save quantized weights
//!   eval-ppl  — perplexity of a checkpoint on a corpus
//!   eval-tasks— zero-shot suite accuracy
//!   serve     — batched scoring server demo
//!   lint      — self-hosted static analysis over the crate's sources
//!   table1|table2|table3|fig1|fig2|fig4|fig5|spearman|ablate-schemes|e2e
//!             — regenerate the paper's tables and figures

use anyhow::Result;
use lieq::util::{cli::Args, logger};

fn main() {
    logger::init();
    let args = Args::from_env();
    // Global worker count for every pool-parallel path (kernels,
    // diagnostics, quantization, serving). Falls back to LIEQ_THREADS /
    // auto-detection when the flag is absent.
    if let Some(t) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        lieq::util::pool::set_global_threads(t);
    }
    // Global dq_gemm path override (auto | direct | lut | panel | a8 |
    // auto-a8). Falls back to LIEQ_KERNEL / shape-based auto dispatch
    // when absent.
    if let Some(k) = args.get("kernel") {
        match lieq::kernels::parse_kernel_spec(k) {
            Some((p, a8)) => lieq::kernels::set_global_kernel_pref(p, a8),
            None => {
                eprintln!("error: unknown --kernel {k:?} (auto|direct|lut|panel|a8|auto-a8)");
                std::process::exit(1);
            }
        }
    }
    // Global SIMD tier override (off | auto | portable | avx2 | neon).
    // Falls back to LIEQ_SIMD / runtime ISA probe when absent; a forced
    // ISA the host lacks degrades to the portable-chunk tier.
    if let Some(s) = args.get("simd") {
        match lieq::kernels::SimdMode::from_name(s) {
            Some(m) => lieq::kernels::set_global_simd(m),
            None => {
                eprintln!("error: unknown --simd {s:?} (off|auto|portable|avx2|neon)");
                std::process::exit(1);
            }
        }
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "train" => lieq::cmds::cmd_train(args),
        "diagnose" => lieq::cmds::cmd_diagnose(args),
        "quantize" => lieq::cmds::cmd_quantize(args),
        "eval-ppl" => lieq::cmds::cmd_eval_ppl(args),
        "eval-tasks" => lieq::cmds::cmd_eval_tasks(args),
        "serve" => lieq::cmds::cmd_serve(args),
        "lint" => lieq::cmds::cmd_lint(args),
        "table1" => lieq::experiments::table1(args),
        "table2" => lieq::experiments::table2(args),
        "table3" => lieq::experiments::table3(args),
        "fig1" => lieq::experiments::fig1(args),
        "fig2" => lieq::experiments::fig2(args),
        "fig4" => lieq::experiments::fig4(args),
        "fig5" => lieq::experiments::fig5(args),
        "spearman" => lieq::experiments::spearman(args),
        "ablate-schemes" => lieq::experiments::ablate_schemes(args),
        "ablate-alloc" => lieq::experiments::ablate_alloc(args),
        "ablate-weights" => lieq::experiments::ablate_weights(args),
        "pareto" => lieq::experiments::pareto(args),
        "e2e" => lieq::experiments::e2e(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "lieq — layer-wise information effectiveness quantization (ACL'26 repro)

USAGE: lieq <subcommand> [--options]

Core:
  train          --model q_nano [--steps 300] [--lr 3e-3]
  diagnose       --model q_nano [--steps 300] [--domains wiki,c4]
  quantize       --model q_nano [--top-m 1] [--backend gptq] [--out path]
                 [--packed] [--outlier-eps E]
                 (--packed writes a .lieq v2/v3/v4 deployment
                  archive: bit-plane payload + quant grids + persisted
                  interleaved lane images per quantized linear, plus
                  calibrated INT8 activation params (v3) for the W·A8
                  kernel; GPTQ packs its native grids via replay.
                  --outlier-eps E extracts the top-ceil(E·K) salient
                  input columns per linear into a sparse fp16 sidecar
                  (v4 section) fused into every dq_gemm path; 0 = dense)
  eval-ppl       --model q_nano [--domain wiki] [--checkpoint path]
  eval-tasks     --model q_nano [--items 50]
  serve          --model q_nano [--requests 64] [--batch 8] [--rounds 3]
                 [--queue-cap N] [--admission block|reject|shed]
                 [--deadline-ms N] [--variants 2,3] [--backend rtn]
                 [--archive path.lieq] [--decode-chunk N]
                 [--kv-mb N] [--kv-block N]
                 [--replicas N] [--shards SPEC]
                 (continuous batching: workers fold requests in and out of
                  a running batch between decode iterations; --decode-chunk
                  sets positions per iteration (0 = whole request),
                  --kv-mb/--kv-block size the prefix-reuse KV cache
                  (0 MB disables). Rounds reuse one worker runtime, and
                  --variants A/B-routes fp16 + uniform quantized variants
                  through it with per-request deadlines, EDF formation and
                  bounded admission; --archive cold-loads a packed v2
                  archive as an extra variant — persisted lanes mean 0
                  lane builds. --replicas N serves through the cluster
                  tier: N runtimes behind one session with least-loaded
                  routing and failover migration of in-flight streams;
                  --shards SPEC (e.g. 0-5,6-11) pipelines each replica
                  across layer-range stages over bounded conduits)

Tooling:
  lint           [--deny] [--json ANALYSIS.json] [--root rust/src]
                 (self-hosted static analysis: import resolution,
                  hot-path panic-freedom, lock-order cycles, counter
                  monotonicity, determinism-tier bans, contract
                  hygiene; --deny exits nonzero on unwaived findings,
                  waive inline with `// lint: allow(<rule>) — why`)

Paper artifacts:
  table1 | table2 | table3 | fig1 | fig2 | fig4 | fig5
  spearman | ablate-schemes | ablate-alloc | ablate-weights | pareto | e2e

Common options:
  --steps N      training steps for the cached checkpoint (default 300)
  --fast         shrink passage counts for smoke runs
  --threads N    pool workers for kernels/diagnostics/quantize/serve
                 (default: LIEQ_THREADS or all cores)
  --kernel P     dq_gemm path: auto | direct | lut | panel | a8 | auto-a8
                 (default: LIEQ_KERNEL or shape-based auto dispatch;
                  a8 forces the INT8-activation GEMV, auto-a8 keeps
                  shape dispatch but prefers a8 at GEMV shapes)
  --simd T       SIMD tier: off | auto | portable | avx2 | neon
                 (default: LIEQ_SIMD or runtime ISA probe; forced ISAs
                  the host lacks degrade to portable; off is the scalar
                  reference — bit-identical to every f32 tier)
"
    );
}
