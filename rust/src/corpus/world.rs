//! Shared synthetic "world": a small entity–relation knowledge graph from
//! which every corpus domain generates text.
//!
//! All five domains verbalize the *same* underlying facts with different
//! surface statistics. That is deliberate: the paper's Fig. 2 finding is
//! that layer-wise diagnostics are consistent across datasets *within a
//! model family* — which can only be tested if the corpora share latent
//! structure while differing in style, exactly like WikiText/C4/PTB do
//! for English.

use crate::util::Rng;

pub const CLASSES: &[&str] = &[
    "river", "mountain", "city", "composer", "painter", "novel", "engine",
    "mineral", "festival", "dialect", "comet", "dynasty", "harbor", "temple",
];

pub const PLACES: &[&str] = &[
    "Valdoria", "Kethram", "Oslopol", "Brinmark", "Tessily", "Quorra",
    "Ashveil", "Mirandel", "Pyrrhos", "Lunden", "Skarholm", "Veyra",
];

pub const VERBS_PAST: &[&str] = &[
    "founded", "discovered", "composed", "painted", "charted", "restored",
    "documented", "excavated", "mapped", "translated", "catalogued",
];

pub const ADJECTIVES: &[&str] = &[
    "ancient", "celebrated", "obscure", "monumental", "fragile", "vivid",
    "austere", "prosperous", "remote", "influential", "disputed", "serene",
];

pub const SYLLABLES: &[&str] = &[
    "ka", "ru", "mel", "tor", "vin", "sha", "bel", "dra", "fen", "gor",
    "hal", "ister", "jun", "lor", "mar", "nis", "oth", "pra", "quil", "ser",
];

/// One fact: subject entity, relation template index, object entity/value.
#[derive(Clone, Debug)]
pub struct Fact {
    pub subject: usize,
    pub class: usize,
    pub place: usize,
    pub verb: usize,
    pub agent: usize,
    pub year: u32,
    pub adjective: usize,
}

/// The generated world: entity names plus a fact per entity.
#[derive(Clone, Debug)]
pub struct World {
    pub entities: Vec<String>,
    pub facts: Vec<Fact>,
}

impl World {
    pub fn new(seed: u64, n_entities: usize) -> World {
        let mut rng = Rng::new(seed ^ WORLD_SALT);
        let mut entities = Vec::with_capacity(n_entities);
        for _ in 0..n_entities {
            let syls = 2 + rng.below(2);
            let mut name = String::new();
            for _ in 0..syls {
                let syl: &&str = rng.choose(SYLLABLES);
                name.push_str(syl);
            }
            // Capitalize.
            let mut chars = name.chars();
            let cap: String = chars
                .next()
                .map(|c| c.to_uppercase().collect::<String>() + chars.as_str())
                .unwrap_or_default();
            entities.push(cap);
        }
        let facts = (0..n_entities)
            .map(|i| Fact {
                subject: i,
                class: rng.below(CLASSES.len()),
                place: rng.below(PLACES.len()),
                verb: rng.below(VERBS_PAST.len()),
                agent: rng.below(n_entities),
                year: 1400 + rng.below(600) as u32,
                adjective: rng.below(ADJECTIVES.len()),
            })
            .collect();
        World { entities, facts }
    }

    pub fn entity(&self, i: usize) -> &str {
        &self.entities[i % self.entities.len()]
    }

    pub fn fact(&self, i: usize) -> &Fact {
        &self.facts[i % self.facts.len()]
    }
}

const WORLD_SALT: u64 = 0x57_4F_52_4C_44; // "WORLD"

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = World::new(7, 50);
        let b = World::new(7, 50);
        assert_eq!(a.entities, b.entities);
        assert_eq!(a.facts.len(), 50);
    }

    #[test]
    fn different_seeds_differ() {
        let a = World::new(1, 50);
        let b = World::new(2, 50);
        assert_ne!(a.entities, b.entities);
    }

    #[test]
    fn names_capitalized_nonempty() {
        let w = World::new(3, 30);
        for e in &w.entities {
            assert!(!e.is_empty());
            assert!(e.chars().next().unwrap().is_uppercase());
        }
    }
}
