//! The five corpus domains (substitutes for WikiText-2, C4, PTB, Dolly-15k,
//! HH-RLHF — DESIGN.md §2). Each verbalizes the shared [`World`] with a
//! distinct register; passages are deterministic in (domain, seed, index).

use crate::util::Rng;

use super::world::{World, ADJECTIVES, CLASSES, PLACES, VERBS_PAST};

/// Corpus domain identifiers; `name()` strings appear in tables/figures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Domain {
    Wiki,
    C4,
    Ptb,
    Dolly,
    Hh,
}

pub const ALL_DOMAINS: [Domain; 5] =
    [Domain::Wiki, Domain::C4, Domain::Ptb, Domain::Dolly, Domain::Hh];

impl Domain {
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Wiki => "wiki",
            Domain::C4 => "c4",
            Domain::Ptb => "ptb",
            Domain::Dolly => "dolly",
            Domain::Hh => "hh",
        }
    }

    pub fn from_name(s: &str) -> Option<Domain> {
        ALL_DOMAINS.iter().copied().find(|d| d.name() == s)
    }
}

/// One passage of `sentences` sentences in the domain's register.
pub fn passage(world: &World, domain: Domain, rng: &mut Rng, sentences: usize) -> String {
    let mut out = String::new();
    for i in 0..sentences {
        let s = match domain {
            Domain::Wiki => wiki_sentence(world, rng),
            Domain::C4 => c4_sentence(world, rng),
            Domain::Ptb => ptb_sentence(world, rng),
            Domain::Dolly => dolly_exchange(world, rng),
            Domain::Hh => hh_exchange(world, rng),
        };
        if i > 0 {
            out.push(' ');
        }
        out.push_str(&s);
    }
    out
}

type FactParts<'w> =
    (&'w str, &'static str, &'static str, &'static str, &'w str, u32, &'static str);

fn fact_parts<'w>(world: &'w World, rng: &mut Rng) -> FactParts<'w> {
    let f = world.fact(rng.below(world.facts.len()));
    (
        world.entity(f.subject),
        CLASSES[f.class],
        PLACES[f.place],
        VERBS_PAST[f.verb],
        world.entity(f.agent),
        f.year,
        ADJECTIVES[f.adjective],
    )
}

/// Encyclopedic, declarative (WikiText-like).
fn wiki_sentence(world: &World, rng: &mut Rng) -> String {
    let (subj, class, place, verb, agent, year, adj) = fact_parts(world, rng);
    match rng.below(4) {
        0 => format!("{subj} is a {adj} {class} in {place}."),
        1 => format!("{subj}, a {class} of {place}, was {verb} by {agent} in {year}."),
        2 => format!("The {class} {subj} was {verb} in {year} and remains {adj}."),
        _ => format!("In {year}, {agent} {verb} the {class} {subj} near {place}."),
    }
}

/// Noisy web text (C4-like): casual fillers, truncations, artifacts.
fn c4_sentence(world: &World, rng: &mut Rng) -> String {
    let (subj, class, place, verb, agent, year, adj) = fact_parts(world, rng);
    match rng.below(6) {
        0 => format!("check out {subj} - the most {adj} {class} around {place}!!"),
        1 => format!("{subj} ({class}, {year}) ... read more on our site."),
        2 => format!("top 10 {class}s: number one is {subj}, {verb} by {agent}."),
        3 => format!("honestly {subj} is just a {adj} {class} near {place} lol."),
        4 => format!("FREE guide to {place}: visit {subj} the famous {class} today."),
        _ => format!("{agent} {verb} {subj} in {year}. click here for details."),
    }
}

/// Newswire with figures (PTB-like).
fn ptb_sentence(world: &World, rng: &mut Rng) -> String {
    let (subj, class, place, _verb, agent, year, adj) = fact_parts(world, rng);
    let pct = rng.below(40) + 1;
    let mln = rng.below(900) + 10;
    match rng.below(4) {
        0 => format!("shares of {subj} rose {pct} % after the {place} report."),
        1 => format!("the {class} venture of {agent} posted {mln} million in {year} revenue."),
        2 => format!("analysts called the {subj} deal {adj}, citing {place} demand."),
        _ => format!("{subj} fell {pct} % ; traders in {place} blamed the {class} market."),
    }
}

/// Instruction/response pairs (Dolly-like).
fn dolly_exchange(world: &World, rng: &mut Rng) -> String {
    let (subj, class, place, verb, agent, year, adj) = fact_parts(world, rng);
    match rng.below(3) {
        0 => format!(
            "Instruction: describe {subj}. Response: {subj} is a {adj} {class} located in {place}."
        ),
        1 => format!(
            "Instruction: who {verb} {subj}? Response: it was {verb} by {agent} in {year}."
        ),
        _ => format!(
            "Instruction: list facts about {place}. Response: {place} hosts the {class} {subj}."
        ),
    }
}

/// Two-party dialogue (HH-RLHF-like).
fn hh_exchange(world: &World, rng: &mut Rng) -> String {
    let (subj, class, place, verb, agent, year, adj) = fact_parts(world, rng);
    match rng.below(3) {
        0 => format!(
            "Human: have you heard of {subj}? Assistant: yes, it is a {adj} {class} in {place}."
        ),
        1 => format!(
            "Human: tell me about {agent}. Assistant: {agent} {verb} the {class} {subj} in {year}."
        ),
        _ => format!(
            "Human: is {place} worth visiting? Assistant: many visit for {subj}, the {adj} {class}."
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(11, 64)
    }

    #[test]
    fn deterministic_per_seed() {
        let w = world();
        for d in ALL_DOMAINS {
            let a = passage(&w, d, &mut Rng::new(5), 4);
            let b = passage(&w, d, &mut Rng::new(5), 4);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn domains_have_distinct_registers() {
        let w = world();
        let mut rng = Rng::new(1);
        let texts: Vec<String> =
            ALL_DOMAINS.iter().map(|&d| passage(&w, d, &mut rng, 6)).collect();
        assert!(texts[3].contains("Instruction:"));
        assert!(texts[4].contains("Assistant:"));
        // wiki avoids web junk
        assert!(!texts[0].contains("click here"));
        for (i, a) in texts.iter().enumerate() {
            for b in texts.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn shared_world_entities_appear_across_domains() {
        let w = world();
        let mut rng = Rng::new(2);
        let text: String = ALL_DOMAINS
            .iter()
            .map(|&d| passage(&w, d, &mut rng, 20))
            .collect::<Vec<_>>()
            .join(" ");
        let hits = w.entities.iter().filter(|e| text.contains(*e)).count();
        assert!(hits > w.entities.len() / 4, "only {hits} entities used");
    }

    #[test]
    fn passage_lengths_scale_with_sentences() {
        let w = world();
        let s2 = passage(&w, Domain::Wiki, &mut Rng::new(3), 2).len();
        let s10 = passage(&w, Domain::Wiki, &mut Rng::new(3), 10).len();
        assert!(s10 > s2 * 3);
    }
}
