//! Synthetic corpora: shared world, five domains, token-length bucketing,
//! and batch streaming for training.

pub mod domains;
pub mod world;

pub use domains::{passage, Domain, ALL_DOMAINS};
pub use world::World;

use crate::tokenizer::Bpe;
use crate::util::Rng;

/// The paper buckets calibration passages by token length: 33–128 and
/// 129–512, 100 passages per bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bucket {
    Short, // 33..=128 tokens
    Long,  // 129..=512 tokens
}

impl Bucket {
    pub fn range(&self) -> (usize, usize) {
        match self {
            Bucket::Short => (33, 128),
            Bucket::Long => (129, 512),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Bucket::Short => "33-128",
            Bucket::Long => "129-512",
        }
    }
}

/// A corpus handle: world + domain + seed.
pub struct Corpus {
    pub world: World,
    pub domain: Domain,
    pub seed: u64,
}

impl Corpus {
    pub fn new(domain: Domain, seed: u64) -> Corpus {
        // One shared world per seed: domains differ in register only.
        Corpus { world: World::new(seed, 96), domain, seed }
    }

    /// The i-th raw passage (deterministic), roughly `sentences` long.
    pub fn passage(&self, index: usize, sentences: usize) -> String {
        let mut rng = Rng::new(self.seed ^ (index as u64).wrapping_mul(0x100000001B3));
        passage(&self.world, self.domain, &mut rng, sentences)
    }

    /// Sample `n` tokenized passages whose lengths fall in `bucket`.
    /// Generation adapts sentence count until token length lands in range.
    pub fn sample_bucket(&self, bpe: &Bpe, bucket: Bucket, n: usize) -> Vec<Vec<u32>> {
        self.sample_bucket_from(bpe, bucket, n, 0)
    }

    /// Like [`Self::sample_bucket`] but starting at a passage index offset —
    /// used to keep evaluation passages disjoint from calibration ones
    /// while sharing the same underlying world (held-out text, not a
    /// held-out universe).
    pub fn sample_bucket_from(
        &self,
        bpe: &Bpe,
        bucket: Bucket,
        n: usize,
        start_index: usize,
    ) -> Vec<Vec<u32>> {
        let (lo, hi) = bucket.range();
        let mut out = Vec::with_capacity(n);
        let mut index = start_index;
        let mut sentences = match bucket {
            Bucket::Short => 4,
            Bucket::Long => 14,
        };
        let limit = start_index + n * 60;
        while out.len() < n && index < limit {
            let text = self.passage(index, sentences);
            let ids = bpe.encode(&text);
            index += 1;
            if ids.len() >= lo && ids.len() <= hi {
                out.push(ids);
            } else if ids.len() < lo {
                sentences += 1;
            } else if sentences > 2 {
                sentences -= 1;
            }
        }
        assert!(out.len() == n, "bucket sampling starved: got {} of {n}", out.len());
        out
    }

    /// Token stream for training: concatenated passages, exact length.
    pub fn token_stream(&self, bpe: &Bpe, n_tokens: usize, stream_seed: u64) -> Vec<u32> {
        let mut out = Vec::with_capacity(n_tokens + 256);
        let mut index = (stream_seed as usize) << 16;
        while out.len() < n_tokens {
            let text = self.passage(index, 8);
            out.extend(bpe.encode(&text));
            out.push(b'\n' as u32); // passage separator (newline byte token)
            index += 1;
        }
        out.truncate(n_tokens);
        out
    }
}

/// Mixed-domain training text used both for BPE training and LM training.
pub fn training_texts(seed: u64, per_domain: usize) -> Vec<String> {
    let mut texts = Vec::new();
    for &d in ALL_DOMAINS.iter() {
        let c = Corpus::new(d, seed);
        for i in 0..per_domain {
            texts.push(c.passage(i, 6));
        }
    }
    texts
}

/// Mixed-domain token stream (training mixes all five domains).
pub fn mixed_stream(bpe: &Bpe, seed: u64, n_tokens: usize, stream_seed: u64) -> Vec<u32> {
    let per = n_tokens / ALL_DOMAINS.len() + 1;
    let mut out = Vec::with_capacity(n_tokens + per);
    for (i, &d) in ALL_DOMAINS.iter().enumerate() {
        let c = Corpus::new(d, seed);
        out.extend(c.token_stream(bpe, per, stream_seed.wrapping_add(i as u64)));
    }
    // Interleave coarsely by shuffling passage-sized blocks.
    out.truncate(n_tokens);
    out
}

/// Train (or load cached) the shared 512-vocab tokenizer.
pub fn shared_tokenizer(artifacts: &std::path::Path, vocab: usize, seed: u64) -> Bpe {
    let path = artifacts.join(format!("tokenizer_v{vocab}.bpe"));
    if let Ok(bpe) = Bpe::load(&path) {
        if bpe.vocab_size() <= vocab {
            return bpe;
        }
    }
    let texts = training_texts(seed, 400);
    let bpe = Bpe::train(&texts, vocab);
    let _ = bpe.save(&path);
    bpe
}

/// Pack a token stream into (B, T) i32 batches for the train_step artifact.
pub fn batches(stream: &[u32], batch: usize, seq: usize) -> Vec<Vec<i32>> {
    let per = batch * seq;
    stream
        .chunks_exact(per)
        .map(|chunk| chunk.iter().map(|&t| t as i32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bpe() -> Bpe {
        Bpe::train(&training_texts(3, 60), 512)
    }

    #[test]
    fn bucket_sampling_lands_in_range() {
        let bpe = bpe();
        let c = Corpus::new(Domain::Wiki, 3);
        for bucket in [Bucket::Short, Bucket::Long] {
            let (lo, hi) = bucket.range();
            let samples = c.sample_bucket(&bpe, bucket, 8);
            assert_eq!(samples.len(), 8);
            for s in samples {
                assert!(s.len() >= lo && s.len() <= hi, "len {} not in {lo}..{hi}", s.len());
            }
        }
    }

    #[test]
    fn token_stream_has_requested_len_and_valid_ids() {
        let bpe = bpe();
        let c = Corpus::new(Domain::C4, 3);
        let stream = c.token_stream(&bpe, 5000, 0);
        assert_eq!(stream.len(), 5000);
        assert!(stream.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    }

    #[test]
    fn batches_shape() {
        let stream: Vec<u32> = (0..1000).map(|i| i % 500).collect();
        let b = batches(&stream, 4, 32);
        assert_eq!(b.len(), 1000 / 128);
        assert!(b.iter().all(|x| x.len() == 128));
    }

    #[test]
    fn domains_share_world_per_seed() {
        let a = Corpus::new(Domain::Wiki, 9);
        let b = Corpus::new(Domain::Hh, 9);
        assert_eq!(a.world.entities, b.world.entities);
    }

    #[test]
    fn different_stream_seeds_differ() {
        let bpe = bpe();
        let c = Corpus::new(Domain::Ptb, 4);
        assert_ne!(c.token_stream(&bpe, 512, 0), c.token_stream(&bpe, 512, 1));
    }

    #[test]
    fn mixed_stream_covers_all_domains() {
        let bpe = bpe();
        let s = mixed_stream(&bpe, 3, 4000, 0);
        assert_eq!(s.len(), 4000);
    }
}
