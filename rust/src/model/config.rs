//! Model configuration, loaded from `artifacts/<cfg>/manifest.json`.
//!
//! The manifest (written by the AOT path) is the single source of truth
//! for dimensions and the positional parameter contract; this module
//! never re-derives shapes independently — it binds to what Python lowered.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// The seven quantizable linear projections per transformer layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinearKind {
    QProj,
    KProj,
    VProj,
    OProj,
    GateProj,
    UpProj,
    DownProj,
}

pub const ALL_LINEARS: [LinearKind; 7] = [
    LinearKind::QProj,
    LinearKind::KProj,
    LinearKind::VProj,
    LinearKind::OProj,
    LinearKind::GateProj,
    LinearKind::UpProj,
    LinearKind::DownProj,
];

impl LinearKind {
    pub fn suffix(&self) -> &'static str {
        match self {
            LinearKind::QProj => "q_proj",
            LinearKind::KProj => "k_proj",
            LinearKind::VProj => "v_proj",
            LinearKind::OProj => "o_proj",
            LinearKind::GateProj => "gate_proj",
            LinearKind::UpProj => "up_proj",
            LinearKind::DownProj => "down_proj",
        }
    }

    pub fn from_suffix(s: &str) -> Option<LinearKind> {
        ALL_LINEARS.iter().copied().find(|k| k.suffix() == s)
    }

    /// Which captured activation feeds this linear (calibration input).
    pub fn calib_source(&self) -> &'static str {
        match self {
            LinearKind::QProj | LinearKind::KProj | LinearKind::VProj => "attn_in",
            LinearKind::OProj => "ctx",
            LinearKind::GateProj | LinearKind::UpProj => "mlp_in",
            LinearKind::DownProj => "mlp_act",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub kind: String,
    pub batch: usize,
    pub seq: usize,
    pub input_shapes: Vec<(Vec<usize>, String)>,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub family: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub qk_norm: bool,
    pub tied_embedding: bool,
    pub group_size: usize,
    pub n_params: usize,
    pub params: Vec<ParamInfo>,
    pub artifacts: std::collections::BTreeMap<String, ArtifactInfo>,
    pub dir: PathBuf,
}

impl ModelConfig {
    /// Synthetic config (no manifest) — for pure unit tests of allocation
    /// math and packing that need realistic shapes without artifacts.
    pub fn synthetic(n_layers: usize, d_model: usize, d_ff: usize) -> ModelConfig {
        let mut params = vec![ParamInfo { name: "embed".into(), shape: vec![512, d_model] }];
        for l in 0..n_layers {
            let p = |s: &str, shape: Vec<usize>| ParamInfo {
                name: format!("layers.{l}.{s}"),
                shape,
            };
            params.push(p("attn_norm", vec![d_model]));
            params.push(p("q_proj", vec![d_model, d_model]));
            params.push(p("k_proj", vec![d_model, d_model / 2]));
            params.push(p("v_proj", vec![d_model, d_model / 2]));
            params.push(p("o_proj", vec![d_model, d_model]));
            params.push(p("mlp_norm", vec![d_model]));
            params.push(p("gate_proj", vec![d_model, d_ff]));
            params.push(p("up_proj", vec![d_model, d_ff]));
            params.push(p("down_proj", vec![d_ff, d_model]));
        }
        params.push(ParamInfo { name: "final_norm".into(), shape: vec![d_model] });
        let n_params = params.iter().map(|p| p.shape.iter().product::<usize>()).sum();
        ModelConfig {
            name: format!("synthetic_{n_layers}l_{d_model}d"),
            family: "Q".into(),
            n_layers,
            d_model,
            n_heads: 4,
            n_kv_heads: 2,
            d_head: d_model / 4,
            d_ff,
            vocab: 512,
            qk_norm: false,
            tied_embedding: true,
            group_size: 64,
            n_params,
            params,
            artifacts: Default::default(),
            dir: PathBuf::from("/nonexistent"),
        }
    }

    /// Synthetic config whose `fwd_nll_*` artifact entries point at real
    /// (placeholder) files under `dir` — enough for `NllBatcher`
    /// construction, the serving runtime, and the compile cache to be
    /// exercised offline (the default build's stub engine validates and
    /// caches loads; only *execution* needs `--features pjrt`). Tests and
    /// benches use this; it is never a substitute for a compiled manifest.
    pub fn synthetic_with_artifacts(
        n_layers: usize,
        d_model: usize,
        d_ff: usize,
        dir: &Path,
    ) -> Result<ModelConfig> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create synthetic artifact dir {dir:?}"))?;
        let mut cfg = Self::synthetic(n_layers, d_model, d_ff);
        for (key, batch, seq) in
            [("fwd_nll_b8_t128", 8usize, 128usize), ("fwd_nll_b2_t512", 2, 512)]
        {
            let file = format!("{key}.hlo.txt");
            let path = dir.join(&file);
            std::fs::write(&path, "HloModule synthetic_placeholder\n")
                .with_context(|| format!("write placeholder artifact {path:?}"))?;
            cfg.artifacts.insert(
                key.to_string(),
                ArtifactInfo {
                    file,
                    kind: "fwd_nll".to_string(),
                    batch,
                    seq,
                    input_shapes: Vec::new(),
                },
            );
        }
        cfg.dir = dir.to_path_buf();
        Ok(cfg)
    }

    /// Load from `artifacts/<name>/manifest.json`.
    pub fn load(artifacts_root: &Path, name: &str) -> Result<ModelConfig> {
        let dir = artifacts_root.join(name);
        let manifest = Json::parse_file(dir.join("manifest.json"))
            .with_context(|| format!("manifest for {name}"))?;
        Self::from_manifest(&manifest, dir)
    }

    pub fn from_manifest(m: &Json, dir: PathBuf) -> Result<ModelConfig> {
        let params = m
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = std::collections::BTreeMap::new();
        let arts = m.get("artifacts")?;
        for key in arts.keys() {
            let a = arts.get(key)?;
            artifacts.insert(
                key.to_string(),
                ArtifactInfo {
                    file: a.get("file")?.as_str()?.to_string(),
                    kind: a.get("kind")?.as_str()?.to_string(),
                    batch: a.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
                    seq: a.get("seq").and_then(|v| v.as_usize()).unwrap_or(0),
                    input_shapes: a
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(|i| {
                            Ok((
                                i.get("shape")?
                                    .as_arr()?
                                    .iter()
                                    .map(|d| d.as_usize())
                                    .collect::<Result<Vec<_>>>()?,
                                i.get("dtype")?.as_str()?.to_string(),
                            ))
                        })
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        Ok(ModelConfig {
            name: m.get("name")?.as_str()?.to_string(),
            family: m.get("family")?.as_str()?.to_string(),
            n_layers: m.get("n_layers")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            n_kv_heads: m.get("n_kv_heads")?.as_usize()?,
            d_head: m.get("d_head")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            vocab: m.get("vocab")?.as_usize()?,
            qk_norm: m.get("qk_norm")?.as_bool()?,
            tied_embedding: m.get("tied_embedding")?.as_bool()?,
            group_size: m.get("group_size")?.as_usize()?,
            n_params: m.get("n_params")?.as_usize()?,
            params,
            artifacts,
            dir,
        })
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("model {} lacks artifact {key}", self.name))
    }

    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(key)?.file))
    }

    /// Full parameter name of a per-layer linear.
    pub fn linear_name(&self, layer: usize, kind: LinearKind) -> String {
        format!("layers.{layer}.{}", kind.suffix())
    }

    /// Parameter count of one layer (for Eq. 12's N_ℓ weighting).
    pub fn layer_param_count(&self, layer: usize) -> usize {
        let prefix = format!("layers.{layer}.");
        self.params
            .iter()
            .filter(|p| p.name.starts_with(&prefix))
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }

    /// Quantizable parameter count of one layer (linears only).
    pub fn layer_linear_param_count(&self, layer: usize) -> usize {
        ALL_LINEARS
            .iter()
            .map(|&k| {
                let name = self.linear_name(layer, k);
                self.params
                    .iter()
                    .find(|p| p.name == name)
                    .map(|p| p.shape.iter().product::<usize>())
                    .unwrap_or(0)
            })
            .sum()
    }

    pub fn param_info(&self, name: &str) -> Result<&ParamInfo> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown param {name}"))
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_heads != 0 {
            bail!("d_model not divisible by heads");
        }
        if self.n_heads % self.n_kv_heads != 0 {
            bail!("GQA ratio not integral");
        }
        if self.params.is_empty() {
            bail!("no params in manifest");
        }
        Ok(())
    }
}

/// Names of every config the AOT path emits (must match configs.LADDER).
pub const LADDER: [&str; 7] =
    ["q_nano", "q_micro", "q_small", "q_base", "l_nano", "l_micro", "l_small"];

#[cfg(test)]
mod tests {
    use super::*;

    fn nano() -> Option<ModelConfig> {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return None;
        }
        Some(ModelConfig::load(&root, "q_nano").unwrap())
    }

    #[test]
    fn loads_manifest() {
        let Some(cfg) = nano() else { return };
        assert_eq!(cfg.n_layers, 4);
        assert_eq!(cfg.d_model, 128);
        assert!(cfg.qk_norm && cfg.tied_embedding);
        cfg.validate().unwrap();
        assert!(cfg.artifacts.contains_key("fwd_nll_b8_t128"));
    }

    #[test]
    fn param_contract_matches_python() {
        let Some(cfg) = nano() else { return };
        // 11 per layer (family Q) + embed + final_norm.
        assert_eq!(cfg.params.len(), 4 * 11 + 2);
        assert_eq!(cfg.params[0].name, "embed");
        assert_eq!(cfg.params[0].shape, vec![512, 128]);
        assert_eq!(cfg.param_info("layers.0.gate_proj").unwrap().shape, vec![128, 384]);
    }

    #[test]
    fn layer_param_counts_positive() {
        let Some(cfg) = nano() else { return };
        for l in 0..cfg.n_layers {
            assert!(cfg.layer_linear_param_count(l) > 0);
            assert!(cfg.layer_param_count(l) >= cfg.layer_linear_param_count(l));
        }
    }

    #[test]
    fn calib_sources() {
        assert_eq!(LinearKind::QProj.calib_source(), "attn_in");
        assert_eq!(LinearKind::DownProj.calib_source(), "mlp_act");
        for k in ALL_LINEARS {
            assert_eq!(LinearKind::from_suffix(k.suffix()), Some(k));
        }
    }
}
