//! Named parameter store bound to a [`ModelConfig`]'s positional contract.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::{read_archive, write_archive, DType, Tensor};

use super::ModelConfig;

/// Model weights addressable by name, with conversion to/from the
/// positional argument order the AOT artifacts expect.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub map: BTreeMap<String, Tensor>,
    /// positional order (from the manifest).
    pub order: Vec<String>,
}

impl ParamStore {
    /// Load `init.lieq` / a trained checkpoint and validate against config.
    pub fn load(cfg: &ModelConfig, path: impl AsRef<Path>) -> Result<ParamStore> {
        Self::from_named(cfg, read_archive(path)?)
    }

    /// Build from named tensors (archive entries, in-memory stores) and
    /// validate against the config's parameter contract — shared by the
    /// checkpoint loader and the packed-archive (`.lieq` v2) serve path.
    pub fn from_named(cfg: &ModelConfig, tensors: Vec<(String, Tensor)>) -> Result<ParamStore> {
        let mut map = BTreeMap::new();
        for (name, t) in tensors {
            map.insert(name, t);
        }
        let order: Vec<String> = cfg.params.iter().map(|p| p.name.clone()).collect();
        for p in &cfg.params {
            let Some(t) = map.get(&p.name) else {
                bail!("checkpoint missing param {}", p.name)
            };
            if t.shape != p.shape {
                bail!("param {} shape {:?} != manifest {:?}", p.name, t.shape, p.shape);
            }
            if t.dtype != DType::F32 {
                bail!("param {} is not f32", p.name);
            }
        }
        Ok(ParamStore { map, order })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let tensors: Vec<(String, Tensor)> = self
            .order
            .iter()
            .map(|n| (n.clone(), self.map[n].clone()))
            .collect();
        write_archive(path, &tensors)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("param {name} not in store"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    /// Positional view in manifest order (what artifacts consume).
    pub fn positional(&self) -> Vec<&Tensor> {
        self.order.iter().map(|n| &self.map[n]).collect()
    }

    /// All-zero store matching the config's parameter contract (synthetic
    /// test/bench scaffolding — pairs with
    /// [`ModelConfig::synthetic_with_artifacts`]).
    pub fn zeros(cfg: &ModelConfig) -> ParamStore {
        let order: Vec<String> = cfg.params.iter().map(|p| p.name.clone()).collect();
        let map = cfg
            .params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                (p.name.clone(), Tensor::from_f32(vec![0f32; len], &p.shape))
            })
            .collect();
        ParamStore { map, order }
    }

    /// Rebuild from positional tensors (e.g. train_step outputs).
    pub fn from_positional(cfg: &ModelConfig, tensors: Vec<Tensor>) -> Result<ParamStore> {
        if tensors.len() != cfg.params.len() {
            bail!("expected {} tensors, got {}", cfg.params.len(), tensors.len());
        }
        let order: Vec<String> = cfg.params.iter().map(|p| p.name.clone()).collect();
        let map = order.iter().cloned().zip(tensors).collect();
        Ok(ParamStore { map, order })
    }

    pub fn n_params(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Deep copy with a transform applied to a single named tensor.
    pub fn with_replaced(&self, name: &str, t: Tensor) -> ParamStore {
        let mut out = self.clone();
        out.set(name, t);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn nano() -> Option<(ModelConfig, ParamStore)> {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return None;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let ps = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        Some((cfg, ps))
    }

    #[test]
    fn loads_and_validates_init() {
        let Some((cfg, ps)) = nano() else { return };
        assert_eq!(ps.order.len(), cfg.params.len());
        assert_eq!(ps.n_params(), cfg.n_params);
        assert_eq!(ps.positional().len(), cfg.params.len());
    }

    #[test]
    fn positional_order_matches_manifest() {
        let Some((cfg, ps)) = nano() else { return };
        let pos = ps.positional();
        for (t, p) in pos.iter().zip(&cfg.params) {
            assert_eq!(t.shape, p.shape, "order mismatch at {}", p.name);
        }
    }

    #[test]
    fn roundtrip_through_positional() {
        let Some((cfg, ps)) = nano() else { return };
        let tensors: Vec<Tensor> = ps.positional().into_iter().cloned().collect();
        let ps2 = ParamStore::from_positional(&cfg, tensors).unwrap();
        assert_eq!(ps2.get("embed").unwrap().u32_slice(), ps.get("embed").unwrap().u32_slice());
    }
}
