//! Model zoo: configs mirrored from `python/compile/configs.py`, manifest
//! binding, and named parameter stores.

pub mod config;
pub mod params;

pub use config::{LinearKind, ModelConfig, ParamInfo};
pub use params::ParamStore;
