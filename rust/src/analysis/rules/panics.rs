//! panic-freedom: in the hot-path tier (kernels/, the serving loop,
//! the KV + weight caches, the thread pool), production code may not
//! call `.unwrap()` / `.expect()` or invoke `panic!`-family macros.
//! Test code (`#[test]` fns, `#[cfg(test)]` items) is exempt, and so is
//! the poisoned-mutex pattern: `.unwrap()`/`.expect()` directly on the
//! `LockResult` of `lock()` / `read()` / `write()` / `wait*()` /
//! `into_inner()` — a poisoned lock means a worker already panicked,
//! and propagating is the documented policy.

use crate::analysis::lexer::{test_mask, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::rules::hot_tier;
use crate::analysis::Crate;

pub const RULE: &str = "panic-freedom";

const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

pub fn check(krate: &Crate) -> Vec<Finding> {
    let mut out = Vec::new();
    for sf in &krate.files {
        if !hot_tier(&sf.path) {
            continue;
        }
        let toks = &sf.tokens;
        let mask = test_mask(toks);
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        for ci in 0..code.len() {
            let idx = code[ci];
            let t = &toks[idx];
            if t.kind != TokenKind::Ident || mask[idx] {
                continue;
            }
            let next_is = |off: usize, text: &str| {
                code.get(ci + off).map(|&j| toks[j].is(TokenKind::Punct, text)).unwrap_or(false)
            };
            let prev_is_dot = ci > 0 && toks[code[ci - 1]].is(TokenKind::Punct, ".");
            match t.text.as_str() {
                "unwrap" | "expect" if prev_is_dot && next_is(1, "(") => {
                    if !poison_allowlisted(toks, &code, ci) {
                        out.push(Finding::new(
                            RULE,
                            &sf.path,
                            t.line,
                            format!(".{}() in hot-path tier", t.text),
                        ));
                    }
                }
                m if PANIC_MACROS.contains(&m) && next_is(1, "!") && !prev_is_dot => {
                    out.push(Finding::new(
                        RULE,
                        &sf.path,
                        t.line,
                        format!("{}! in hot-path tier", t.text),
                    ));
                }
                _ => {}
            }
        }
    }
    out
}

/// Is the receiver of the `.unwrap()`/`.expect()` at code position `ci`
/// (pointing at the `unwrap` ident) the direct result of a lock-family
/// call? Pattern: `recv.M(..).unwrap()` where M is a `LockResult`
/// producer. `read`/`write` must be called with empty parens so that
/// io::Read/Write buffer calls (which return io::Result) never match.
fn poison_allowlisted(
    toks: &[crate::analysis::lexer::Token],
    code: &[usize],
    ci: usize,
) -> bool {
    // ci-1 is the `.`; ci-2 must be the `)` of the preceding call.
    let Some(&close) = ci.checked_sub(2).and_then(|k| code.get(k)) else { return false };
    if !toks[close].is(TokenKind::Punct, ")") {
        return false;
    }
    // Walk back to the matching `(`.
    let mut depth = 0i32;
    let mut k = ci - 2;
    let open = loop {
        let t = &toks[code[k]];
        if t.is(TokenKind::Punct, ")") {
            depth += 1;
        } else if t.is(TokenKind::Punct, "(") {
            depth -= 1;
            if depth == 0 {
                break k;
            }
        }
        if k == 0 {
            return false;
        }
        k -= 1;
    };
    if open < 2 {
        return false;
    }
    let meth = &toks[code[open - 1]];
    if meth.kind != TokenKind::Ident || !toks[code[open - 2]].is(TokenKind::Punct, ".") {
        return false;
    }
    let empty_args = open + 1 == ci - 2;
    match meth.text.as_str() {
        "lock" | "into_inner" => true,
        "read" | "write" => empty_args,
        "wait" | "wait_timeout" | "wait_while" | "wait_timeout_while" => true,
        _ => false,
    }
}
