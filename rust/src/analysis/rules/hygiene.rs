//! contract-hygiene, three legs:
//!   1. no `#[deprecated]` items anywhere (that surface was deleted);
//!   2. every `unsafe` block carries a `// SAFETY:` comment on the same
//!      line or within the three lines above it;
//!   3. size arithmetic in `tensor/archive.rs` (header-derived values)
//!      uses `checked_*` — a bare binary `*` in non-test code there is
//!      flagged.

use crate::analysis::lexer::{test_mask, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::Crate;

pub const RULE: &str = "contract-hygiene";

pub fn check(krate: &Crate) -> Vec<Finding> {
    let mut out = Vec::new();
    for sf in &krate.files {
        let toks = &sf.tokens;
        let mask = test_mask(toks);
        let safety_lines: Vec<u32> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Comment && t.text.contains("SAFETY:"))
            .map(|t| t.line)
            .collect();
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        for ci in 0..code.len() {
            let idx = code[ci];
            let t = &toks[idx];
            // Leg 1: #[deprecated].
            if t.is(TokenKind::Ident, "deprecated")
                && ci >= 2
                && toks[code[ci - 1]].is(TokenKind::Punct, "[")
                && toks[code[ci - 2]].is(TokenKind::Punct, "#")
            {
                out.push(Finding::new(
                    RULE,
                    &sf.path,
                    t.line,
                    "#[deprecated] item — delete the item or the attribute".to_string(),
                ));
                continue;
            }
            // Leg 2: unsafe block without a SAFETY comment.
            if t.is(TokenKind::Ident, "unsafe")
                && code
                    .get(ci + 1)
                    .map(|&j| toks[j].is(TokenKind::Punct, "{"))
                    .unwrap_or(false)
            {
                let l = t.line;
                let covered =
                    safety_lines.iter().any(|&c| c <= l && l.saturating_sub(c) <= 3);
                if !covered {
                    out.push(Finding::new(
                        RULE,
                        &sf.path,
                        l,
                        "unsafe block without a // SAFETY: comment".to_string(),
                    ));
                }
                continue;
            }
            // Leg 3: bare multiplication in archive size math.
            if sf.path == "tensor/archive.rs"
                && !mask[idx]
                && t.is(TokenKind::Punct, "*")
                && ci > 0
            {
                let p = &toks[code[ci - 1]];
                let binary = p.kind == TokenKind::Ident
                    || p.kind == TokenKind::Num
                    || p.is(TokenKind::Punct, ")")
                    || p.is(TokenKind::Punct, "]");
                if binary {
                    out.push(Finding::new(
                        RULE,
                        &sf.path,
                        t.line,
                        "bare `*` on size math in archive parsing — use checked_mul".to_string(),
                    ));
                }
            }
        }
    }
    out
}
