//! lock-order: detects cyclic `Mutex`/`RwLock` acquisition order.
//!
//! A lock is identified by `Struct.field` (or the static's name).
//! Within each fn body, a guard-scope tracker records which locks are
//! held at every acquisition and call site; per-fn acquisition
//! summaries are propagated over the intra-crate call graph to a
//! fixpoint, so `A.lock(); shared.queue.push(..)` picks up the locks
//! `push` (and its callees) take. Any cycle in the resulting
//! "held-while-acquiring" edge set — including a self-edge, which is an
//! outright re-entrant deadlock with std's non-reentrant `Mutex` — is
//! reported.
//!
//! Precision notes (kept deliberately conservative): receivers that
//! cannot be traced to a uniquely-named lock field produce no edge; a
//! method call whose receiver type cannot be inferred resolves to *no*
//! callee rather than falling back by name (std method names like
//! `len`/`get`/`push` must not alias crate fns); and a temporary guard
//! created in a `for`-loop header is assumed live until the next
//! statement boundary at its depth.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{Token, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::rules::{index_file, receiver_chain, FnInfo};
use crate::analysis::{resolve, Crate};

pub const RULE: &str = "lock-order";

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "break", "continue", "in", "as",
    "let", "move", "ref", "mut", "fn", "impl", "where", "unsafe", "dyn",
];

#[derive(Clone, Debug)]
struct Call {
    name: String,
    hint: Option<String>,
    /// Method call through `.` (vs a bare or `::`-qualified call).
    dotted: bool,
}

#[derive(Default)]
struct Body {
    file: String,
    direct: BTreeSet<String>,
    calls: Vec<Call>,
    /// (held lock, call, line) — call made while the lock was held.
    held_calls: Vec<(String, Call, u32)>,
    /// (held lock, acquired lock, line).
    direct_edges: Vec<(String, String, u32)>,
}

struct LockWorld {
    /// (struct, field) -> lock id, for lock-typed fields.
    lock_fields: BTreeMap<(String, String), String>,
    /// field name -> lock ids sharing that name.
    by_field: BTreeMap<String, BTreeSet<String>>,
    /// field name -> crate struct names appearing in its type.
    field_struct: BTreeMap<String, BTreeSet<String>>,
    /// static locks by name.
    statics: BTreeSet<String>,
}

fn is_lock_type(type_text: &str) -> bool {
    type_text.split(' ').any(|w| w == "Mutex" || w == "RwLock")
}

impl LockWorld {
    fn build(krate: &Crate) -> LockWorld {
        let fields = resolve::struct_fields(krate);
        let struct_names: BTreeSet<&str> = fields.iter().map(|f| f.strukt.as_str()).collect();
        let mut w = LockWorld {
            lock_fields: BTreeMap::new(),
            by_field: BTreeMap::new(),
            field_struct: BTreeMap::new(),
            statics: BTreeSet::new(),
        };
        for f in &fields {
            if is_lock_type(&f.type_text) {
                let id = format!("{}.{}", f.strukt, f.field);
                w.lock_fields.insert((f.strukt.clone(), f.field.clone()), id.clone());
                w.by_field.entry(f.field.clone()).or_default().insert(id);
            }
            // Crate struct named in the field's type, for receiver-type
            // inference (`Arc<ServeShared>` -> ServeShared).
            if let Some(s) =
                f.type_text.split(' ').find(|wrd| struct_names.contains(wrd) && *wrd != f.strukt)
            {
                w.field_struct.entry(f.field.clone()).or_default().insert(s.to_string());
            }
        }
        for s in resolve::statics(krate) {
            if is_lock_type(&s.type_text) {
                w.statics.insert(s.name);
            }
        }
        w
    }

    /// Lock id for an acquisition whose receiver chain (`self.ctx.queued`
    /// -> `[self, ctx, queued]`) ends in a candidate field. Ambiguous
    /// receivers yield None (no edge) rather than a guess.
    fn lock_of(&self, chain: &[String], impl_type: Option<&str>) -> Option<String> {
        let f = chain.last()?;
        if chain.len() == 1 {
            return if self.statics.contains(f) { Some(f.clone()) } else { None };
        }
        let cands = self.by_field.get(f)?;
        let owner = if chain.len() == 2 && chain[0] == "self" {
            impl_type.map(|s| s.to_string())
        } else {
            let x = &chain[chain.len() - 2];
            match self.field_struct.get(x) {
                Some(set) if set.len() == 1 => set.iter().next().cloned(),
                _ => None,
            }
        };
        if let Some(t) = owner {
            if let Some(id) = self.lock_fields.get(&(t, f.clone())) {
                return Some(id.clone());
            }
        }
        if cands.len() == 1 {
            return cands.iter().next().cloned();
        }
        None
    }
}

pub fn check(krate: &Crate) -> Vec<Finding> {
    let world = LockWorld::build(krate);
    let mut bodies: Vec<(FnInfo, Body)> = Vec::new();
    for sf in &krate.files {
        let fx = index_file(sf);
        for f in &fx.fns {
            let body = scan_body(&sf.tokens, &fx.code, f, &world, &sf.path);
            bodies.push((f.clone(), body));
        }
    }
    // Name -> body indices; (impl, name) -> index.
    let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut by_key: BTreeMap<(String, String), usize> = BTreeMap::new();
    for (i, (f, _)) in bodies.iter().enumerate() {
        by_name.entry(f.name.as_str()).or_default().push(i);
        if let Some(t) = &f.impl_type {
            by_key.insert((t.clone(), f.name.clone()), i);
        }
    }
    // Call resolution is deliberately strict to keep edges honest:
    // method names shared with std containers (`len`, `get`, `push`,
    // `insert`, …) must never fall back to same-named crate fns.
    //   * `recv.name(..)` — only via a (receiver type, name) impl match;
    //     an untraceable receiver produces no edge.
    //   * `Type::name(..)` — impl match, else nothing (std assoc fns).
    //   * `name(..)` / `module::name(..)` — free fns only.
    let resolve_call = |c: &Call| -> Vec<usize> {
        if let Some(h) = &c.hint {
            if let Some(&i) = by_key.get(&(h.clone(), c.name.clone())) {
                return vec![i];
            }
        }
        if c.dotted {
            return Vec::new();
        }
        if c.hint.as_deref().and_then(|h| h.chars().next()).map(|ch| ch.is_uppercase()) == Some(true)
        {
            return Vec::new();
        }
        by_name
            .get(c.name.as_str())
            .map(|v| v.iter().copied().filter(|&i| bodies[i].0.impl_type.is_none()).collect())
            .unwrap_or_default()
    };
    // Fixpoint: summary = locks acquired by the fn or anything it calls.
    let mut summaries: Vec<BTreeSet<String>> =
        bodies.iter().map(|(_, b)| b.direct.clone()).collect();
    loop {
        let mut changed = false;
        for i in 0..bodies.len() {
            let mut add = BTreeSet::new();
            for c in &bodies[i].1.calls {
                for j in resolve_call(c) {
                    for l in &summaries[j] {
                        if !summaries[i].contains(l) {
                            add.insert(l.clone());
                        }
                    }
                }
            }
            if !add.is_empty() {
                summaries[i].extend(add);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    // Edge set with a representative site per (from, to).
    let mut edges: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    for (_, b) in &bodies {
        for (from, to, line) in &b.direct_edges {
            edges
                .entry((from.clone(), to.clone()))
                .or_insert_with(|| (b.file.clone(), *line));
        }
        for (held, call, line) in &b.held_calls {
            for j in resolve_call(call) {
                for m in &summaries[j] {
                    edges
                        .entry((held.clone(), m.clone()))
                        .or_insert_with(|| (b.file.clone(), *line));
                }
            }
        }
    }
    // Cycles: self-edges, then any edge whose reverse reachability closes
    // a loop.
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from.as_str()).or_default().insert(to.as_str());
    }
    let reach = |from: &str, to: &str| -> bool {
        let mut seen = BTreeSet::new();
        let mut stack = vec![from];
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if !seen.insert(n) {
                continue;
            }
            if let Some(ns) = adj.get(n) {
                stack.extend(ns.iter().copied());
            }
        }
        false
    };
    let mut out = Vec::new();
    let mut reported: BTreeSet<(String, String)> = BTreeSet::new();
    for ((from, to), (file, line)) in &edges {
        if from == to {
            out.push(Finding::new(
                RULE,
                file,
                *line,
                format!("lock `{from}` acquired while already held (re-entrant deadlock)"),
            ));
            continue;
        }
        if reach(to, from) {
            let key = if from < to {
                (from.clone(), to.clone())
            } else {
                (to.clone(), from.clone())
            };
            if reported.insert(key) {
                out.push(Finding::new(
                    RULE,
                    file,
                    *line,
                    format!(
                        "cyclic lock order: `{from}` held while acquiring `{to}`, but a \
                         path also acquires `{from}` while holding `{to}`"
                    ),
                ));
            }
        }
    }
    out
}

#[derive(Clone)]
struct Guard {
    lock: String,
    var: Option<String>,
    depth: i32,
}

fn scan_body(toks: &[Token], code: &[usize], f: &FnInfo, world: &LockWorld, file: &str) -> Body {
    let mut b = Body { file: file.to_string(), ..Body::default() };
    let (start, end) = f.body;
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0i32;
    let mut stmt_start = start;
    let mut ci = start;
    while ci < end.min(code.len()) {
        let t = &toks[code[ci]];
        match t.text.as_str() {
            "{" if t.kind == TokenKind::Punct => {
                depth += 1;
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            "}" if t.kind == TokenKind::Punct => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            // A `;` is a statement boundary at any paren depth — inside
            // parens it can only sit in a closure body, where it ends a
            // statement of that closure.
            ";" if t.kind == TokenKind::Punct => {
                guards.retain(|g| !(g.var.is_none() && g.depth >= depth));
                stmt_start = ci + 1;
                ci += 1;
                continue;
            }
            _ => {}
        }
        if t.kind == TokenKind::Ident {
            let next_open = code
                .get(ci + 1)
                .map(|&j| toks[j].is(TokenKind::Punct, "("))
                .unwrap_or(false);
            let prev_dot = ci > 0 && toks[code[ci - 1]].is(TokenKind::Punct, ".");
            // drop(g) releases a named guard early.
            if t.text == "drop" && next_open && !prev_dot {
                if let Some(&vj) = code.get(ci + 2) {
                    if toks[vj].kind == TokenKind::Ident {
                        let v = toks[vj].text.clone();
                        guards.retain(|g| g.var.as_deref() != Some(v.as_str()));
                    }
                }
                ci += 1;
                continue;
            }
            let is_acquire_name =
                t.text == "lock" || t.text == "read" || t.text == "write";
            if is_acquire_name && next_open && prev_dot {
                let empty = code
                    .get(ci + 2)
                    .map(|&j| toks[j].is(TokenKind::Punct, ")"))
                    .unwrap_or(false);
                if t.text == "lock" || empty {
                    let chain = receiver_chain(toks, code, ci);
                    if let Some(lock) =
                        world.lock_of(&chain, f.impl_type.as_deref())
                    {
                        for g in &guards {
                            b.direct_edges.push((g.lock.clone(), lock.clone(), t.line));
                        }
                        b.direct.insert(lock.clone());
                        let var = guard_binding(toks, code, ci, stmt_start);
                        guards.push(Guard { lock, var, depth });
                        ci += 1;
                        continue;
                    }
                }
            }
            // Plain or method call — candidate for call-graph edges.
            if next_open
                && !KEYWORDS.contains(&t.text.as_str())
                && t.text != "unwrap"
                && t.text != "expect"
            {
                let (hint, dotted) = if prev_dot {
                    let chain = receiver_chain(toks, code, ci);
                    let h = if chain.last().map(|s| s.as_str()) == Some("self") {
                        f.impl_type.clone()
                    } else {
                        // Receiver type = type of the chain's last
                        // segment (`shared.queue.push(..)` -> queue's
                        // struct), when uniquely named.
                        chain
                            .last()
                            .and_then(|x| world.field_struct.get(x))
                            .filter(|s| s.len() == 1)
                            .and_then(|s| s.iter().next().cloned())
                    };
                    (h, true)
                } else if ci > 0 && toks[code[ci - 1]].is(TokenKind::Punct, "::") {
                    let h = ci.checked_sub(2).and_then(|k| code.get(k)).and_then(|&j| {
                        let p = &toks[j];
                        if p.kind == TokenKind::Ident {
                            if p.text == "Self" {
                                f.impl_type.clone()
                            } else {
                                Some(p.text.clone())
                            }
                        } else {
                            None
                        }
                    });
                    (h, false)
                } else {
                    (None, false)
                };
                let call = Call { name: t.text.clone(), hint, dotted };
                for g in &guards {
                    b.held_calls.push((g.lock.clone(), call.clone(), t.line));
                }
                b.calls.push(call);
            }
        }
        ci += 1;
    }
    b
}

/// Named binding when the acquisition at `ci` ends a `let g = ….lock()
/// .unwrap();` / `.expect(..);` statement; None means a temporary.
fn guard_binding(
    toks: &[Token],
    code: &[usize],
    ci: usize,
    stmt_start: usize,
) -> Option<String> {
    // Past the acquisition's `( )`: expect `. unwrap|expect ( … ) ;`.
    let mut k = ci + 1; // at `(`
    let mut depth = 0i32;
    while let Some(&j) = code.get(k) {
        if toks[j].is(TokenKind::Punct, "(") {
            depth += 1;
        } else if toks[j].is(TokenKind::Punct, ")") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        k += 1;
    }
    if !code.get(k + 1).map(|&j| toks[j].is(TokenKind::Punct, ".")).unwrap_or(false) {
        return None;
    }
    let m = code.get(k + 2).map(|&j| &toks[j])?;
    if m.kind != TokenKind::Ident || (m.text != "unwrap" && m.text != "expect") {
        return None;
    }
    let mut k2 = k + 3;
    let mut depth = 0i32;
    while let Some(&j) = code.get(k2) {
        if toks[j].is(TokenKind::Punct, "(") {
            depth += 1;
        } else if toks[j].is(TokenKind::Punct, ")") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        k2 += 1;
    }
    if !code.get(k2 + 1).map(|&j| toks[j].is(TokenKind::Punct, ";")).unwrap_or(false) {
        return None;
    }
    // Statement must start with `let [mut] name`.
    if !code.get(stmt_start).map(|&j| toks[j].is(TokenKind::Ident, "let")).unwrap_or(false) {
        return None;
    }
    let mut n = stmt_start + 1;
    if code.get(n).map(|&j| toks[j].is(TokenKind::Ident, "mut")).unwrap_or(false) {
        n += 1;
    }
    code.get(n).and_then(|&j| {
        if toks[j].kind == TokenKind::Ident {
            Some(toks[j].text.clone())
        } else {
            None
        }
    })
}
