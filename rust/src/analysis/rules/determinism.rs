//! determinism: the modules whose behaviour feeds pinned counters in
//! tier-1 tests — KV-cache keying/eviction (`runtime/kvcache.rs`),
//! pool rank order (`util/pool.rs`), and shard-plan splitting /
//! pipeline sequencing (`coordinator/cluster/shard.rs`) — may not read
//! wall clocks (`Instant::now`, `SystemTime`) or depend on `HashMap`
//! iteration order. Logical tick counters and sorted containers keep
//! replays byte-identical.

use std::collections::BTreeSet;

use crate::analysis::lexer::{test_mask, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::{resolve, Crate};

pub const RULE: &str = "determinism";

const TIER: &[&str] =
    &["runtime/kvcache.rs", "util/pool.rs", "coordinator/cluster/shard.rs"];

const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "keys", "values", "values_mut", "drain", "retain", "into_iter"];

pub fn check(krate: &Crate) -> Vec<Finding> {
    // Names of HashMap-typed fields declared in tier files (iteration
    // over them is order-nondeterministic).
    let mut hashmap_fields: BTreeSet<String> = BTreeSet::new();
    for f in resolve::struct_fields(krate) {
        if TIER.contains(&f.file.as_str()) && f.type_text.split(' ').any(|w| w == "HashMap") {
            hashmap_fields.insert(f.field);
        }
    }
    let mut out = Vec::new();
    for sf in &krate.files {
        if !TIER.contains(&sf.path.as_str()) {
            continue;
        }
        let toks = &sf.tokens;
        let mask = test_mask(toks);
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        for ci in 0..code.len() {
            let idx = code[ci];
            let t = &toks[idx];
            if t.kind != TokenKind::Ident || mask[idx] {
                continue;
            }
            let next_is = |off: usize, text: &str| {
                code.get(ci + off).map(|&j| toks[j].is(TokenKind::Punct, text)).unwrap_or(false)
            };
            if t.text == "Instant"
                && next_is(1, "::")
                && code
                    .get(ci + 2)
                    .map(|&j| toks[j].is(TokenKind::Ident, "now"))
                    .unwrap_or(false)
            {
                out.push(Finding::new(
                    RULE,
                    &sf.path,
                    t.line,
                    "Instant::now in a determinism-tier module".to_string(),
                ));
                continue;
            }
            if t.text == "SystemTime" {
                out.push(Finding::new(
                    RULE,
                    &sf.path,
                    t.line,
                    "SystemTime in a determinism-tier module".to_string(),
                ));
                continue;
            }
            if hashmap_fields.contains(&t.text) && next_is(1, ".") {
                if let Some(&mj) = code.get(ci + 2) {
                    let m = &toks[mj];
                    if m.kind == TokenKind::Ident && ITER_METHODS.contains(&m.text.as_str()) {
                        out.push(Finding::new(
                            RULE,
                            &sf.path,
                            m.line,
                            format!(
                                "HashMap iteration (`{}.{}`) in a determinism-tier module",
                                t.text, m.text
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}
