//! import-resolution: every `use crate::…`/`lieq::…` path and every
//! inline `crate::`/`lieq::`-qualified expression path must resolve to
//! a declared module or item. Replaces the ad-hoc Python import sweeps
//! from earlier PRs.

use crate::analysis::lexer::TokenKind;
use crate::analysis::report::Finding;
use crate::analysis::resolve::{parse_use_tree, ModuleMap};
use crate::analysis::Crate;

pub const RULE: &str = "import-resolution";

pub fn check(krate: &Crate) -> Vec<Finding> {
    let map = ModuleMap::build(krate);
    let mut out = Vec::new();
    for sf in &krate.files {
        let toks = &sf.tokens;
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        let mut ci = 0usize;
        while ci < code.len() {
            let t = &toks[code[ci]];
            if t.is(TokenKind::Ident, "use") {
                let line = t.line;
                let (paths, end) = parse_use_tree(toks, &code, ci + 1);
                for (p, _visible) in paths {
                    let Some(first) = p.first() else { continue };
                    if first != "crate" && first != "lieq" {
                        continue;
                    }
                    let mut segs = p.clone();
                    segs[0] = "crate".to_string();
                    if let Err(why) = map.resolve(&segs) {
                        out.push(Finding::new(
                            RULE,
                            &sf.path,
                            line,
                            format!("unresolved import `{}`: {}", p.join("::"), why),
                        ));
                    }
                }
                ci = end;
                continue;
            }
            // Inline qualified path: `crate::a::b` / `lieq::a::b` in
            // expression or type position.
            if (t.is(TokenKind::Ident, "crate") || t.is(TokenKind::Ident, "lieq"))
                && code.get(ci + 1).map(|&j| toks[j].is(TokenKind::Punct, "::")).unwrap_or(false)
            {
                let line = t.line;
                let mut segs = vec!["crate".to_string()];
                let mut cj = ci + 1;
                while code.get(cj).map(|&j| toks[j].is(TokenKind::Punct, "::")).unwrap_or(false) {
                    match code.get(cj + 1) {
                        Some(&j) if toks[j].kind == TokenKind::Ident => {
                            segs.push(toks[j].text.clone());
                            cj += 2;
                        }
                        _ => break, // turbofish `::<` or macro path end
                    }
                }
                if segs.len() > 1 {
                    if let Err(why) = map.resolve(&segs) {
                        out.push(Finding::new(
                            RULE,
                            &sf.path,
                            line,
                            format!("unresolved path `{}`: {}", segs.join("::"), why),
                        ));
                    }
                }
                ci = cj;
                continue;
            }
            ci += 1;
        }
    }
    out
}
