//! counter-monotonicity: fields of `*Stats` structs are cumulative
//! counters consumed by delta-windowing readers — they may be
//! incremented (`+=`, `x.f = x.f.saturating_add(..)`, `fetch_add`) but
//! never plainly reassigned, decremented, or `fetch_sub`'d outside the
//! allowlisted windowing fns (`reset`, `clear`, `delta_from`, plus
//! constructors).
//!
//! Only `self`-rooted or multi-segment field-path receivers are live
//! shared counters; a single-ident receiver is a fn-local snapshot
//! value under construction (`let mut s = DqKernelStats::…; s.f = 1;`)
//! and is exempt.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{test_mask, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::rules::{index_file, receiver_chain};
use crate::analysis::{resolve, Crate};

pub const RULE: &str = "counter-monotonicity";

const ALLOWED_FNS: &[&str] = &["reset", "clear", "delta_from", "new", "default"];

pub fn check(krate: &Crate) -> Vec<Finding> {
    // field name -> owning *Stats structs.
    let mut counter_fields: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in resolve::struct_fields(krate) {
        if f.strukt.ends_with("Stats") {
            counter_fields.entry(f.field).or_default().insert(f.strukt);
        }
    }
    if counter_fields.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for sf in &krate.files {
        let toks = &sf.tokens;
        let mask = test_mask(toks);
        let fx = index_file(sf);
        let code = &fx.code;
        // code position -> innermost enclosing fn name.
        let enclosing = |ci: usize| -> Option<&str> {
            fx.fns
                .iter()
                .filter(|f| f.body.0 <= ci && ci < f.body.1)
                .min_by_key(|f| f.body.1 - f.body.0)
                .map(|f| f.name.as_str())
        };
        for ci in 0..code.len() {
            let idx = code[ci];
            let t = &toks[idx];
            if t.kind != TokenKind::Ident || mask[idx] {
                continue;
            }
            let Some(owners) = counter_fields.get(&t.text) else { continue };
            if !(ci > 0 && toks[code[ci - 1]].is(TokenKind::Punct, ".")) {
                continue;
            }
            // See module docs: fn-local snapshot values are exempt.
            let chain = receiver_chain(toks, code, ci);
            if !chain.iter().any(|s| s == "self") && chain.len() < 2 {
                continue;
            }
            let Some(&nj) = code.get(ci + 1) else { continue };
            let nt = &toks[nj];
            let violation = if nt.is(TokenKind::Punct, "-=") {
                Some("decremented")
            } else if nt.is(TokenKind::Punct, "=") {
                // `x.f = x.f.saturating_add(..)` stays monotone.
                if rhs_is_monotone(toks, code, ci + 2, &t.text) {
                    None
                } else {
                    Some("reassigned")
                }
            } else if nt.is(TokenKind::Punct, ".")
                && code
                    .get(ci + 2)
                    .map(|&j| toks[j].is(TokenKind::Ident, "fetch_sub"))
                    .unwrap_or(false)
            {
                Some("fetch_sub'd")
            } else {
                None
            };
            let Some(verb) = violation else { continue };
            if enclosing(ci).map(|n| ALLOWED_FNS.contains(&n)).unwrap_or(false) {
                continue;
            }
            let owner = owners.iter().cloned().collect::<Vec<_>>().join("/");
            out.push(Finding::new(
                RULE,
                &sf.path,
                t.line,
                format!("counter field `{owner}.{}` {verb} outside reset/delta fns", t.text),
            ));
        }
    }
    out
}

/// RHS of `x.f = …;` keeps `f` monotone when it reads `f` back through a
/// non-decreasing op (`saturating_add`, `checked_add`, `wrapping_add`,
/// `max`, or a plain `f + …`).
fn rhs_is_monotone(
    toks: &[crate::analysis::lexer::Token],
    code: &[usize],
    start: usize,
    field: &str,
) -> bool {
    let mut saw_field = false;
    let mut saw_add = false;
    let mut cj = start;
    let mut paren = 0i32;
    while let Some(&j) = code.get(cj) {
        let t = &toks[j];
        match t.text.as_str() {
            "(" | "[" | "{" => paren += 1,
            ")" | "]" | "}" => paren -= 1,
            ";" | "," if paren <= 0 => break,
            "saturating_add" | "checked_add" | "wrapping_add" | "max" | "+" => saw_add = true,
            s if s == field => saw_field = true,
            _ => {}
        }
        cj += 1;
    }
    saw_field && saw_add
}
