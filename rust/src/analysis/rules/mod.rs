//! The rule set. Each rule is a pure function `Crate -> Vec<Finding>`;
//! the engine ([`crate::analysis::run_all`]) runs all of them and then
//! applies inline waivers.

pub mod counters;
pub mod determinism;
pub mod hygiene;
pub mod imports;
pub mod locks;
pub mod panics;

use crate::analysis::lexer::{Token, TokenKind};
use crate::analysis::report::Finding;
use crate::analysis::{Crate, SourceFile};

/// Registry entry: slug + short description + check fn.
pub struct Rule {
    pub name: &'static str,
    pub describe: &'static str,
    pub check: fn(&Crate) -> Vec<Finding>,
}

pub fn all_rules() -> Vec<Rule> {
    vec![
        Rule {
            name: imports::RULE,
            describe: "every `use crate::`/`lieq::` path resolves to a declared module/item",
            check: imports::check,
        },
        Rule {
            name: panics::RULE,
            describe: "no unwrap/expect/panic! in the hot-path tier outside tests \
                       (poisoned-mutex lock().unwrap() allowlisted)",
            check: panics::check,
        },
        Rule {
            name: locks::RULE,
            describe: "no cyclic Mutex/RwLock acquisition order across the call graph",
            check: locks::check,
        },
        Rule {
            name: counters::RULE,
            describe: "fields of *Stats structs are only incremented, never reassigned \
                       outside reset/delta windowing fns",
            check: counters::check,
        },
        Rule {
            name: determinism::RULE,
            describe: "no Instant::now/SystemTime/HashMap-iteration in modules feeding \
                       pinned counters",
            check: determinism::check,
        },
        Rule {
            name: hygiene::RULE,
            describe: "no #[deprecated] items; unsafe blocks carry SAFETY comments; \
                       archive size math is checked_*",
            check: hygiene::check,
        },
    ]
}

/// The hot-path tier: files whose production code must be panic-free.
pub fn hot_tier(path: &str) -> bool {
    path.starts_with("kernels/")
        || path.starts_with("coordinator/cluster/")
        || path == "coordinator/server.rs"
        || path == "runtime/kvcache.rs"
        || path == "runtime/cache.rs"
        || path == "util/pool.rs"
}

/// One function item: enclosing `impl` type head (None for free fns),
/// name, and the body as a half-open range over *code-token positions*
/// (indices into [`FileIndex::code`]) excluding the outer braces.
#[derive(Clone, Debug)]
pub struct FnInfo {
    pub impl_type: Option<String>,
    pub name: String,
    pub body: (usize, usize),
    pub line: u32,
}

/// Per-file structural index shared by rules: comment-free token
/// positions and the function table.
pub struct FileIndex {
    /// Indices of non-comment tokens, in order.
    pub code: Vec<usize>,
    pub fns: Vec<FnInfo>,
}

pub fn index_file(sf: &SourceFile) -> FileIndex {
    let toks = &sf.tokens;
    let code: Vec<usize> =
        (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
    let mut fns = Vec::new();
    // impl stack: (type head, brace depth inside the impl body).
    let mut impls: Vec<(String, i32)> = Vec::new();
    let mut depth = 0i32;
    let mut ci = 0usize;
    while ci < code.len() {
        let t = &toks[code[ci]];
        if t.is(TokenKind::Punct, "{") {
            depth += 1;
            ci += 1;
            continue;
        }
        if t.is(TokenKind::Punct, "}") {
            depth -= 1;
            while impls.last().map(|x| x.1 > depth).unwrap_or(false) {
                impls.pop();
            }
            ci += 1;
            continue;
        }
        if t.is(TokenKind::Ident, "impl") {
            if let Some((ty, open)) = parse_impl_head(toks, &code, ci) {
                impls.push((ty, depth + 1));
                depth += 1;
                ci = open + 1;
                continue;
            }
        }
        if t.is(TokenKind::Ident, "fn") {
            if let Some(&nidx) = code.get(ci + 1) {
                if toks[nidx].kind == TokenKind::Ident {
                    let name = toks[nidx].text.clone();
                    let line = toks[nidx].line;
                    // Find the body opener (or `;` for a bodyless trait
                    // method decl).
                    let mut cj = ci + 2;
                    let mut open = None;
                    while let Some(&j) = code.get(cj) {
                        if toks[j].is(TokenKind::Punct, "{") {
                            open = Some(cj);
                            break;
                        }
                        if toks[j].is(TokenKind::Punct, ";") {
                            break;
                        }
                        cj += 1;
                    }
                    if let Some(open) = open {
                        // Matching close brace.
                        let mut d = 0i32;
                        let mut ck = open;
                        let mut close = code.len();
                        while let Some(&j) = code.get(ck) {
                            if toks[j].is(TokenKind::Punct, "{") {
                                d += 1;
                            } else if toks[j].is(TokenKind::Punct, "}") {
                                d -= 1;
                                if d == 0 {
                                    close = ck;
                                    break;
                                }
                            }
                            ck += 1;
                        }
                        let impl_type = impls
                            .iter()
                            .rev()
                            .find(|x| x.1 <= depth)
                            .map(|x| x.0.clone());
                        fns.push(FnInfo { impl_type, name, body: (open + 1, close), line });
                        // Continue scanning *inside* the body too (for
                        // nested fns — rare, but index them as well).
                        ci += 2;
                        continue;
                    }
                }
            }
        }
        ci += 1;
    }
    FileIndex { code, fns }
}

/// The dotted receiver chain before a method or field ident at code
/// position `ci`: for `self.ctx.queued.lock()` with `ci` at `lock`,
/// returns `[self, ctx, queued]`. Chains interrupted by calls/indexing
/// return the traceable suffix only.
pub fn receiver_chain(toks: &[Token], code: &[usize], ci: usize) -> Vec<String> {
    let mut rev = Vec::new();
    let mut k = ci; // points at the ident; walk `.` ident pairs back
    while k >= 2
        && toks[code[k - 1]].is(TokenKind::Punct, ".")
        && toks[code[k - 2]].kind == TokenKind::Ident
    {
        rev.push(toks[code[k - 2]].text.clone());
        k -= 2;
    }
    rev.reverse();
    rev
}

/// Parse `impl ...` head starting at code position `ci` (the `impl`
/// token). Returns `(type head ident, code position of the body '{')`.
fn parse_impl_head(toks: &[Token], code: &[usize], ci: usize) -> Option<(String, usize)> {
    let mut angle = 0i32;
    let mut after_for: Option<usize> = None;
    let mut open = None;
    let mut cj = ci + 1;
    while let Some(&j) = code.get(cj) {
        let t = &toks[j];
        match t.text.as_str() {
            "<" => angle += 1,
            ">" => angle -= 1,
            ">>" => angle -= 2,
            "for" if angle <= 0 => after_for = Some(cj + 1),
            "{" if angle <= 0 => {
                open = Some(cj);
                break;
            }
            ";" if angle <= 0 => return None,
            _ => {}
        }
        cj += 1;
    }
    let open = open?;
    let from = after_for.unwrap_or(ci + 1);
    // First ident at angle depth 0 in [from, open) — skip `&`, lifetimes,
    // generic params before it.
    let mut angle = 0i32;
    for &j in code.get(from..open)? {
        let t = &toks[j];
        match t.text.as_str() {
            "<" => angle += 1,
            ">" => angle -= 1,
            ">>" => angle -= 2,
            _ => {
                if angle <= 0 && t.kind == TokenKind::Ident && t.text != "dyn" && t.text != "mut" {
                    return Some((t.text.clone(), open));
                }
            }
        }
    }
    Some(("?".to_string(), open))
}
