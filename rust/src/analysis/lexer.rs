//! Comment/string/raw-string-aware Rust token scanner.
//!
//! Zero dependencies (no `syn`, no proc-macro machinery — the offline
//! registry has neither): a hand-rolled maximal-munch lexer producing
//! just enough structure for the token-pattern rules in
//! [`crate::analysis::rules`]. Comments are **retained** as tokens —
//! the waiver syntax (`// lint: allow(<rule>) — why`) and the
//! `// SAFETY:` contract live in them.
//!
//! Handled edge cases (pinned in `rust/tests/lint.rs`):
//! * nested block comments (`/* a /* b */ c */` is one token),
//! * raw strings with any hash depth (`r#"..."#`, `br##"..."##`),
//! * byte strings (`b"..."`) and escapes inside ordinary strings,
//! * char literals vs lifetimes (`'a'` is a char, `'a` a lifetime,
//!   `'\u{1F600}'` an escaped char),
//! * glued multi-char operators (`::`, `->`, `+=`, `>>`, …) so rules
//!   can tell `=` (assignment) from `==`/`=>`/`>=` by a single token.

/// Lexical class of one [`Token`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Lifetime,
    Num,
    /// `"..."`, `b"..."` — escapes consumed, delimiters included.
    Str,
    /// `r"..."` / `r#"..."#` / `br#"..."#` — no escape processing.
    RawStr,
    /// `'x'`, `'\n'`, `'\u{..}'`.
    Char,
    /// `// ...` or `/* ... */` (nested), delimiters included.
    Comment,
    /// One operator/punctuation token (multi-char ops glued).
    Punct,
}

/// One lexed token. `text` includes delimiters for strings/comments;
/// `line` is 1-based and points at the token's first character.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is(&self, kind: TokenKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

/// Multi-char operators, longest first (maximal munch).
const OPS: &[&str] = &[
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
    "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>", "..",
];

/// Lex `text` into tokens. Unterminated strings/comments consume to the
/// end of input rather than erroring — a lint scanner must degrade, not
/// die, on the file it is about to report on.
pub fn lex(text: &str) -> Vec<Token> {
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    // Count newlines inside `b[from..to]` into `line`.
    let bump = |from: usize, to: usize, line: &mut u32, b: &[char]| {
        for &c in &b[from..to.min(b.len())] {
            if c == '\n' {
                *line += 1;
            }
        }
    };

    while i < n {
        let c = b[i];
        let start_line = line;
        // Whitespace.
        if c.is_whitespace() {
            if c == '\n' {
                line += 1;
            }
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let s = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.push(tok(TokenKind::Comment, &b[s..i], start_line));
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let s = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            bump(s, i, &mut line, &b);
            out.push(tok(TokenKind::Comment, &b[s..i], start_line));
            continue;
        }
        // Raw strings: r"..." / r#"..."# / br"..." / br#"..."#.
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 2;
            } else if b[j] == 'r' {
                j += 1;
            } else {
                j = usize::MAX; // plain b"..." handled below
            }
            if j != usize::MAX && j < n && (b[j] == '"' || b[j] == '#') {
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    j += 1; // past opening quote
                    'scan: while j < n {
                        if b[j] == '"' {
                            let mut k = 0usize;
                            while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    bump(i, j, &mut line, &b);
                    out.push(tok(TokenKind::RawStr, &b[i..j], start_line));
                    i = j;
                    continue;
                }
            }
        }
        // Byte string b"..." (b not followed by r/" falls through to ident).
        if c == 'b' && i + 1 < n && b[i + 1] == '"' {
            let j = scan_str(&b, i + 1);
            bump(i, j, &mut line, &b);
            out.push(tok(TokenKind::Str, &b[i..j], start_line));
            i = j;
            continue;
        }
        // Ordinary string.
        if c == '"' {
            let j = scan_str(&b, i);
            bump(i, j, &mut line, &b);
            out.push(tok(TokenKind::Str, &b[i..j], start_line));
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char: consume to the closing quote.
                let mut j = i + 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                j = (j + 1).min(n);
                out.push(tok(TokenKind::Char, &b[i..j], start_line));
                i = j;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                out.push(tok(TokenKind::Char, &b[i..i + 3], start_line));
                i += 3;
                continue;
            }
            // Lifetime: 'ident (no closing quote).
            let mut j = i + 1;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            out.push(tok(TokenKind::Lifetime, &b[i..j], start_line));
            i = j.max(i + 1);
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            // Fractional part — but never eat a `..` range operator.
            if j < n && b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
            }
            out.push(tok(TokenKind::Num, &b[i..j], start_line));
            i = j;
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            out.push(tok(TokenKind::Ident, &b[i..j], start_line));
            i = j;
            continue;
        }
        // Glued operators, longest first.
        let mut matched = false;
        for op in OPS {
            let oc: Vec<char> = op.chars().collect();
            if i + oc.len() <= n && b[i..i + oc.len()] == oc[..] {
                out.push(tok(TokenKind::Punct, &b[i..i + oc.len()], start_line));
                i += oc.len();
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        out.push(tok(TokenKind::Punct, &b[i..i + 1], start_line));
        i += 1;
    }
    out
}

fn tok(kind: TokenKind, chars: &[char], line: u32) -> Token {
    Token { kind, text: chars.iter().collect(), line }
}

/// Scan an ordinary string starting at the opening quote `b[i] == '"'`;
/// returns the index just past the closing quote.
fn scan_str(b: &[char], i: usize) -> usize {
    let n = b.len();
    let mut j = i + 1;
    while j < n {
        match b[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

/// Per-token mask: `true` for every token inside a `#[cfg(test)]` item
/// (attribute included) or a `#[test]` function. Rules use this to
/// exempt test code from production contracts.
pub fn test_mask(tokens: &[Token]) -> Vec<bool> {
    let code: Vec<usize> =
        (0..tokens.len()).filter(|&i| tokens[i].kind != TokenKind::Comment).collect();
    let mut mask = vec![false; tokens.len()];
    let mut ci = 0usize;
    while ci < code.len() {
        if is_test_attr_at(tokens, &code, ci) {
            let start = code[ci];
            // Consume this attribute, any further attributes, then the
            // item itself (to its closing brace, or a terminating `;`).
            let mut cj = skip_attr(tokens, &code, ci);
            while cj < code.len() && tokens[code[cj]].is(TokenKind::Punct, "#") {
                cj = skip_attr(tokens, &code, cj);
            }
            let mut depth = 0i32;
            while cj < code.len() {
                let t = &tokens[code[cj]];
                if t.is(TokenKind::Punct, "{") {
                    depth += 1;
                } else if t.is(TokenKind::Punct, "}") {
                    depth -= 1;
                    if depth == 0 {
                        cj += 1;
                        break;
                    }
                } else if depth == 0 && t.is(TokenKind::Punct, ";") {
                    cj += 1;
                    break;
                }
                cj += 1;
            }
            let end = if cj < code.len() { code[cj] } else { tokens.len() };
            for m in mask.iter_mut().take(end).skip(start) {
                *m = true;
            }
            ci = cj;
        } else {
            ci += 1;
        }
    }
    mask
}

/// Does the code-token position `ci` start a test attribute? `#[test]`
/// and `#[cfg(test)]`-like forms count (`cfg(all(test, ...))` too); a
/// negated `#[cfg(not(test))]` is live production code and does not.
fn is_test_attr_at(tokens: &[Token], code: &[usize], ci: usize) -> bool {
    if !tokens[code[ci]].is(TokenKind::Punct, "#") {
        return false;
    }
    if ci + 1 >= code.len() || !tokens[code[ci + 1]].is(TokenKind::Punct, "[") {
        return false;
    }
    let mut depth = 0i32;
    let mut head: Option<String> = None;
    let mut saw_test = false;
    let mut saw_not = false;
    for &idx in &code[ci + 1..] {
        let t = &tokens[idx];
        if t.is(TokenKind::Punct, "[") {
            depth += 1;
        } else if t.is(TokenKind::Punct, "]") {
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if t.kind == TokenKind::Ident {
            if head.is_none() {
                head = Some(t.text.clone());
            }
            match t.text.as_str() {
                "test" => saw_test = true,
                "not" => saw_not = true,
                _ => {}
            }
        }
    }
    match head.as_deref() {
        Some("test") => true,
        Some("cfg") => saw_test && !saw_not,
        _ => false,
    }
}

/// Skip one `#[...]` attribute starting at code position `ci`; returns
/// the code position just past its closing `]`.
fn skip_attr(tokens: &[Token], code: &[usize], ci: usize) -> usize {
    let mut cj = ci + 1; // at `[`
    let mut depth = 0i32;
    while cj < code.len() {
        let t = &tokens[code[cj]];
        if t.is(TokenKind::Punct, "[") {
            depth += 1;
        } else if t.is(TokenKind::Punct, "]") {
            depth -= 1;
            if depth == 0 {
                return cj + 1;
            }
        }
        cj += 1;
    }
    code.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn nested_block_comment_is_one_token() {
        let ts = kinds("a /* x /* y */ z */ b");
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[1].0, TokenKind::Comment);
        assert_eq!(ts[1].1, "/* x /* y */ z */");
    }

    #[test]
    fn raw_string_with_hashes_and_quotes() {
        let ts = kinds(r####"let s = r#"he said "hi""#;"####);
        assert!(ts.iter().any(|(k, t)| *k == TokenKind::RawStr && t.contains("he said")));
        // Nothing inside the raw string leaked as separate tokens.
        assert!(!ts.iter().any(|(_, t)| t == "hi"));
    }

    #[test]
    fn char_vs_lifetime() {
        let ts = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let chars: Vec<_> = ts.iter().filter(|(k, _)| *k == TokenKind::Char).collect();
        let lifetimes: Vec<_> = ts.iter().filter(|(k, _)| *k == TokenKind::Lifetime).collect();
        assert_eq!(chars.len(), 2);
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(lifetimes[0].1, "'a");
    }

    #[test]
    fn glued_operators() {
        let ts = kinds("a += b; c == d; e => f; g :: h; i >>= 2;");
        let puncts: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokenKind::Punct)
            .map(|(_, t)| t.as_str())
            .collect();
        assert!(puncts.contains(&"+="));
        assert!(puncts.contains(&"=="));
        assert!(puncts.contains(&"=>"));
        assert!(puncts.contains(&"::"));
        assert!(puncts.contains(&">>="));
    }

    #[test]
    fn string_escapes_do_not_end_string() {
        let ts = kinds(r#"let s = "a \" b"; x"#);
        let strs: Vec<_> = ts.iter().filter(|(k, _)| *k == TokenKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert_eq!(strs[0].1, r#""a \" b""#);
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests { fn t() { y.unwrap(); } }";
        let toks = lex(src);
        let mask = test_mask(&toks);
        let unwraps: Vec<bool> = toks
            .iter()
            .zip(&mask)
            .filter(|(t, _)| t.text == "unwrap")
            .map(|(_, &m)| m)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let ts = kinds("for i in 0..10 { let f = 1.5e3; }");
        assert!(ts.iter().any(|(k, t)| *k == TokenKind::Punct && t == ".."));
        assert!(ts.iter().any(|(k, t)| *k == TokenKind::Num && t == "1.5e3"));
    }
}
