//! Crate shape extraction: the module tree with per-module item
//! namespaces (for import resolution) and struct-field metadata shared
//! by the lock-order / counter / determinism rules.
//!
//! `main.rs` is a separate binary crate: it *consumes* `lieq::` paths
//! but contributes nothing to the library namespace, so it is indexed
//! as a consumer only.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{Token, TokenKind};
use crate::analysis::{Crate, SourceFile};

/// One module's namespace.
#[derive(Default, Debug)]
pub struct Module {
    /// Items declared here (fns, structs, enums, traits, types, consts,
    /// statics, macros) plus named `pub use` re-exports.
    pub items: BTreeSet<String>,
    pub submodules: BTreeSet<String>,
    /// Module paths glob-re-exported into this namespace (`pub use m::*`).
    pub globs: Vec<String>,
}

/// The crate's module tree, keyed by absolute path (`crate`,
/// `crate::util`, `crate::util::pool`, …).
#[derive(Default, Debug)]
pub struct ModuleMap {
    pub modules: BTreeMap<String, Module>,
}

/// A named struct field (named-field structs only).
#[derive(Clone, Debug)]
pub struct StructField {
    pub strukt: String,
    pub field: String,
    /// Field type as space-joined tokens, e.g. `Mutex < BTreeMap < String , u64 > >`.
    pub type_text: String,
    /// First ident of the type (`Mutex`, `TaskQueue`, …).
    pub type_head: String,
    pub file: String,
    pub line: u32,
}

/// A module-level `static NAME: Type`.
#[derive(Clone, Debug)]
pub struct StaticItem {
    pub name: String,
    pub type_text: String,
    pub file: String,
    pub line: u32,
}

/// File path (relative, slash-separated) -> module path, or `None` for
/// files that do not define library modules (`main.rs`).
pub fn module_path_of(file: &str) -> Option<String> {
    if file == "main.rs" {
        return None;
    }
    if file == "lib.rs" {
        return Some("crate".to_string());
    }
    let stem = file.strip_suffix(".rs").unwrap_or(file);
    let mut segs: Vec<&str> = stem.split('/').collect();
    if segs.last() == Some(&"mod") {
        segs.pop();
    }
    let mut path = "crate".to_string();
    for s in segs {
        path.push_str("::");
        path.push_str(s);
    }
    Some(path)
}

impl ModuleMap {
    pub fn build(krate: &Crate) -> ModuleMap {
        let mut map = ModuleMap::default();
        map.modules.entry("crate".to_string()).or_default();
        // Submodule edges from the file layout.
        for sf in &krate.files {
            let Some(mp) = module_path_of(&sf.path) else { continue };
            map.modules.entry(mp.clone()).or_default();
            if let Some(pos) = mp.rfind("::") {
                let (parent, name) = (mp[..pos].to_string(), mp[pos + 2..].to_string());
                map.modules.entry(parent.clone()).or_default().submodules.insert(name.clone());
                map.modules.entry(parent).or_default().items.insert(name);
            }
        }
        for sf in &krate.files {
            let Some(mp) = module_path_of(&sf.path) else { continue };
            index_file(&mut map, &mp, sf);
        }
        map
    }

    fn module(&self, path: &str) -> Option<&Module> {
        self.modules.get(path)
    }

    /// Is `name` reachable as an item of module `path` (directly, as a
    /// submodule, or through a chain of glob re-exports)?
    pub fn has_item(&self, path: &str, name: &str) -> bool {
        let mut seen = BTreeSet::new();
        self.has_item_inner(path, name, &mut seen)
    }

    fn has_item_inner(&self, path: &str, name: &str, seen: &mut BTreeSet<String>) -> bool {
        if !seen.insert(path.to_string()) {
            return false;
        }
        let Some(m) = self.module(path) else { return false };
        if m.items.contains(name) || m.submodules.contains(name) {
            return true;
        }
        m.globs.iter().any(|g| self.has_item_inner(g, name, seen))
    }

    /// Resolve an absolute path (`segs[0]` is `crate`). Returns `Err`
    /// with a human-readable reason when any segment fails. Trailing
    /// segments *after* the first item segment are associated items
    /// (`Type::new`) and are not checked.
    pub fn resolve(&self, segs: &[String]) -> Result<(), String> {
        let mut cur = "crate".to_string();
        let mut i = 1usize;
        while i < segs.len() {
            let seg = &segs[i];
            if seg == "*" {
                return Ok(()); // glob import of a verified module prefix
            }
            if seg == "self" {
                i += 1; // `use crate::m::{self, ..}` — stays at `cur`
                continue;
            }
            let child = format!("{cur}::{seg}");
            if self.modules.contains_key(&child) {
                cur = child;
                i += 1;
                continue;
            }
            if self.has_item(&cur, seg) {
                return Ok(()); // item found; rest is associated-item space
            }
            return Err(format!("`{}` not found in `{cur}`", seg));
        }
        Ok(()) // path names a module
    }
}

/// Index one file's top-level declarations into its module (tracking
/// inline `mod name { ... }` scopes).
fn index_file(map: &mut ModuleMap, module_path: &str, sf: &SourceFile) {
    let toks = &sf.tokens;
    let code: Vec<usize> =
        (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
    // Stack of (module path, base depth).
    let mut stack: Vec<(String, i32)> = vec![(module_path.to_string(), 0)];
    let mut depth = 0i32;
    let mut ci = 0usize;
    while ci < code.len() {
        let t = &toks[code[ci]];
        if t.is(TokenKind::Punct, "{") {
            depth += 1;
            ci += 1;
            continue;
        }
        if t.is(TokenKind::Punct, "}") {
            depth -= 1;
            while stack.len() > 1 && depth < stack.last().map(|s| s.1).unwrap_or(0) {
                stack.pop();
            }
            ci += 1;
            continue;
        }
        let at_module_level = depth == stack.last().map(|s| s.1).unwrap_or(0);
        if at_module_level && t.kind == TokenKind::Ident {
            let cur = stack.last().map(|s| s.0.clone()).unwrap_or_default();
            match t.text.as_str() {
                "mod" => {
                    if let Some(name) = ident_at(toks, &code, ci + 1) {
                        let m = map.modules.entry(cur.clone()).or_default();
                        m.submodules.insert(name.clone());
                        m.items.insert(name.clone());
                        let child = format!("{cur}::{name}");
                        map.modules.entry(child.clone()).or_default();
                        // Inline body? (`mod x { ... }` vs `mod x;`)
                        let has_body = code
                            .get(ci + 2)
                            .map(|&j| toks[j].is(TokenKind::Punct, "{"))
                            .unwrap_or(false);
                        if has_body {
                            stack.push((child, depth + 1));
                        }
                    }
                }
                "fn" | "struct" | "enum" | "trait" | "type" | "const" | "static" | "union" => {
                    if let Some(name) = ident_at(toks, &code, ci + 1) {
                        map.modules.entry(cur).or_default().items.insert(name);
                    }
                }
                "macro_rules" => {
                    // `macro_rules! name` — exported macros land at the
                    // crate root; declare in both namespaces.
                    if let Some(name) = ident_at(toks, &code, ci + 2) {
                        map.modules.entry(cur).or_default().items.insert(name.clone());
                        map.modules.entry("crate".to_string()).or_default().items.insert(name);
                    }
                }
                "use" => {
                    // Only `pub use` extends the module namespace:
                    // accept `pub use` and `pub(crate/super/in ..) use`
                    // by scanning back over a possible `(..)` group.
                    let is_pub = {
                        let mut k = ci;
                        let mut saw = false;
                        while k > 0 && ci - k < 6 {
                            k -= 1;
                            let p = &toks[code[k]];
                            if p.is(TokenKind::Ident, "pub") {
                                saw = true;
                                break;
                            }
                            let chained = p.is(TokenKind::Punct, ")")
                                || p.is(TokenKind::Punct, "(")
                                || p.kind == TokenKind::Ident;
                            if !chained {
                                break;
                            }
                        }
                        saw
                    };
                    let (paths, end) = parse_use_tree(toks, &code, ci + 1);
                    if is_pub {
                        for (p, visible) in &paths {
                            match p.last().map(|s| s.as_str()) {
                                Some("*") => {
                                    // Glob re-export: record the source
                                    // module path when it is absolute.
                                    if p.first().map(|s| s.as_str()) == Some("crate") {
                                        let src = p[..p.len() - 1].join("::");
                                        map.modules.entry(cur.clone()).or_default().globs.push(src);
                                    } else if let Some(first) = p.first() {
                                        // Relative glob: resolve against
                                        // this module's submodules.
                                        let mut src = format!("{cur}::{first}");
                                        for s in &p[1..p.len() - 1] {
                                            src.push_str("::");
                                            src.push_str(s);
                                        }
                                        map.modules.entry(cur.clone()).or_default().globs.push(src);
                                    }
                                }
                                Some("self") => {
                                    if p.len() >= 2 {
                                        let name = p[p.len() - 2].clone();
                                        map.modules.entry(cur.clone()).or_default().items.insert(name);
                                    }
                                }
                                Some(_) => {
                                    map.modules
                                        .entry(cur.clone())
                                        .or_default()
                                        .items
                                        .insert(visible.clone());
                                }
                                None => {}
                            }
                        }
                    }
                    ci = end;
                    continue;
                }
                _ => {}
            }
        }
        ci += 1;
    }
}

fn ident_at(toks: &[Token], code: &[usize], ci: usize) -> Option<String> {
    code.get(ci).and_then(|&i| {
        let t = &toks[i];
        if t.kind == TokenKind::Ident {
            Some(t.text.clone())
        } else {
            None
        }
    })
}

/// Parse a use-tree starting at code position `start` (just past the
/// `use` keyword). Returns `(segments, visible)` pairs — the pre-rename
/// segment path (what import resolution checks) plus the name the item
/// is visible as (the `as` rename target when present, else the leaf;
/// that's what `pub use` adds to the module namespace) — and the code
/// position just past the terminating `;`.
pub fn parse_use_tree(
    toks: &[Token],
    code: &[usize],
    start: usize,
) -> (Vec<(Vec<String>, String)>, usize) {
    let mut out = Vec::new();
    let mut ci = start;
    let mut prefix: Vec<Vec<String>> = vec![Vec::new()];
    fn walk(
        toks: &[Token],
        code: &[usize],
        ci: &mut usize,
        prefix: &[String],
        out: &mut Vec<(Vec<String>, String)>,
    ) {
        let mut path = prefix.to_vec();
        let mut rename: Option<String> = None;
        loop {
            let Some(&idx) = code.get(*ci) else { return };
            let t = &toks[idx];
            if t.kind == TokenKind::Ident {
                if t.text == "as" {
                    // Record the rename target; it becomes the visible name.
                    if let Some(&nj) = code.get(*ci + 1) {
                        if toks[nj].kind == TokenKind::Ident {
                            rename = Some(toks[nj].text.clone());
                        }
                    }
                    *ci += 2;
                    continue;
                }
                path.push(t.text.clone());
                *ci += 1;
            } else if t.is(TokenKind::Punct, "*") {
                path.push("*".to_string());
                *ci += 1;
            } else if t.is(TokenKind::Punct, "::") {
                *ci += 1;
                // Group?
                if let Some(&nidx) = code.get(*ci) {
                    if toks[nidx].is(TokenKind::Punct, "{") {
                        *ci += 1;
                        loop {
                            if let Some(&gidx) = code.get(*ci) {
                                if toks[gidx].is(TokenKind::Punct, "}") {
                                    *ci += 1;
                                    break;
                                }
                                if toks[gidx].is(TokenKind::Punct, ",") {
                                    *ci += 1;
                                    continue;
                                }
                                walk(toks, code, ci, &path, out);
                            } else {
                                break;
                            }
                        }
                        return;
                    }
                }
                continue;
            } else {
                break;
            }
        }
        if !path.is_empty() {
            let visible = rename.unwrap_or_else(|| path.last().cloned().unwrap_or_default());
            out.push((path, visible));
        }
    }
    let pref = prefix.pop().unwrap_or_default();
    walk(toks, code, &mut ci, &pref, &mut out);
    // Consume to the `;`.
    while let Some(&idx) = code.get(ci) {
        ci += 1;
        if toks[idx].is(TokenKind::Punct, ";") {
            break;
        }
    }
    (out, ci)
}

/// All named-field struct declarations in the crate.
pub fn struct_fields(krate: &Crate) -> Vec<StructField> {
    let mut out = Vec::new();
    for sf in &krate.files {
        let toks = &sf.tokens;
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        let mut ci = 0usize;
        while ci < code.len() {
            if toks[code[ci]].is(TokenKind::Ident, "struct") {
                // Not `struct` in `fn struct_fields` idents — keyword use
                // only: preceded by nothing/pub/visibility or start.
                if let Some(name) = ident_at(toks, &code, ci + 1) {
                    let mut cj = ci + 2;
                    // Skip generics + where clause to the body opener.
                    let mut angle = 0i32;
                    let mut opened = false;
                    while let Some(&idx) = code.get(cj) {
                        let t = &toks[idx];
                        if t.is(TokenKind::Punct, "<") {
                            angle += 1;
                        } else if t.is(TokenKind::Punct, ">") {
                            angle -= 1;
                        } else if t.is(TokenKind::Punct, ">>") {
                            angle -= 2;
                        } else if angle <= 0 && t.is(TokenKind::Punct, "{") {
                            opened = true;
                            break;
                        } else if angle <= 0
                            && (t.is(TokenKind::Punct, ";") || t.is(TokenKind::Punct, "("))
                        {
                            break; // unit or tuple struct
                        }
                        cj += 1;
                    }
                    if opened {
                        parse_fields(toks, &code, cj + 1, &name, &sf.path, &mut out);
                    }
                }
            }
            ci += 1;
        }
    }
    out
}

/// Parse `name: Type,` fields from code position `start` (just inside
/// the struct body) to the matching close brace.
fn parse_fields(
    toks: &[Token],
    code: &[usize],
    start: usize,
    strukt: &str,
    file: &str,
    out: &mut Vec<StructField>,
) {
    let mut ci = start;
    loop {
        let Some(&idx) = code.get(ci) else { return };
        if toks[idx].is(TokenKind::Punct, "}") {
            return;
        }
        // Skip attributes and visibility.
        if toks[idx].is(TokenKind::Punct, "#") {
            let mut depth = 0i32;
            ci += 1;
            while let Some(&j) = code.get(ci) {
                if toks[j].is(TokenKind::Punct, "[") {
                    depth += 1;
                } else if toks[j].is(TokenKind::Punct, "]") {
                    depth -= 1;
                    if depth == 0 {
                        ci += 1;
                        break;
                    }
                }
                ci += 1;
            }
            continue;
        }
        if toks[idx].is(TokenKind::Ident, "pub") {
            ci += 1;
            if let Some(&j) = code.get(ci) {
                if toks[j].is(TokenKind::Punct, "(") {
                    while let Some(&k) = code.get(ci) {
                        ci += 1;
                        if toks[k].is(TokenKind::Punct, ")") {
                            break;
                        }
                    }
                }
            }
            continue;
        }
        // Expect `ident : type`.
        let (fname, fline) = match code.get(ci) {
            Some(&j) if toks[j].kind == TokenKind::Ident => (toks[j].text.clone(), toks[j].line),
            _ => {
                ci += 1;
                continue;
            }
        };
        let Some(&cidx) = code.get(ci + 1) else { return };
        if !toks[cidx].is(TokenKind::Punct, ":") {
            ci += 1;
            continue;
        }
        // Type tokens until `,` or `}` at zero nesting.
        let mut cj = ci + 2;
        let (mut angle, mut paren, mut brack) = (0i32, 0i32, 0i32);
        let mut ty = Vec::new();
        while let Some(&j) = code.get(cj) {
            let t = &toks[j];
            if angle <= 0 && paren == 0 && brack == 0 {
                if t.is(TokenKind::Punct, ",") || t.is(TokenKind::Punct, "}") {
                    break;
                }
            }
            match t.text.as_str() {
                "<" => angle += 1,
                ">" => angle -= 1,
                ">>" => angle -= 2,
                "(" => paren += 1,
                ")" => paren -= 1,
                "[" => brack += 1,
                "]" => brack -= 1,
                _ => {}
            }
            ty.push(t.text.clone());
            cj += 1;
        }
        let type_head = ty
            .iter()
            .find(|s| s.chars().next().map(|c| c.is_alphabetic() || c == '_').unwrap_or(false))
            .cloned()
            .unwrap_or_default();
        out.push(StructField {
            strukt: strukt.to_string(),
            field: fname,
            type_text: ty.join(" "),
            type_head,
            file: file.to_string(),
            line: fline,
        });
        ci = cj;
        if let Some(&j) = code.get(ci) {
            if toks[j].is(TokenKind::Punct, ",") {
                ci += 1;
            }
        }
    }
}

/// All module-level `static NAME: Type` items.
pub fn statics(krate: &Crate) -> Vec<StaticItem> {
    let mut out = Vec::new();
    for sf in &krate.files {
        let toks = &sf.tokens;
        let code: Vec<usize> =
            (0..toks.len()).filter(|&i| toks[i].kind != TokenKind::Comment).collect();
        for (k, &idx) in code.iter().enumerate() {
            if !toks[idx].is(TokenKind::Ident, "static") {
                continue;
            }
            // `static NAME : ...` or `static mut NAME : ...`; skip
            // `&'static` lifetimes (lexed as Lifetime, never Ident).
            let mut kn = k + 1;
            if ident_at(toks, code.as_slice(), kn).as_deref() == Some("mut") {
                kn += 1;
            }
            let Some(name) = ident_at(toks, code.as_slice(), kn) else { continue };
            let Some(&cidx) = code.get(kn + 1) else { continue };
            if !toks[cidx].is(TokenKind::Punct, ":") {
                continue;
            }
            let mut ty = Vec::new();
            let mut cj = kn + 2;
            while let Some(&j) = code.get(cj) {
                if toks[j].is(TokenKind::Punct, "=") || toks[j].is(TokenKind::Punct, ";") {
                    break;
                }
                ty.push(toks[j].text.clone());
                cj += 1;
            }
            out.push(StaticItem {
                name,
                type_text: ty.join(" "),
                file: sf.path.clone(),
                line: toks[idx].line,
            });
        }
    }
    out
}
