//! Findings, waiver application, and report rendering (text + JSON).

use crate::analysis::Crate;
use crate::util::json::Json;

/// One rule violation at a source location. `waived` is set by
/// [`apply_waivers`] when an inline `// lint: allow(<rule>) — why`
/// comment covers the finding's line.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule slug (`panic-freedom`, `lock-order`, …).
    pub rule: &'static str,
    /// Path relative to the scanned source root (slash-separated).
    pub file: String,
    pub line: u32,
    pub message: String,
    pub waived: bool,
    /// The waiver's justification text, when waived.
    pub waiver: Option<String>,
}

impl Finding {
    pub fn new(rule: &'static str, file: &str, line: u32, message: String) -> Finding {
        Finding { rule, file: file.to_string(), line, message, waived: false, waiver: None }
    }
}

/// All findings of one lint run, waivers applied.
#[derive(Default)]
pub struct Report {
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn unwaived(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| !f.waived).collect()
    }

    pub fn waived_count(&self) -> usize {
        self.findings.iter().filter(|f| f.waived).count()
    }

    /// Human-readable rendering: one `file:line [rule] message` per
    /// finding, waived ones tagged with their justification.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let tag = match &f.waiver {
                Some(why) => format!("  (waived: {why})"),
                None => String::new(),
            };
            out.push_str(&format!("{}:{} [{}] {}{}\n", f.file, f.line, f.rule, f.message, tag));
        }
        out.push_str(&format!(
            "{} finding(s): {} unwaived, {} waived\n",
            self.findings.len(),
            self.unwaived().len(),
            self.waived_count()
        ));
        out
    }

    /// The `ANALYSIS.json` shape: totals plus one record per finding.
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for f in &self.findings {
            let mut o = Json::obj();
            o.set("rule", Json::Str(f.rule.to_string()));
            o.set("file", Json::Str(f.file.clone()));
            o.set("line", Json::Num(f.line as f64));
            o.set("message", Json::Str(f.message.clone()));
            o.set("waived", Json::Bool(f.waived));
            if let Some(w) = &f.waiver {
                o.set("waiver", Json::Str(w.clone()));
            }
            arr.push(o);
        }
        let mut root = Json::obj();
        root.set("total", Json::Num(self.findings.len() as f64));
        root.set("unwaived", Json::Num(self.unwaived().len() as f64));
        root.set("waived", Json::Num(self.waived_count() as f64));
        root.set("findings", Json::Arr(arr));
        root
    }
}

/// Parse one comment's waiver: `lint: allow(<rule>) <dash> <why>`.
/// Returns `(rule, justification)`; the justification is mandatory —
/// a bare `allow(rule)` with no reason does not waive anything.
fn parse_waiver(comment: &str) -> Option<(String, String)> {
    let at = comment.find("lint: allow(")?;
    let rest = &comment[at + "lint: allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let mut why = rest[close + 1..].trim();
    for dash in ["—", "--", "-"] {
        if let Some(s) = why.strip_prefix(dash) {
            why = s.trim();
            break;
        }
    }
    if rule.is_empty() || why.is_empty() {
        return None;
    }
    Some((rule, why.to_string()))
}

/// Mark findings as waived. A waiver covers a finding when a line
/// comment carrying `lint: allow(<rule>) — <why>` for the same rule
/// sits on the finding's own line (trailing) or in the contiguous
/// comment block on the line(s) immediately above it.
pub fn apply_waivers(krate: &Crate, findings: &mut [Finding]) {
    use std::collections::HashMap;
    // file -> line -> parsed waivers on that line.
    let mut by_file: HashMap<&str, HashMap<u32, Vec<(String, String)>>> = HashMap::new();
    let mut comment_lines: HashMap<&str, std::collections::HashSet<u32>> = HashMap::new();
    for sf in &krate.files {
        let lines = by_file.entry(sf.path.as_str()).or_default();
        let clines = comment_lines.entry(sf.path.as_str()).or_default();
        let mut code_lines = std::collections::HashSet::new();
        for t in &sf.tokens {
            if t.kind == super::lexer::TokenKind::Comment {
                if let Some(w) = parse_waiver(&t.text) {
                    lines.entry(t.line).or_default().push(w);
                }
            } else {
                code_lines.insert(t.line);
            }
        }
        for t in &sf.tokens {
            if t.kind == super::lexer::TokenKind::Comment && !code_lines.contains(&t.line) {
                clines.insert(t.line);
            }
        }
    }
    for f in findings.iter_mut() {
        let Some(lines) = by_file.get(f.file.as_str()) else { continue };
        let empty = std::collections::HashSet::new();
        let clines = comment_lines.get(f.file.as_str()).unwrap_or(&empty);
        // Same line, then walk up through comment-only lines.
        let mut cand = vec![f.line];
        let mut l = f.line;
        while l > 1 && clines.contains(&(l - 1)) {
            l -= 1;
            cand.push(l);
        }
        'search: for c in cand {
            if let Some(ws) = lines.get(&c) {
                for (rule, why) in ws {
                    if rule == f.rule {
                        f.waived = true;
                        f.waiver = Some(why.clone());
                        break 'search;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiver_parsing_requires_justification() {
        assert_eq!(
            parse_waiver("// lint: allow(panic-freedom) — slice length fixed by loop bound"),
            Some(("panic-freedom".to_string(), "slice length fixed by loop bound".to_string()))
        );
        assert_eq!(
            parse_waiver("// lint: allow(lock-order) -- shed path, documented"),
            Some(("lock-order".to_string(), "shed path, documented".to_string()))
        );
        assert_eq!(parse_waiver("// lint: allow(panic-freedom)"), None);
        assert_eq!(parse_waiver("// lint: allow() — empty rule"), None);
        assert_eq!(parse_waiver("// just a comment"), None);
    }
}
