//! Self-hosted static analysis: `lieq lint`.
//!
//! A zero-dependency, comment/string/raw-string-aware token scanner
//! ([`lexer`]) plus a rule engine ([`rules`]) that enforces the crate's
//! concurrency, determinism, and panic-freedom contracts mechanically —
//! replacing the ad-hoc per-session sweeps that previously guarded
//! them. Findings can be waived inline with
//! `// lint: allow(<rule>) — <justification>`; the justification is
//! mandatory and surfaces in reports.

pub mod lexer;
pub mod report;
pub mod resolve;
pub mod rules;

use std::path::Path;

use anyhow::{Context, Result};

use report::Report;

/// One scanned source file: path relative to the source root
/// (slash-separated), raw text, and its token stream.
pub struct SourceFile {
    pub path: String,
    pub text: String,
    pub tokens: Vec<lexer::Token>,
}

impl SourceFile {
    pub fn new(path: &str, text: &str) -> SourceFile {
        SourceFile {
            path: path.to_string(),
            text: text.to_string(),
            tokens: lexer::lex(text),
        }
    }
}

/// The unit of analysis: every `.rs` file under one source root.
pub struct Crate {
    pub files: Vec<SourceFile>,
}

impl Crate {
    /// Build from in-memory `(path, source)` pairs — the fixture-test
    /// entry point.
    pub fn from_sources(files: &[(&str, &str)]) -> Crate {
        Crate { files: files.iter().map(|(p, s)| SourceFile::new(p, s)).collect() }
    }

    /// Scan `src_root` recursively for `.rs` files, sorted by path so
    /// runs are byte-identical.
    pub fn load(src_root: &Path) -> Result<Crate> {
        let mut paths = Vec::new();
        collect_rs(src_root, src_root, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        for rel in paths {
            let abs = src_root.join(&rel);
            let text = std::fs::read_to_string(&abs)
                .with_context(|| format!("read {}", abs.display()))?;
            files.push(SourceFile::new(&rel.replace('\\', "/"), &text));
        }
        Ok(Crate { files })
    }
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("scan {}", dir.display()))?;
    for e in entries {
        let e = e?;
        let p = e.path();
        if p.is_dir() {
            collect_rs(root, &p, out)?;
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Run every rule, sort findings for stable output, apply waivers.
pub fn run_all(krate: &Crate) -> Report {
    let mut findings = Vec::new();
    for rule in rules::all_rules() {
        findings.extend((rule.check)(krate));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    report::apply_waivers(krate, &mut findings);
    Report { findings }
}
