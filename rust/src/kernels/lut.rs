//! LUT GEMV over interleaved code lanes — the decode-shape members of
//! the fused-dequant kernel family. Two table flavors cover every
//! packed layout, so *all* bit-widths 1–8 have a LUT path:
//!
//! * **Nibble lanes** (bits <= 4, even group) — per x-row, one
//!   256-entry *code-pair table* per two adjacent K rows `(2p, 2p+1)`,
//!   indexed by the packed lane byte:
//!   `t_p[b] = x[2p]·lo(b) + x[2p+1]·hi(b)` (lo/hi = the two nibble
//!   codes). One byte read + one table load + one add advances two
//!   weights.
//! * **Byte lanes** (bits 5–8, or any odd group) — per x-row, one
//!   256-entry *single-code table* per K row: `t_r[b] = x[r]·b`. One
//!   byte read + one table load + one add advances one weight — still
//!   no bit reassembly and no int→float conversion in the loop, which
//!   is what the direct path pays per weight at 5–8 bits.
//!
//! Both flavors share the **per-group dequant grid**: the affine
//! `c·scale + min` is applied once per (group, column) on the
//! accumulated code dot-product:
//! `out[col] += scale[g,col]·Σ x·c + min[g,col]·Σ x`, which is exactly
//! the per-group dequant table `lut[c] = c·scale + min` factored out of
//! the inner loop (2^bits table entries collapse to one FMA pair
//! because the grid is affine in the code).
//!
//! Columns are processed in 4-wide register blocks with unrolled
//! accumulators: four independent dependency chains hide the
//! load→add latency of a single accumulator.
//!
//! Parallelism: the output row is split into fixed-size column chunks on
//! [`Pool::current`]; every column's accumulation order (groups
//! ascending, lane bytes ascending) is independent of the chunking, so
//! results are bit-identical at any thread count.

use crate::quant::PackedWeight;
use crate::util::Pool;

use super::gemm::{group_sum, DIRECT_PAR_MIN_WORK, MIN_COL_BLOCK};
use super::outlier::{self, SparseArgs};
use super::simd::{self, SimdTier};
use super::stats::DqKernelStats;

thread_local! {
    /// Reusable table scratch: decode serving calls this kernel once per
    /// linear per token, and a fresh ~(K/2 or K)·1 KiB alloc+memset per
    /// call would rival the table-build cost itself. The tables are
    /// built on the calling thread (workers only read a borrowed slice),
    /// so a caller-thread-local buffer is reused across calls and only
    /// grows.
    static TABLE_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// out[M][N] = x[M][K] · dequant(W) through the LUT path. Decodes any
/// lane layout: nibble lanes through code-pair tables, byte lanes
/// through single-code tables. `sp` carries a fused outlier sidecar:
/// its sparse product is added per column chunk right after the dense
/// tables, inside the same parallel fan-out.
pub(crate) fn dq_gemm_lut(
    tier: SimdTier,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    sp: Option<SparseArgs<'_>>,
    out: &mut [f32],
) -> DqKernelStats {
    let (k, n, g) = (w.k, w.n, w.group_size);
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    let nibble = w.nibble_lanes();
    // Cold-call attribution: `interleaved()` itself counts the build in
    // the process-wide `lane_builds`; this flag mirrors it per call.
    let lane_cold = !w.lanes_built();
    let lanes = w.interleaved();
    let ll = w.lane_len(); // g/2 (nibble) or g (byte) lane bytes per (group, column)
    let groups = k / g;

    let pool = Pool::current();
    let chunk = if pool.workers() == 1 || n / MIN_COL_BLOCK < 2 || m * k * n < DIRECT_PAR_MIN_WORK
    {
        n
    } else {
        // ~2 chunks per worker; fixed chunking keeps writes disjoint.
        ((n + pool.workers() * 2 - 1) / (pool.workers() * 2)).max(MIN_COL_BLOCK)
    };

    // One 256-entry table per lane byte: K/2 pair tables (nibble) or K
    // single-code tables (byte).
    let table_len = groups * ll * 256;
    TABLE_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        if scratch.len() < table_len {
            scratch.resize(table_len, 0.0);
        }
        let tables = &mut scratch[..table_len];
        let mut gsums = vec![0f32; groups];
        for row in 0..m {
            let xrow = &x[row * k..(row + 1) * k];
            if nibble {
                build_pair_tables(tier, xrow, tables);
            } else {
                build_code_tables(tier, xrow, tables);
            }
            for (gi, gs) in gsums.iter_mut().enumerate() {
                *gs = group_sum(xrow, gi, g);
            }
            let orow = &mut out[row * n..(row + 1) * n];
            let (tables, gsums) = (&*tables, &gsums);
            pool.par_chunks_mut(orow, chunk, |ci, ochunk| {
                lut_cols(tier, w, lanes, ll, tables, gsums, ci * chunk, ochunk);
                if let Some(sp) = sp {
                    outlier::sparse_accum(tier, &sp, sp.xg_row(row), ci * chunk, ochunk);
                }
            });
        }
    });

    let mut s = DqKernelStats::for_lanes(w, m);
    s.lut_calls = 1;
    s.simd_lut_calls = (tier != SimdTier::Off) as usize;
    if nibble {
        s.lut_nibble_calls = 1;
    } else {
        s.lut_byte_calls = 1;
    }
    s.lut_builds = m; // one table family per x-row
    s.lane_builds = lane_cold as usize;
    s
}

/// Fill the per-row code-pair tables: `t_p[b] = x0·(b & 15) + x1·(b >> 4)`
/// for pair `p` = K rows `(2p, 2p+1)`. Nibble lanes only (needs even K).
/// The `lo` ramp and the 16 broadcast-add rows run on the SIMD tier —
/// the same per-entry expression (`x1·hi + x0·lo`) at every tier.
fn build_pair_tables(tier: SimdTier, xrow: &[f32], tables: &mut [f32]) {
    debug_assert_eq!(tables.len(), (xrow.len() / 2) * 256);
    for (p, t) in tables.chunks_exact_mut(256).enumerate() {
        let x0 = xrow[2 * p];
        let x1 = xrow[2 * p + 1];
        let mut lo = [0f32; 16];
        simd::ramp_scale(tier, &mut lo, x0);
        for hi in 0..16usize {
            let hv = x1 * hi as f32;
            simd::add_bcast(tier, &mut t[hi * 16..(hi + 1) * 16], &lo, hv);
        }
    }
}

/// Fill the per-row single-code tables: `t_r[b] = x[r]·b` for every K
/// row `r` (byte lanes: one code per lane byte, codes < 256 for any
/// bit-width up to 8).
fn build_code_tables(tier: SimdTier, xrow: &[f32], tables: &mut [f32]) {
    debug_assert_eq!(tables.len(), xrow.len() * 256);
    for (r, t) in tables.chunks_exact_mut(256).enumerate() {
        simd::ramp_scale(tier, t, xrow[r]);
    }
}

/// One output chunk (columns `[c0, c0 + ochunk.len())`) for one x-row.
/// Layout-agnostic: `tables` holds one 256-entry table per lane byte
/// (pair tables for nibble lanes, single-code tables for byte lanes), so
/// the inner loop is identical for both flavors.
///
/// On AVX2 the column block widens from 4 to 8 and the table lookups go
/// through `_mm256_i32gather_ps` ([`lut_cols_octet`]). Per column the
/// accumulation order over lane bytes and the final affine are
/// unchanged, so the gather path is bit-identical to this scalar body
/// (block width never mixes columns). Other tiers keep the quad block:
/// scattered table loads don't vectorize portably, so their SIMD win is
/// the table build.
fn lut_cols(
    tier: SimdTier,
    w: &PackedWeight,
    lanes: &[u8],
    ll: usize,
    tables: &[f32],
    gsums: &[f32],
    c0: usize,
    ochunk: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if tier == SimdTier::Avx2 {
        return lut_cols_octet(tier, w, lanes, ll, tables, gsums, c0, ochunk);
    }
    let _ = tier;
    let n = w.n;
    let bw = ochunk.len();
    ochunk.fill(0.0);
    for (gi, &gs) in gsums.iter().enumerate() {
        let tg = &tables[gi * ll * 256..(gi + 1) * ll * 256];
        let srow = &w.stats.scale[gi * n + c0..gi * n + c0 + bw];
        let mrow = &w.stats.minv[gi * n + c0..gi * n + c0 + bw];
        let glanes = &lanes[(gi * n + c0) * ll..(gi * n + c0 + bw) * ll];

        // 4-column register block: four independent accumulator chains.
        let quads = bw / 4;
        for q in 0..quads {
            let c = 4 * q;
            let l0 = &glanes[c * ll..][..ll];
            let l1 = &glanes[(c + 1) * ll..][..ll];
            let l2 = &glanes[(c + 2) * ll..][..ll];
            let l3 = &glanes[(c + 3) * ll..][..ll];
            let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
            for p in 0..ll {
                // lint: allow(panic-freedom) — a 256-element slice into
                // [f32; 256] is infallible.
                let t: &[f32; 256] = tg[p * 256..p * 256 + 256].try_into().unwrap();
                a0 += t[l0[p] as usize];
                a1 += t[l1[p] as usize];
                a2 += t[l2[p] as usize];
                a3 += t[l3[p] as usize];
            }
            ochunk[c] += srow[c] * a0 + mrow[c] * gs;
            ochunk[c + 1] += srow[c + 1] * a1 + mrow[c + 1] * gs;
            ochunk[c + 2] += srow[c + 2] * a2 + mrow[c + 2] * gs;
            ochunk[c + 3] += srow[c + 3] * a3 + mrow[c + 3] * gs;
        }
        for c in quads * 4..bw {
            let lane = &glanes[c * ll..][..ll];
            let mut a = 0f32;
            for p in 0..ll {
                // lint: allow(panic-freedom) — a 256-element slice into
                // [f32; 256] is infallible.
                let t: &[f32; 256] = tg[p * 256..p * 256 + 256].try_into().unwrap();
                a += t[lane[p] as usize];
            }
            ochunk[c] += srow[c] * a + mrow[c] * gs;
        }
    }
}

/// AVX2 variant of [`lut_cols`]: 8-column blocks, table lookups through
/// the hardware gather, affine applied via [`simd::affine_acc`]. Per
/// column, the gathered accumulation visits lane bytes in the same
/// ascending order and the affine folds the same expression
/// (`s·a + mn·gs`) as the quad body — bit-identical by construction.
#[cfg(target_arch = "x86_64")]
fn lut_cols_octet(
    tier: SimdTier,
    w: &PackedWeight,
    lanes: &[u8],
    ll: usize,
    tables: &[f32],
    gsums: &[f32],
    c0: usize,
    ochunk: &mut [f32],
) {
    let n = w.n;
    let bw = ochunk.len();
    ochunk.fill(0.0);
    for (gi, &gs) in gsums.iter().enumerate() {
        let tg = &tables[gi * ll * 256..(gi + 1) * ll * 256];
        let srow = &w.stats.scale[gi * n + c0..gi * n + c0 + bw];
        let mrow = &w.stats.minv[gi * n + c0..gi * n + c0 + bw];
        let glanes = &lanes[(gi * n + c0) * ll..(gi * n + c0 + bw) * ll];

        let octets = bw / 8;
        for o in 0..octets {
            let c = 8 * o;
            let mut ls: [&[u8]; 8] = [&[]; 8];
            for (l, slot) in ls.iter_mut().enumerate() {
                *slot = &glanes[(c + l) * ll..][..ll];
            }
            // SAFETY: this function is only reached when the resolved
            // tier is Avx2 (runtime-detected); `tg` holds `ll` 256-entry
            // tables and each lane slice has exactly `ll` bytes.
            let accs = unsafe { simd::lut_octet_avx2(tg, &ls, ll) };
            simd::affine_acc(
                tier,
                &mut ochunk[c..c + 8],
                &srow[c..c + 8],
                &accs,
                &mrow[c..c + 8],
                gs,
            );
        }
        for c in octets * 8..bw {
            let lane = &glanes[c * ll..][..ll];
            let mut a = 0f32;
            for p in 0..ll {
                // lint: allow(panic-freedom) — a 256-element slice into
                // [f32; 256] is infallible.
                let t: &[f32; 256] = tg[p * 256..p * 256 + 256].try_into().unwrap();
                a += t[lane[p] as usize];
            }
            ochunk[c] += srow[c] * a + mrow[c] * gs;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{dequantize, pack_weight, quantize_group};
    use crate::util::Rng;

    fn assert_lut_matches_reference(cases: &[(usize, usize, usize, usize, u8)]) {
        let mut rng = Rng::new(91);
        for &(m, k, n, g, bits) in cases {
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let pw = pack_weight(&w, k, n, g, bits);
            let (codes, stats) = quantize_group(&w, k, n, g, bits);
            let wdq = dequantize(&codes, &stats, k, n, g);
            let mut out = vec![0f32; m * n];
            let mut out_ref = vec![0f32; m * n];
            let s = dq_gemm_lut(simd::current_tier(), &x, m, &pw, None, &mut out);
            assert_eq!(s.lut_calls, 1);
            // Whatever tier ran, the scalar reference is bit-identical.
            let mut out_off = vec![0f32; m * n];
            dq_gemm_lut(SimdTier::Off, &x, m, &pw, None, &mut out_off);
            assert!(
                out.iter().zip(&out_off).all(|(a, b)| a.to_bits() == b.to_bits()),
                "m{m} k{k} n{n} g{g} b{bits}: tier {} != scalar",
                simd::current_tier().name()
            );
            assert_eq!(
                (s.lut_nibble_calls, s.lut_byte_calls),
                if pw.nibble_lanes() { (1, 0) } else { (0, 1) },
                "m{m} k{k} n{n} g{g} b{bits}: wrong LUT flavor attribution"
            );
            crate::kernels::gemm_f32(&x, m, &wdq, k, n, &mut out_ref);
            let max_err = out
                .iter()
                .zip(&out_ref)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < 5e-3, "m{m} k{k} n{n} g{g} b{bits}: max err {max_err}");
        }
    }

    #[test]
    fn lut_matches_dequantized_reference_nibble() {
        assert_lut_matches_reference(&[
            (1, 64, 70, 32, 2),
            (3, 128, 33, 64, 3),
            (2, 96, 129, 32, 4),
        ]);
    }

    #[test]
    fn lut_matches_dequantized_reference_byte() {
        assert_lut_matches_reference(&[
            (1, 64, 70, 32, 5),
            (3, 128, 33, 64, 6),
            (2, 96, 129, 32, 7),
            (1, 128, 96, 64, 8),
            (1, 1056, 40, 33, 3), // odd group: nibble-ineligible fallback case
        ]);
    }

    #[test]
    fn pair_tables_encode_both_nibbles() {
        let x = [2.0f32, 10.0];
        let mut t = vec![0f32; 256];
        build_pair_tables(SimdTier::Off, &x, &mut t);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[3], 6.0); // lo code 3 -> 2*3
        assert_eq!(t[0x30], 30.0); // hi code 3 -> 10*3
        assert_eq!(t[0x21], 22.0); // 2*1 + 10*2
    }

    #[test]
    fn code_tables_scale_full_byte_range() {
        let x = [0.5f32, -3.0];
        let mut t = vec![0f32; 2 * 256];
        build_code_tables(SimdTier::Off, &x, &mut t);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[200], 100.0); // row 0, code 200 -> 0.5*200
        assert_eq!(t[256], 0.0);
        assert_eq!(t[256 + 255], -765.0); // row 1, code 255 -> -3*255
    }
}
