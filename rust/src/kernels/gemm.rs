//! Packed fused-dequant GEMM + f32 reference GEMM.
//!
//! Layout (shared with quant::pack and the Pallas kernel):
//!   planes u32[bits][K/32][N], scale/min f32[K/g][N], x f32[M][K].
//!
//! Strategy: dequantize one K-panel of 32 rows at a time into a stack
//! buffer (unpack once per panel), then run a blocked (M x 32) x (32 x N)
//! GEMM update on it. Unpack cost amortizes over M; for M = 1 (decode
//! GEMV) the kernel stays memory-bound on the packed planes, which is the
//! win being measured.
//!
//! Both paths run on [`Pool::current`]: the direct/GEMV path splits the N
//! output columns into blocks, the panel path splits the M rows into
//! per-worker panels. Every output element is computed by exactly one
//! worker with an unchanged inner-loop order, so results are bit-identical
//! at any thread count and `DqKernelStats` stays exact.

use crate::quant::PackedWeight;
use crate::util::Pool;

/// Column-block width floor for the parallel direct path; narrower blocks
/// would thrash the per-block accumulator for no spread.
const MIN_COL_BLOCK: usize = 32;

/// Minimum m·k·n before the direct path fans out: the pool spawns threads
/// per call (~tens of µs), so tiny GEMVs run sequentially rather than
/// paying spawn overhead comparable to the kernel itself. Large-N decode
/// shapes (real model widths) clear this easily.
pub(crate) const DIRECT_PAR_MIN_WORK: usize = 400_000;

/// Counters for the §Perf log.
#[derive(Clone, Copy, Debug, Default)]
pub struct DqKernelStats {
    pub weight_bytes_read: usize,
    pub flops: usize,
}

impl DqKernelStats {
    fn for_weight(w: &PackedWeight, m: usize) -> DqKernelStats {
        DqKernelStats {
            weight_bytes_read: w.planes.len() * 4 + w.stats.scale.len() * 8,
            flops: 2 * m * w.k * w.n,
        }
    }
}

/// out[M][N] = x[M][K] · dequant(W). Returns byte/flop stats.
///
/// Two paths:
/// * small M (decode GEMV): direct accumulation — the affine form
///   `W = c·scale + min` splits into a per-group `Σ x` term (free) plus a
///   bit-plane code dot-product assembled in-register, never
///   materializing dequantized weights (≈5–7 ops/weight, column-contiguous
///   inner loops that auto-vectorize); parallel over column blocks;
/// * large M: dequantize one 32-row panel and amortize it over all rows;
///   parallel over row ranges (each worker unpacks its own panels).
pub fn dq_gemm(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) -> DqKernelStats {
    if m == 0 {
        return DqKernelStats::for_weight(w, 0);
    }
    if m < 8 {
        return dq_gemm_direct(x, m, w, out);
    }
    dq_gemm_panel(x, m, w, out)
}

/// Direct (no-panel) path for GEMV-like shapes: fan out over N.
fn dq_gemm_direct(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) -> DqKernelStats {
    let n = w.n;
    assert_eq!(x.len(), m * w.k);
    assert_eq!(out.len(), m * n);
    let pool = Pool::current();
    let max_blocks = n / MIN_COL_BLOCK;
    if pool.workers() == 1 || max_blocks < 2 || m * w.k * n < DIRECT_PAR_MIN_WORK {
        dq_gemm_direct_cols(x, m, w, 0, n, out);
        return DqKernelStats::for_weight(w, m);
    }
    // ~2 blocks per worker: enough spread to absorb ragged finishes while
    // keeping the stitch copy negligible.
    let target = pool.workers().min(max_blocks) * 2;
    let block = ((n + target - 1) / target).max(MIN_COL_BLOCK);
    let n_blocks = (n + block - 1) / block;
    let parts = pool.par_map((0..n_blocks).collect::<Vec<usize>>(), |bi| {
        let c0 = bi * block;
        let c1 = (c0 + block).min(n);
        let mut buf = vec![0f32; m * (c1 - c0)];
        dq_gemm_direct_cols(x, m, w, c0, c1, &mut buf);
        buf
    });
    for (bi, buf) in parts.iter().enumerate() {
        let c0 = bi * block;
        let bw = buf.len() / m;
        for row in 0..m {
            out[row * n + c0..row * n + c0 + bw].copy_from_slice(&buf[row * bw..(row + 1) * bw]);
        }
    }
    DqKernelStats::for_weight(w, m)
}

/// Direct path over the column range `[c0, c1)`; `out` is an
/// `m x (c1 - c0)` row-major block.
fn dq_gemm_direct_cols(
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let (k, n, bits, g) = (w.k, w.n, w.bits as usize, w.group_size);
    let bw = c1 - c0;
    debug_assert_eq!(out.len(), m * bw);
    out.fill(0.0);
    let kw = k / 32;
    let plane_stride = kw * n;
    let groups = k / g;
    let words_per_group = g / 32;

    let mut acc = vec![0f32; bw];
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        let orow = &mut out[row * bw..(row + 1) * bw];

        // min-term: y += Σ_g (Σ_{k∈g} x_k) · min[g, ·]
        for gi in 0..groups {
            let gx: f32 = xrow[gi * g..(gi + 1) * g].iter().sum();
            if gx == 0.0 {
                continue;
            }
            let mrow = &w.stats.minv[gi * n + c0..gi * n + c1];
            for col in 0..bw {
                orow[col] += gx * mrow[col];
            }
        }

        // code-term per group: y += scale[g, ·] ⊙ Σ_{k∈g} x_k · c[k, ·]
        for gi in 0..groups {
            acc.fill(0.0);
            for wi in gi * words_per_group..(gi + 1) * words_per_group {
                let base = wi * n;
                match bits {
                    2 => {
                        let p0 = &w.planes[base + c0..base + c1];
                        let p1 = &w.planes[plane_stride + base + c0..plane_stride + base + c1];
                        for bit in 0..32 {
                            let xv = xrow[wi * 32 + bit];
                            if xv == 0.0 {
                                continue;
                            }
                            for col in 0..bw {
                                let c = ((p0[col] >> bit) & 1) | (((p1[col] >> bit) & 1) << 1);
                                acc[col] += xv * c as f32;
                            }
                        }
                    }
                    3 => {
                        let p0 = &w.planes[base + c0..base + c1];
                        let p1 = &w.planes[plane_stride + base + c0..plane_stride + base + c1];
                        let p2 = &w.planes
                            [2 * plane_stride + base + c0..2 * plane_stride + base + c1];
                        for bit in 0..32 {
                            let xv = xrow[wi * 32 + bit];
                            if xv == 0.0 {
                                continue;
                            }
                            for col in 0..bw {
                                let c = ((p0[col] >> bit) & 1)
                                    | (((p1[col] >> bit) & 1) << 1)
                                    | (((p2[col] >> bit) & 1) << 2);
                                acc[col] += xv * c as f32;
                            }
                        }
                    }
                    4 => {
                        let p0 = &w.planes[base + c0..base + c1];
                        let p1 = &w.planes[plane_stride + base + c0..plane_stride + base + c1];
                        let p2 = &w.planes
                            [2 * plane_stride + base + c0..2 * plane_stride + base + c1];
                        let p3 = &w.planes
                            [3 * plane_stride + base + c0..3 * plane_stride + base + c1];
                        for bit in 0..32 {
                            let xv = xrow[wi * 32 + bit];
                            if xv == 0.0 {
                                continue;
                            }
                            for col in 0..bw {
                                let c = ((p0[col] >> bit) & 1)
                                    | (((p1[col] >> bit) & 1) << 1)
                                    | (((p2[col] >> bit) & 1) << 2)
                                    | (((p3[col] >> bit) & 1) << 3);
                                acc[col] += xv * c as f32;
                            }
                        }
                    }
                    _ => {
                        for bit in 0..32 {
                            let xv = xrow[wi * 32 + bit];
                            if xv == 0.0 {
                                continue;
                            }
                            for col in 0..bw {
                                let mut c = 0u32;
                                for j in 0..bits {
                                    c |= ((w.planes[j * plane_stride + base + c0 + col] >> bit)
                                        & 1)
                                        << j;
                                }
                                acc[col] += xv * c as f32;
                            }
                        }
                    }
                }
            }
            let srow = &w.stats.scale[gi * n + c0..gi * n + c1];
            for col in 0..bw {
                orow[col] += srow[col] * acc[col];
            }
        }
    }
}

/// Panel path: unpack 32 dequantized rows once, reuse across all M rows;
/// fan out over M so each worker amortizes its own panel unpacks.
fn dq_gemm_panel(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) -> DqKernelStats {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    let pool = Pool::current();
    // At least 16 rows per worker: below that the duplicated panel unpack
    // outweighs the spread.
    let rows_per = ((m + pool.workers() - 1) / pool.workers()).max(16);
    pool.par_chunks_mut(out, rows_per * n, |ci, ochunk| {
        let r0 = ci * rows_per;
        let rows = ochunk.len() / n;
        dq_gemm_panel_rows(&x[r0 * k..(r0 + rows) * k], rows, w, ochunk);
    });
    DqKernelStats::for_weight(w, m)
}

/// Sequential panel kernel over `m` rows (callers slice x/out per worker).
fn dq_gemm_panel_rows(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) {
    let (k, n, bits, g) = (w.k, w.n, w.bits as usize, w.group_size);
    out.fill(0.0);
    let kw = k / 32;
    let plane_stride = kw * n;

    // Panel buffer: 32 dequantized weight rows (32 x N).
    let mut panel = vec![0f32; 32 * n];

    for word in 0..kw {
        // --- unpack + dequant one 32-row panel -----------------------------
        let gi_base = word * 32; // first k row of this panel
        for col in 0..n {
            // Gather plane words for this column.
            let mut pw = [0u32; 8];
            for j in 0..bits {
                pw[j] = w.planes[j * plane_stride + word * n + col];
            }
            for bit in 0..32 {
                let mut c = 0u32;
                for j in 0..bits {
                    c |= ((pw[j] >> bit) & 1) << j;
                }
                let row = gi_base + bit;
                let gi = row / g;
                let s = w.stats.scale[gi * n + col];
                let mn = w.stats.minv[gi * n + col];
                panel[bit * n + col] = c as f32 * s + mn;
            }
        }
        // --- GEMM update: out += x[:, panel_rows] * panel ------------------
        for row in 0..m {
            let xrow = &x[row * k + word * 32..row * k + word * 32 + 32];
            let orow = &mut out[row * n..(row + 1) * n];
            for (bit, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let prow = &panel[bit * n..(bit + 1) * n];
                for c in 0..n {
                    orow[c] += xv * prow[c];
                }
            }
        }
    }
}

/// Reference f32 GEMM (the FP16-baseline stand-in; f32 on CPU).
pub fn gemm_f32(x: &[f32], m: usize, w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        let orow = &mut out[row * n..(row + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for c in 0..n {
                orow[c] += xv * wrow[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{dequantize, pack_weight, quantize_group};
    use crate::util::prop::forall;
    use crate::util::Rng;

    #[test]
    fn matches_dequantized_reference() {
        forall(
            "dq_gemm == gemm(dequant)",
            12,
            301,
            |rng| {
                let m = 1 + rng.below(8);
                let k = 32 * (1 + rng.below(4));
                let n = 8 + rng.below(64);
                let bits = [2u8, 3, 4][rng.below(3)];
                let g = 32;
                let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
                let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
                (m, k, n, bits, g, w, x)
            },
            |(m, k, n, bits, g, w, x)| {
                let pw = pack_weight(w, *k, *n, *g, *bits);
                let (codes, stats) = quantize_group(w, *k, *n, *g, *bits);
                let wdq = dequantize(&codes, &stats, *k, *n, *g);
                let mut out = vec![0f32; m * n];
                let mut out_ref = vec![0f32; m * n];
                dq_gemm(x, *m, &pw, &mut out);
                gemm_f32(x, *m, &wdq, *k, *n, &mut out_ref);
                let max_err = out
                    .iter()
                    .zip(&out_ref)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                if max_err < 2e-3 {
                    Ok(())
                } else {
                    Err(format!("max err {max_err}"))
                }
            },
        );
    }

    #[test]
    fn gemv_m1_correct() {
        let mut rng = Rng::new(5);
        let (k, n) = (128, 96);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, 64, 4);
        let mut out = vec![0f32; n];
        let stats = dq_gemm(&x, 1, &pw, &mut out);
        assert!(stats.weight_bytes_read < k * n * 2); // beats fp16 traffic
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn byte_traffic_scales_with_bits() {
        let mut rng = Rng::new(6);
        let (k, n) = (256, 128);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x = vec![1.0f32; k];
        let mut out = vec![0f32; n];
        let b2 = dq_gemm(&x, 1, &pack_weight(&w, k, n, 64, 2), &mut out).weight_bytes_read;
        let b4 = dq_gemm(&x, 1, &pack_weight(&w, k, n, 64, 4), &mut out).weight_bytes_read;
        assert!(b4 > b2 && b4 < 2 * b2 + k * n, "b2={b2} b4={b4}");
    }

    #[test]
    fn gemm_f32_known() {
        let x = [1.0, 2.0];
        let w = [3.0, 4.0, 5.0, 6.0]; // 2x2
        let mut out = vec![0.0; 2];
        gemm_f32(&x, 1, &w, 2, 2, &mut out);
        assert_eq!(out, vec![13.0, 16.0]);
    }
}
