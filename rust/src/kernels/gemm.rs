//! Packed fused-dequant GEMM family + f32 reference GEMM.
//!
//! Layout (shared with quant::pack and the Pallas kernel):
//!   planes u32[bits][K/32][N], scale/min f32[K/g][N], x f32[M][K];
//!   the LUT and panel paths read the derived interleaved lanes
//!   (`PackedWeight::interleaved`) instead of the planes.
//!
//! [`dq_gemm`] dispatches through [`KernelPolicy`]:
//!
//! * **direct** — per-weight bit-plane reassembly, column-contiguous
//!   inner loops; the reference path that decodes every layout.
//! * **lut** ([`super::lut`]) — interleaved-lane GEMV with per-row
//!   tables (code-pair tables on nibble lanes, single-code tables on
//!   byte lanes); the decode (small M) hot path for every bit-width.
//! * **panel** — decode one 32-row K-panel from the interleaved lanes
//!   into a cache-resident column tile and amortize it over all M rows
//!   (prefill shapes); no plane reassembly.
//!
//! Every path runs on [`Pool::current`] with fixed work decomposition
//! and unchanged per-element inner-loop order, so results are
//! bit-identical at any thread count and [`DqKernelStats`] stays exact.

use crate::quant::PackedWeight;
use crate::util::Pool;

use super::outlier::{self, SparseArgs};
use super::policy::{KernelPath, KernelPolicy};
use super::simd::{self, SimdTier};
use super::stats::{self, DqKernelStats};

/// Column-block width floor for the parallel direct/LUT paths; narrower
/// blocks would thrash the per-block accumulator for no spread.
pub(crate) const MIN_COL_BLOCK: usize = 32;

/// Minimum m·k·n before the small-M paths fan out: the pool spawns
/// threads per call (~tens of µs), so tiny GEMVs run sequentially rather
/// than paying spawn overhead comparable to the kernel itself. Large-N
/// decode shapes (real model widths) clear this easily.
pub(crate) const DIRECT_PAR_MIN_WORK: usize = 400_000;

/// Columns per panel-path cache tile: a 32 x 128 f32 block is 16 KB, so
/// panel + out tile + plane words stay L1/L2-resident while the update
/// streams x.
const PANEL_NC: usize = 128;

/// Σ of one group of `xrow` (the min-term coefficient). Shared by the
/// direct and LUT paths so both fold the same FP expression.
pub(crate) fn group_sum(xrow: &[f32], gi: usize, g: usize) -> f32 {
    xrow[gi * g..(gi + 1) * g].iter().sum()
}

/// out[M][N] = x[M][K] · dequant(W) through the policy-selected path
/// (CLI `--kernel` / `LIEQ_KERNEL` / auto). Returns byte/flop/path stats.
pub fn dq_gemm(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) -> DqKernelStats {
    dq_gemm_with(&KernelPolicy::current(), x, m, w, out)
}

/// [`dq_gemm`] with an explicit policy (benches and tests pin paths this
/// way without mutating process-wide state).
pub fn dq_gemm_with(
    policy: &KernelPolicy,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    out: &mut [f32],
) -> DqKernelStats {
    if m == 0 {
        return DqKernelStats::for_planes(w, 0);
    }
    let tier = policy.simd;
    // Outlier fusion pre-pass: mask the sidecar rows out of x and gather
    // them, in one sweep, so the selected dense path runs unmodified on
    // the masked input and every path adds the same sparse product (see
    // `kernels::outlier`). Purely dense weights skip all of this.
    let fusion = outlier::prepare(x, m, w);
    let (xd, sp) = match (&fusion, &w.outliers) {
        (Some(f), Some(side)) => (f.xm.as_slice(), Some(SparseArgs::new(side, f, w.n))),
        _ => (x, None),
    };
    let mut s = match policy.select(m, w) {
        KernelPath::Lut => super::lut::dq_gemm_lut(tier, xd, m, w, sp, out),
        KernelPath::Panel => dq_gemm_panel(tier, xd, m, w, sp, out),
        KernelPath::A8 => super::a8::dq_gemm_a8(xd, m, w, sp, out),
        KernelPath::Direct | KernelPath::Auto => dq_gemm_direct(tier, xd, m, w, sp, out),
    };
    if let Some(f) = &fusion {
        s.outlier_cols = f.nc;
        s.outlier_fused_calls = 1;
        // Sparse traffic on top of the dense path's accounting: the u32
        // index + N fp16 values per column, and the fused multiply-adds.
        s.weight_bytes_read += f.nc * 4 + f.nc * w.n * 2;
        s.flops += 2 * m * f.nc * w.n;
    }
    stats::record(&s);
    s
}

/// Direct (no-panel) path for GEMV-like shapes: fan out over N.
fn dq_gemm_direct(
    tier: SimdTier,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    sp: Option<SparseArgs<'_>>,
    out: &mut [f32],
) -> DqKernelStats {
    let (k, n, g) = (w.k, w.n, w.group_size);
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    let groups = k / g;

    // Per-(row, group) Σx computed once and shared by every column
    // block; each parallel block previously recomputed all group sums of
    // its row.
    let mut gsums = vec![0f32; m * groups];
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        for gi in 0..groups {
            gsums[row * groups + gi] = group_sum(xrow, gi, g);
        }
    }
    let gsums = &gsums;

    let pool = Pool::current();
    let max_blocks = n / MIN_COL_BLOCK;
    let mut s = DqKernelStats::for_planes(w, m);
    s.direct_calls = 1;
    s.simd_direct_calls = (tier != SimdTier::Off) as usize;
    if pool.workers() == 1 || max_blocks < 2 || m * k * n < DIRECT_PAR_MIN_WORK {
        dq_gemm_direct_cols(tier, x, m, w, gsums, sp, 0, n, out);
        return s;
    }
    // ~2 blocks per worker: enough spread to absorb ragged finishes while
    // keeping the stitch copy negligible.
    let target = pool.workers().min(max_blocks) * 2;
    let block = ((n + target - 1) / target).max(MIN_COL_BLOCK);
    let n_blocks = (n + block - 1) / block;
    let parts = pool.par_map((0..n_blocks).collect::<Vec<usize>>(), |bi| {
        let c0 = bi * block;
        let c1 = (c0 + block).min(n);
        let mut buf = vec![0f32; m * (c1 - c0)];
        dq_gemm_direct_cols(tier, x, m, w, gsums, sp, c0, c1, &mut buf);
        buf
    });
    for (bi, buf) in parts.iter().enumerate() {
        let c0 = bi * block;
        let bw = buf.len() / m;
        for row in 0..m {
            out[row * n + c0..row * n + c0 + bw].copy_from_slice(&buf[row * bw..(row + 1) * bw]);
        }
    }
    s
}

/// Direct path over the column range `[c0, c1)`; `out` is an
/// `m x (c1 - c0)` row-major block. `gsums` carries the per-(row, group)
/// Σx precomputed by the caller.
///
/// The per-word reassembly+accumulate runs through
/// [`simd::decode_accum`]: at `tier == Off` that is the exact
/// specialized-arm scalar code this function used to inline, and every
/// live tier computes the identical per-column expression (see the
/// `simd` module docs), so the path stays bit-identical across tiers
/// and thread counts.
fn dq_gemm_direct_cols(
    tier: SimdTier,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    gsums: &[f32],
    sp: Option<SparseArgs<'_>>,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let (k, n, bits, g) = (w.k, w.n, w.bits as usize, w.group_size);
    let bw = c1 - c0;
    debug_assert_eq!(out.len(), m * bw);
    out.fill(0.0);
    let kw = k / 32;
    let plane_stride = kw * n;
    let groups = k / g;
    let words_per_group = g / 32;

    let mut acc = vec![0f32; bw];
    let mut rows: [&[u32]; 8] = [&[]; 8];
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        let orow = &mut out[row * bw..(row + 1) * bw];

        // min-term: y += Σ_g (Σ_{k∈g} x_k) · min[g, ·]
        for gi in 0..groups {
            let gx = gsums[row * groups + gi];
            if gx == 0.0 {
                continue;
            }
            let mrow = &w.stats.minv[gi * n + c0..gi * n + c1];
            simd::axpy(tier, orow, mrow, gx);
        }

        // code-term per group: y += scale[g, ·] ⊙ Σ_{k∈g} x_k · c[k, ·]
        for gi in 0..groups {
            acc.fill(0.0);
            for wi in gi * words_per_group..(gi + 1) * words_per_group {
                let base = wi * n;
                for (j, r) in rows.iter_mut().take(bits).enumerate() {
                    *r = &w.planes[j * plane_stride + base + c0..j * plane_stride + base + c1];
                }
                let planes = &rows[..bits];
                for bit in 0..32 {
                    let xv = xrow[wi * 32 + bit];
                    if xv == 0.0 {
                        continue;
                    }
                    simd::decode_accum(tier, &mut acc, xv, planes, bit as u32);
                }
            }
            let srow = &w.stats.scale[gi * n + c0..gi * n + c1];
            simd::mul_acc(tier, orow, srow, &acc);
        }

        // Fused sparse term: same output block, fixed ascending order —
        // identical per-column FP expression whatever the col blocking.
        if let Some(sp) = sp {
            outlier::sparse_accum(tier, &sp, sp.xg_row(row), c0, orow);
        }
    }
}

/// Panel path: decode one 32-row K-panel *straight from the interleaved
/// lanes* into a cache-resident column tile, reuse it across all M rows;
/// fan out over M so each worker amortizes its own panel decodes. No
/// bit-plane reassembly: `panel_unpacks` stays 0 on this path (the
/// counter now tracks residual plane-reassembly work only).
fn dq_gemm_panel(
    tier: SimdTier,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    sp: Option<SparseArgs<'_>>,
    out: &mut [f32],
) -> DqKernelStats {
    let (k, n, g) = (w.k, w.n, w.group_size);
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    // Cold-call attribution mirrors the LUT path: the lane image is
    // built at most once (`interleaved()` bumps the global counter).
    let lane_cold = !w.lanes_built();
    let lanes = w.interleaved();
    let pool = Pool::current();
    // At least 16 rows per worker: below that the duplicated panel decode
    // outweighs the spread.
    let rows_per = ((m + pool.workers() - 1) / pool.workers()).max(16);
    pool.par_chunks_mut(out, rows_per * n, |ci, ochunk| {
        let r0 = ci * rows_per;
        let rows = ochunk.len() / n;
        let spc = sp.map(|s| s.rows(r0, rows));
        dq_gemm_panel_rows(tier, &x[r0 * k..(r0 + rows) * k], rows, w, lanes, spc, ochunk);
    });
    let n_chunks = (m + rows_per - 1) / rows_per;
    let n_tiles = (n + PANEL_NC - 1) / PANEL_NC;
    let mut s = DqKernelStats::for_lanes(w, m);
    s.panel_calls = 1;
    s.simd_panel_calls = (tier != SimdTier::Off) as usize;
    s.lane_builds = lane_cold as usize;
    // When the panel aligns with the group grid, each row-chunk worker
    // decodes through a per-group dequant table rebuilt once per
    // (tile, group).
    if g % 32 == 0 {
        s.lut_builds = n_chunks * n_tiles * (k / g);
    }
    s
}

/// Sequential panel kernel over `m` rows (callers slice x/out per
/// worker), decoding codes from the interleaved lane image. Tiles the
/// (M x 32) x (32 x Ncol) update: `PANEL_NC` output columns at a time,
/// so the dequantized panel block, the out tile and the lane bytes all
/// stay cache-resident while x streams.
///
/// Dequantization is the exact FP expression of the original plane-based
/// panel (`lut[c]` when 32-aligned, else `c as f32 * s + mn`), applied
/// in the same (col outer, bit inner) order over identical codes — so
/// the output is bit-identical to the plane decoder at any thread count
/// (`panel_lane_decode_matches_plane_decode` pins this).
fn dq_gemm_panel_rows(
    tier: SimdTier,
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    lanes: &[u8],
    sp: Option<SparseArgs<'_>>,
    out: &mut [f32],
) {
    let (k, n, bits, g) = (w.k, w.n, w.bits as usize, w.group_size);
    out.fill(0.0);
    let kw = k / 32;
    let levels = 1usize << bits;
    let nibble = w.nibble_lanes();
    let ll = w.lane_len();
    // A 32-row word panel sits inside one quant group iff the group grid
    // is word-aligned; then decode goes through the per-group dequant
    // table `lut[c] = c·scale + min` rebuilt at group boundaries, and
    // the 32 codes of a column are one contiguous lane run.
    let lut_decode = g % 32 == 0;

    // Panel buffer: 32 dequantized weight rows x one column tile.
    let mut panel = vec![0f32; 32 * PANEL_NC.min(n)];
    let mut lut = vec![0f32; levels * PANEL_NC.min(n)];

    let mut c0 = 0usize;
    while c0 < n {
        let cw = PANEL_NC.min(n - c0);
        let mut lut_group = usize::MAX;
        for word in 0..kw {
            // --- decode one 32 x cw code block from the lanes --------------
            let gi_base = word * 32; // first k row of this panel
            if lut_decode {
                let gi = gi_base / g;
                if gi != lut_group {
                    // Per-group dequant table for the tile's columns: the
                    // same `c as f32 * s + mn` expression the arithmetic
                    // path folds per weight, evaluated once per code level.
                    for col in 0..cw {
                        let s = w.stats.scale[gi * n + c0 + col];
                        let mn = w.stats.minv[gi * n + c0 + col];
                        simd::ramp_affine(tier, &mut lut[col * levels..(col + 1) * levels], s, mn);
                    }
                    lut_group = gi;
                }
                // Aligned fast path: the word's 32 codes per column are a
                // contiguous lane run at in-group offset `gi_base % g`.
                let gi = gi_base / g;
                let off = gi_base % g;
                for col in 0..cw {
                    let lane = &lanes[(gi * n + c0 + col) * ll..(gi * n + c0 + col + 1) * ll];
                    if nibble {
                        let run = &lane[off / 2..off / 2 + 16];
                        for (p, &b) in run.iter().enumerate() {
                            panel[(2 * p) * cw + col] = lut[col * levels + (b & 0xF) as usize];
                            panel[(2 * p + 1) * cw + col] =
                                lut[col * levels + (b >> 4) as usize];
                        }
                    } else {
                        let run = &lane[off..off + 32];
                        for (bit, &b) in run.iter().enumerate() {
                            panel[bit * cw + col] = lut[col * levels + b as usize];
                        }
                    }
                }
            } else {
                // Unaligned groups (g not a multiple of 32): a word can
                // span group boundaries — decode per element with the
                // direct affine, same expression as the plane decoder.
                for col in 0..cw {
                    for bit in 0..32 {
                        let row = gi_base + bit;
                        let gi = row / g;
                        let o = row % g;
                        let base = (gi * n + c0 + col) * ll;
                        let c = if nibble {
                            let b = lanes[base + o / 2];
                            if o % 2 == 0 {
                                (b & 0xF) as usize
                            } else {
                                (b >> 4) as usize
                            }
                        } else {
                            lanes[base + o] as usize
                        };
                        let s = w.stats.scale[gi * n + c0 + col];
                        let mn = w.stats.minv[gi * n + c0 + col];
                        panel[bit * cw + col] = c as f32 * s + mn;
                    }
                }
            }
            // --- GEMM update: out tile += x[:, panel_rows] * panel ---------
            for row in 0..m {
                let xrow = &x[row * k + word * 32..row * k + word * 32 + 32];
                let orow = &mut out[row * n + c0..row * n + c0 + cw];
                for (bit, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let prow = &panel[bit * cw..(bit + 1) * cw];
                    simd::axpy(tier, orow, prow, xv);
                }
            }
        }
        // Fused sparse term, once per (row, tile) after the dense panel
        // updates: the tile decomposition never changes the per-column
        // accumulation order (dense K ascending, then sidecar ascending).
        if let Some(sp) = sp {
            for row in 0..m {
                let orow = &mut out[row * n + c0..row * n + c0 + cw];
                outlier::sparse_accum(tier, &sp, sp.xg_row(row), c0, orow);
            }
        }
        c0 += cw;
    }
}

/// The original plane-reassembly panel decoder, kept (test-only) as the
/// bit-identity reference for the lane-native path above.
#[cfg(test)]
fn dq_gemm_panel_rows_planes(x: &[f32], m: usize, w: &PackedWeight, out: &mut [f32]) {
    let (k, n, bits, g) = (w.k, w.n, w.bits as usize, w.group_size);
    out.fill(0.0);
    let kw = k / 32;
    let plane_stride = kw * n;
    let levels = 1usize << bits;
    let lut_decode = g % 32 == 0;
    let mut panel = vec![0f32; 32 * PANEL_NC.min(n)];
    let mut lut = vec![0f32; levels * PANEL_NC.min(n)];
    let mut c0 = 0usize;
    while c0 < n {
        let cw = PANEL_NC.min(n - c0);
        let mut lut_group = usize::MAX;
        for word in 0..kw {
            let gi_base = word * 32;
            if lut_decode {
                let gi = gi_base / g;
                if gi != lut_group {
                    for col in 0..cw {
                        let s = w.stats.scale[gi * n + c0 + col];
                        let mn = w.stats.minv[gi * n + c0 + col];
                        for c in 0..levels {
                            lut[col * levels + c] = c as f32 * s + mn;
                        }
                    }
                    lut_group = gi;
                }
            }
            for col in 0..cw {
                let mut pw = [0u32; 8];
                for j in 0..bits {
                    pw[j] = w.planes[j * plane_stride + word * n + c0 + col];
                }
                for bit in 0..32 {
                    let mut c = 0u32;
                    for j in 0..bits {
                        c |= ((pw[j] >> bit) & 1) << j;
                    }
                    panel[bit * cw + col] = if lut_decode {
                        lut[col * levels + c as usize]
                    } else {
                        let row = gi_base + bit;
                        let gi = row / g;
                        let s = w.stats.scale[gi * n + c0 + col];
                        let mn = w.stats.minv[gi * n + c0 + col];
                        c as f32 * s + mn
                    };
                }
            }
            for row in 0..m {
                let xrow = &x[row * k + word * 32..row * k + word * 32 + 32];
                let orow = &mut out[row * n + c0..row * n + c0 + cw];
                for (bit, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let prow = &panel[bit * cw..(bit + 1) * cw];
                    for c in 0..cw {
                        orow[c] += xv * prow[c];
                    }
                }
            }
        }
        c0 += cw;
    }
}

/// Reference f32 GEMM (the FP16-baseline stand-in; f32 on CPU).
pub fn gemm_f32(x: &[f32], m: usize, w: &[f32], k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        let orow = &mut out[row * n..(row + 1) * n];
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            for c in 0..n {
                orow[c] += xv * wrow[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{dequantize, pack_weight, quantize_group};
    use crate::util::prop::forall;
    use crate::util::Rng;

    #[test]
    fn matches_dequantized_reference() {
        forall(
            "dq_gemm == gemm(dequant)",
            12,
            301,
            |rng| {
                let m = 1 + rng.below(8);
                let k = 32 * (1 + rng.below(4));
                let n = 8 + rng.below(64);
                let bits = [2u8, 3, 4][rng.below(3)];
                let g = 32;
                let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
                let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
                (m, k, n, bits, g, w, x)
            },
            |(m, k, n, bits, g, w, x)| {
                let pw = pack_weight(w, *k, *n, *g, *bits);
                let (codes, stats) = quantize_group(w, *k, *n, *g, *bits);
                let wdq = dequantize(&codes, &stats, *k, *n, *g);
                let mut out_ref = vec![0f32; m * n];
                gemm_f32(x, *m, &wdq, *k, *n, &mut out_ref);
                // Every concrete path must agree with the dequantized
                // reference, whatever Auto would pick for this shape.
                let paths =
                    [KernelPath::Auto, KernelPath::Direct, KernelPath::Lut, KernelPath::Panel];
                for path in paths {
                    let mut out = vec![0f32; m * n];
                    dq_gemm_with(&KernelPolicy::with_path(path), x, *m, &pw, &mut out);
                    let max_err = out
                        .iter()
                        .zip(&out_ref)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    if max_err >= 2e-3 {
                        return Err(format!("{}: max err {max_err}", path.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gemv_m1_correct() {
        let mut rng = Rng::new(5);
        let (k, n) = (128, 96);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, 64, 4);
        let mut out = vec![0f32; n];
        let stats = dq_gemm(&x, 1, &pw, &mut out);
        assert!(stats.weight_bytes_read < k * n * 2); // beats fp16 traffic
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn byte_traffic_scales_with_bits() {
        // Plane-layout traffic (the direct path reads the interchange
        // format; LUT lane traffic is bits-independent by design).
        let mut rng = Rng::new(6);
        let (k, n) = (256, 128);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x = vec![1.0f32; k];
        let mut out = vec![0f32; n];
        let direct = KernelPolicy::with_path(KernelPath::Direct);
        let b2 = dq_gemm_with(&direct, &x, 1, &pack_weight(&w, k, n, 64, 2), &mut out)
            .weight_bytes_read;
        let b4 = dq_gemm_with(&direct, &x, 1, &pack_weight(&w, k, n, 64, 4), &mut out)
            .weight_bytes_read;
        assert!(b4 > b2 && b4 < 2 * b2 + k * n, "b2={b2} b4={b4}");
        // Nibble lanes: 2-bit and 4-bit stream the same lane bytes.
        let lut = KernelPolicy::with_path(KernelPath::Lut);
        let l2 = dq_gemm_with(&lut, &x, 1, &pack_weight(&w, k, n, 64, 2), &mut out)
            .weight_bytes_read;
        let l4 = dq_gemm_with(&lut, &x, 1, &pack_weight(&w, k, n, 64, 4), &mut out)
            .weight_bytes_read;
        assert_eq!(l2, l4);
    }

    #[test]
    fn per_path_counters_attribute_calls() {
        let mut rng = Rng::new(8);
        let (k, n, g) = (64usize, 48usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 2);
        let x1: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let x16: Vec<f32> = (0..16 * k).map(|_| rng.normal_f32()).collect();
        let mut o1 = vec![0f32; n];
        let mut o16 = vec![0f32; 16 * n];

        let base = stats::snapshot();
        let d = dq_gemm_with(&KernelPolicy::with_path(KernelPath::Direct), &x1, 1, &pw, &mut o1);
        assert_eq!((d.direct_calls, d.panel_calls, d.lut_calls), (1, 0, 0));
        assert_eq!(d.lane_builds, 0, "direct path never touches lanes");
        let l = dq_gemm_with(&KernelPolicy::with_path(KernelPath::Lut), &x1, 1, &pw, &mut o1);
        assert_eq!((l.direct_calls, l.panel_calls, l.lut_calls), (0, 0, 1));
        assert_eq!((l.lut_nibble_calls, l.lut_byte_calls), (1, 0));
        assert_eq!(l.lut_builds, 1, "one pair-table family per GEMV row");
        assert_eq!(l.lane_builds, 1, "first lane use converts the planes");
        let p =
            dq_gemm_with(&KernelPolicy::with_path(KernelPath::Panel), &x16, 16, &pw, &mut o16);
        assert_eq!((p.direct_calls, p.panel_calls, p.lut_calls), (0, 1, 0));
        assert_eq!(p.panel_unpacks, 0, "lane-native panel does no plane reassembly");
        assert_eq!(p.lane_builds, 0, "lanes already resident after the LUT call");
        assert!(p.lut_builds >= k / g, "group-aligned panel decodes via dequant tables");
        let delta = stats::snapshot().delta_from(base);
        assert!(delta.direct_calls >= 1 && delta.lut_calls >= 1 && delta.panel_calls >= 1);
        assert!(delta.lut_nibble_calls >= 1);
        assert!(delta.lane_builds >= 1);
    }

    /// Byte-lane attribution: a 5-bit weight through the LUT path counts
    /// as `lut_byte_calls`; the panel path decodes its byte lanes too.
    #[test]
    fn byte_lane_counters_attribute_flavor() {
        let mut rng = Rng::new(15);
        let (k, n, g) = (64usize, 48usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 6);
        assert!(!pw.nibble_lanes());
        let x1: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let mut o1 = vec![0f32; n];
        let l = dq_gemm_with(&KernelPolicy::with_path(KernelPath::Lut), &x1, 1, &pw, &mut o1);
        assert_eq!((l.lut_calls, l.lut_nibble_calls, l.lut_byte_calls), (1, 0, 1));
        assert_eq!(l.lane_builds, 1);
        let x16: Vec<f32> = (0..16 * k).map(|_| rng.normal_f32()).collect();
        let mut o16 = vec![0f32; 16 * n];
        let p =
            dq_gemm_with(&KernelPolicy::with_path(KernelPath::Panel), &x16, 16, &pw, &mut o16);
        assert_eq!(p.panel_calls, 1);
        assert_eq!(p.panel_unpacks, 0);
        assert_eq!(p.lane_builds, 0);
    }

    /// The lane-native panel is bit-identical to the retained
    /// plane-reassembly decoder over aligned and unaligned group grids
    /// and both lane kinds (the PR 5 "same output, no plane traffic"
    /// contract).
    #[test]
    fn panel_lane_decode_matches_plane_decode() {
        let mut rng = Rng::new(23);
        for (m, k, n, g, bits) in [
            (16usize, 128usize, 200usize, 32usize, 2u8), // aligned nibble
            (16, 128, 130, 64, 4),                       // aligned nibble, ragged tile
            (12, 96, 140, 32, 5),                        // aligned byte (5-bit)
            (9, 128, 150, 64, 8),                        // aligned byte (8-bit)
            (8, 64, 90, 16, 3),                          // unaligned: word spans groups
            (8, 1056, 40, 33, 6),                        // odd group byte lanes
        ] {
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let pw = pack_weight(&w, k, n, g, bits);
            let mut out_lane = vec![0f32; m * n];
            let mut out_plane = vec![0f32; m * n];
            // The live SIMD tier must still match the scalar plane
            // reference bit-for-bit (the tier is identity-preserving).
            dq_gemm_panel_rows(
                simd::current_tier(),
                &x,
                m,
                &pw,
                pw.interleaved(),
                None,
                &mut out_lane,
            );
            dq_gemm_panel_rows_planes(&x, m, &pw, &mut out_plane);
            let identical = out_lane
                .iter()
                .zip(&out_plane)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(identical, "m{m} k{k} n{n} g{g} b{bits}: lane panel != plane panel");
        }
    }

    #[test]
    fn gemm_f32_known() {
        let x = [1.0, 2.0];
        let w = [3.0, 4.0, 5.0, 6.0]; // 2x2
        let mut out = vec![0.0; 2];
        gemm_f32(&x, 1, &w, 2, 2, &mut out);
        assert_eq!(out, vec![13.0, 16.0]);
    }
}
