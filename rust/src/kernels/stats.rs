//! Kernel traffic accounting: per-call [`DqKernelStats`] and a
//! process-wide [`KernelPathStats`] accumulator so coordinator surfaces
//! (`ServerReport`, `PipelineResult`) can attribute traffic per kernel
//! path without threading a registry through every GEMM call.
//!
//! Per-owner attribution mirrors `runtime::cache`: a thread that calls
//! [`attach_thread_sink`] additionally counts its traffic into a shared
//! [`KernelPathSink`], so e.g. a serving runtime whose kernels only run
//! on its own worker threads reads exact per-runtime counters even with
//! other runtimes or pipelines live in the same process.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};

use crate::quant::PackedWeight;

/// Counters for one `dq_gemm` call (the §Perf log rows). Exactly one of
/// the `*_calls` fields is 1 per call — which path served it.
#[derive(Clone, Copy, Debug, Default)]
pub struct DqKernelStats {
    /// Packed bytes the selected path actually streams: planes + grids
    /// for the direct/panel paths, interleaved lanes + grids for LUT.
    pub weight_bytes_read: usize,
    pub flops: usize,
    pub direct_calls: usize,
    pub panel_calls: usize,
    pub lut_calls: usize,
    /// 32-row x col-tile blocks dequantized by the panel path.
    pub panel_unpacks: usize,
    /// Table constructions by the LUT family: one per GEMV row on the
    /// LUT path, one per (group, col-tile) dequant grid on the panel
    /// path when it decodes through the per-group table.
    pub lut_builds: usize,
}

impl DqKernelStats {
    /// Base byte/flop accounting for an `m`-row call over `w`, reading
    /// `weight_bytes` of packed weight data.
    pub(crate) fn for_traffic(w: &PackedWeight, m: usize, weight_bytes: usize) -> DqKernelStats {
        DqKernelStats {
            weight_bytes_read: weight_bytes,
            flops: 2 * m * w.k * w.n,
            ..DqKernelStats::default()
        }
    }

    /// Plane-layout traffic (direct and panel paths).
    pub(crate) fn for_planes(w: &PackedWeight, m: usize) -> DqKernelStats {
        Self::for_traffic(w, m, w.planes.len() * 4 + w.stats.scale.len() * 8)
    }

    /// Interleaved-lane traffic (LUT path).
    pub(crate) fn for_lanes(w: &PackedWeight, m: usize) -> DqKernelStats {
        let lanes = (w.k / w.group_size) * w.n * w.lane_len();
        Self::for_traffic(w, m, lanes + w.stats.scale.len() * 8)
    }
}

/// Process-wide per-path call counters (monotonic). Snapshot with
/// [`snapshot`], diff with [`KernelPathStats::delta_from`] — the same
/// pattern as `runtime::cache::stats`, and with the same caveat:
/// counters are global, so concurrently-live runtimes see each other's
/// traffic in their deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelPathStats {
    pub direct_calls: u64,
    pub panel_calls: u64,
    pub lut_calls: u64,
    pub panel_unpacks: u64,
    pub lut_builds: u64,
}

impl KernelPathStats {
    pub fn delta_from(&self, base: KernelPathStats) -> KernelPathStats {
        KernelPathStats {
            direct_calls: self.direct_calls.saturating_sub(base.direct_calls),
            panel_calls: self.panel_calls.saturating_sub(base.panel_calls),
            lut_calls: self.lut_calls.saturating_sub(base.lut_calls),
            panel_unpacks: self.panel_unpacks.saturating_sub(base.panel_unpacks),
            lut_builds: self.lut_builds.saturating_sub(base.lut_builds),
        }
    }

    pub fn total_calls(&self) -> u64 {
        self.direct_calls + self.panel_calls + self.lut_calls
    }
}

static DIRECT_CALLS: AtomicU64 = AtomicU64::new(0);
static PANEL_CALLS: AtomicU64 = AtomicU64::new(0);
static LUT_CALLS: AtomicU64 = AtomicU64::new(0);
static PANEL_UNPACKS: AtomicU64 = AtomicU64::new(0);
static LUT_BUILDS: AtomicU64 = AtomicU64::new(0);

/// A shareable per-path accumulator for per-owner attribution (see the
/// module docs). Read with [`KernelPathSink::stats`].
#[derive(Debug, Default)]
pub struct KernelPathSink {
    direct_calls: AtomicU64,
    panel_calls: AtomicU64,
    lut_calls: AtomicU64,
    panel_unpacks: AtomicU64,
    lut_builds: AtomicU64,
}

impl KernelPathSink {
    pub fn stats(&self) -> KernelPathStats {
        KernelPathStats {
            direct_calls: self.direct_calls.load(Ordering::Relaxed),
            panel_calls: self.panel_calls.load(Ordering::Relaxed),
            lut_calls: self.lut_calls.load(Ordering::Relaxed),
            panel_unpacks: self.panel_unpacks.load(Ordering::Relaxed),
            lut_builds: self.lut_builds.load(Ordering::Relaxed),
        }
    }

    fn add(&self, s: &DqKernelStats) {
        self.direct_calls.fetch_add(s.direct_calls as u64, Ordering::Relaxed);
        self.panel_calls.fetch_add(s.panel_calls as u64, Ordering::Relaxed);
        self.lut_calls.fetch_add(s.lut_calls as u64, Ordering::Relaxed);
        self.panel_unpacks.fetch_add(s.panel_unpacks as u64, Ordering::Relaxed);
        self.lut_builds.fetch_add(s.lut_builds as u64, Ordering::Relaxed);
    }
}

thread_local! {
    static THREAD_SINKS: RefCell<Vec<Weak<KernelPathSink>>> = const { RefCell::new(Vec::new()) };
}

/// Make every later `dq_gemm` on the *calling thread* also count into
/// `sink` (weak registration: dies with the sink or the thread).
pub fn attach_thread_sink(sink: &Arc<KernelPathSink>) {
    THREAD_SINKS.with(|s| s.borrow_mut().push(Arc::downgrade(sink)));
}

/// Fold one call's stats into the process-wide accumulator and any sinks
/// attached to this thread (the `dq_gemm` dispatcher calls this once per
/// call).
pub(crate) fn record(s: &DqKernelStats) {
    DIRECT_CALLS.fetch_add(s.direct_calls as u64, Ordering::Relaxed);
    PANEL_CALLS.fetch_add(s.panel_calls as u64, Ordering::Relaxed);
    LUT_CALLS.fetch_add(s.lut_calls as u64, Ordering::Relaxed);
    PANEL_UNPACKS.fetch_add(s.panel_unpacks as u64, Ordering::Relaxed);
    LUT_BUILDS.fetch_add(s.lut_builds as u64, Ordering::Relaxed);
    THREAD_SINKS.with(|sinks| {
        sinks.borrow_mut().retain(|w| match w.upgrade() {
            Some(sink) => {
                sink.add(s);
                true
            }
            None => false,
        });
    });
}

/// Current process-wide counters.
pub fn snapshot() -> KernelPathStats {
    KernelPathStats {
        direct_calls: DIRECT_CALLS.load(Ordering::Relaxed),
        panel_calls: PANEL_CALLS.load(Ordering::Relaxed),
        lut_calls: LUT_CALLS.load(Ordering::Relaxed),
        panel_unpacks: PANEL_UNPACKS.load(Ordering::Relaxed),
        lut_builds: LUT_BUILDS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_subtracts_fieldwise() {
        let base = KernelPathStats { direct_calls: 2, lut_calls: 1, ..Default::default() };
        let now = KernelPathStats {
            direct_calls: 5,
            lut_calls: 4,
            lut_builds: 7,
            ..Default::default()
        };
        let d = now.delta_from(base);
        assert_eq!(d.direct_calls, 3);
        assert_eq!(d.lut_calls, 3);
        assert_eq!(d.lut_builds, 7);
        assert_eq!(d.total_calls(), 6);
    }

    #[test]
    fn thread_sink_counts_only_its_thread() {
        let sink = Arc::new(KernelPathSink::default());
        let s = Arc::clone(&sink);
        std::thread::spawn(move || {
            attach_thread_sink(&s);
            record(&DqKernelStats { direct_calls: 1, ..Default::default() });
            record(&DqKernelStats { lut_calls: 1, lut_builds: 2, ..Default::default() });
        })
        .join()
        .unwrap();
        // This thread never attached the sink: its records don't land.
        record(&DqKernelStats { panel_calls: 1, ..Default::default() });
        let got = sink.stats();
        assert_eq!(got.direct_calls, 1);
        assert_eq!(got.lut_calls, 1);
        assert_eq!(got.lut_builds, 2);
        assert_eq!(got.panel_calls, 0);
    }

    #[test]
    fn record_moves_global_counters() {
        let base = snapshot();
        record(&DqKernelStats { lut_calls: 1, lut_builds: 3, ..Default::default() });
        record(&DqKernelStats { panel_calls: 1, panel_unpacks: 2, ..Default::default() });
        let d = snapshot().delta_from(base);
        // Other tests may run kernels concurrently; counters only grow.
        assert!(d.lut_calls >= 1 && d.lut_builds >= 3);
        assert!(d.panel_calls >= 1 && d.panel_unpacks >= 2);
    }
}
