//! Kernel traffic accounting: per-call [`DqKernelStats`] and a
//! process-wide [`KernelPathStats`] accumulator so coordinator surfaces
//! (`ServerReport`, `PipelineResult`) can attribute traffic per kernel
//! path without threading a registry through every GEMM call.
//!
//! Per-owner attribution mirrors `runtime::cache`: a thread that calls
//! [`attach_thread_sink`] additionally counts its traffic into a shared
//! [`KernelPathSink`], so e.g. a serving runtime whose kernels only run
//! on its own worker threads reads exact per-runtime counters even with
//! other runtimes or pipelines live in the same process.
//!
//! Besides the per-path call counters, [`lane_builds`] counts
//! `planes_to_interleaved` conversions (the lazy lane-cache build in
//! `PackedWeight::interleaved`). A cold load from a `.lieq` v2 archive
//! that persisted its lane images must leave this counter untouched —
//! the acceptance check `kernel_path_stats().lane_builds == 0` after a
//! cold serve is what "cold-start-free" means.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Weak};

use crate::quant::PackedWeight;

/// Counters for one `dq_gemm` call (the §Perf log rows). Exactly one of
/// the `*_calls` fields is 1 per call — which path served it.
#[derive(Clone, Copy, Debug, Default)]
pub struct DqKernelStats {
    /// Packed bytes the selected path actually streams: planes + grids
    /// for the direct path, interleaved lanes + grids for the LUT and
    /// lane-native panel paths.
    pub weight_bytes_read: usize,
    pub flops: usize,
    pub direct_calls: usize,
    pub panel_calls: usize,
    /// Total LUT-family calls (= `lut_nibble_calls + lut_byte_calls`).
    pub lut_calls: usize,
    /// LUT calls decoded through code-pair tables over nibble lanes
    /// (bits <= 4, even group).
    pub lut_nibble_calls: usize,
    /// LUT calls decoded through single-code tables over byte lanes
    /// (bits 5–8, or any bit-width with an odd group size).
    pub lut_byte_calls: usize,
    /// 32-row x col-tile blocks still decoded by bit-plane reassembly.
    /// The lane-native panel path reads interleaved lanes instead, so
    /// this stays 0 there — it only moves on the direct path's plane
    /// loops (never) or a future plane-only fallback.
    pub panel_unpacks: usize,
    /// Table constructions by the LUT family: one per GEMV row on the
    /// LUT paths (pair tables for nibble lanes, single-code tables for
    /// byte lanes), one per (group, col-tile) dequant grid on the panel
    /// path when it decodes through the per-group table.
    pub lut_builds: usize,
    /// `planes_to_interleaved` conversions triggered by this call (lazy
    /// lane-cache builds; 0 when the lane image was already resident —
    /// warm, or persisted in a `.lieq` v2 archive). Informational: the
    /// conversion counts itself into the process-wide/sink counters at
    /// build time, so [`record`] does not fold this field again.
    pub lane_builds: usize,
    /// Direct-path calls whose inner loops ran on a live SIMD tier
    /// (portable/AVX2/NEON — anything but `off`). Subset of
    /// `direct_calls`.
    pub simd_direct_calls: usize,
    /// Panel-path calls on a live SIMD tier. Subset of `panel_calls`.
    pub simd_panel_calls: usize,
    /// LUT-path calls on a live SIMD tier (SIMD table builds; the octet
    /// gather additionally on AVX2). Subset of `lut_calls`.
    pub simd_lut_calls: usize,
    /// Integer W·A8 GEMV calls (the fourth path — disjoint from the
    /// three f32 paths above).
    pub a8_calls: usize,
    /// Sparse outlier columns fused into this call's dense pass (the
    /// extracted fp16 sidecar width; 0 for purely dense weights).
    pub outlier_cols: usize,
    /// Calls that fused an outlier sidecar into their dense pass (1 when
    /// the weight carries outliers, regardless of the path selected).
    pub outlier_fused_calls: usize,
}

impl DqKernelStats {
    /// Base byte/flop accounting for an `m`-row call over `w`, reading
    /// `weight_bytes` of packed weight data.
    pub(crate) fn for_traffic(w: &PackedWeight, m: usize, weight_bytes: usize) -> DqKernelStats {
        DqKernelStats {
            weight_bytes_read: weight_bytes,
            flops: 2 * m * w.k * w.n,
            ..DqKernelStats::default()
        }
    }

    /// Plane-layout traffic (direct path).
    pub(crate) fn for_planes(w: &PackedWeight, m: usize) -> DqKernelStats {
        Self::for_traffic(w, m, w.planes.len() * 4 + w.stats.scale.len() * 8)
    }

    /// Interleaved-lane traffic (LUT and lane-native panel paths).
    pub(crate) fn for_lanes(w: &PackedWeight, m: usize) -> DqKernelStats {
        let lanes = (w.k / w.group_size) * w.n * w.lane_len();
        Self::for_traffic(w, m, lanes + w.stats.scale.len() * 8)
    }
}

/// Process-wide per-path call counters (monotonic). Snapshot with
/// [`snapshot`], diff with [`KernelPathStats::delta_from`] — the same
/// pattern as `runtime::cache::stats`, and with the same caveat:
/// counters are global, so concurrently-live runtimes see each other's
/// traffic in their deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelPathStats {
    pub direct_calls: u64,
    pub panel_calls: u64,
    /// Total LUT-family calls (nibble + byte).
    pub lut_calls: u64,
    pub lut_nibble_calls: u64,
    pub lut_byte_calls: u64,
    pub panel_unpacks: u64,
    pub lut_builds: u64,
    /// `planes_to_interleaved` lane-cache builds (see [`DqKernelStats::lane_builds`]).
    pub lane_builds: u64,
    /// Per-tier attribution (see the [`DqKernelStats`] fields): how many
    /// of the path calls above ran SIMD inner loops, plus the disjoint
    /// integer A8 path.
    pub simd_direct_calls: u64,
    pub simd_panel_calls: u64,
    pub simd_lut_calls: u64,
    pub a8_calls: u64,
    /// Sparse outlier columns fused across all calls (sums each call's
    /// sidecar width — a traffic measure, not a call count).
    pub outlier_cols: u64,
    /// Calls that fused an outlier sidecar (subset of the path calls).
    pub outlier_fused_calls: u64,
}

impl KernelPathStats {
    pub fn delta_from(&self, base: KernelPathStats) -> KernelPathStats {
        KernelPathStats {
            direct_calls: self.direct_calls.saturating_sub(base.direct_calls),
            panel_calls: self.panel_calls.saturating_sub(base.panel_calls),
            lut_calls: self.lut_calls.saturating_sub(base.lut_calls),
            lut_nibble_calls: self.lut_nibble_calls.saturating_sub(base.lut_nibble_calls),
            lut_byte_calls: self.lut_byte_calls.saturating_sub(base.lut_byte_calls),
            panel_unpacks: self.panel_unpacks.saturating_sub(base.panel_unpacks),
            lut_builds: self.lut_builds.saturating_sub(base.lut_builds),
            lane_builds: self.lane_builds.saturating_sub(base.lane_builds),
            simd_direct_calls: self.simd_direct_calls.saturating_sub(base.simd_direct_calls),
            simd_panel_calls: self.simd_panel_calls.saturating_sub(base.simd_panel_calls),
            simd_lut_calls: self.simd_lut_calls.saturating_sub(base.simd_lut_calls),
            a8_calls: self.a8_calls.saturating_sub(base.a8_calls),
            outlier_cols: self.outlier_cols.saturating_sub(base.outlier_cols),
            outlier_fused_calls: self
                .outlier_fused_calls
                .saturating_sub(base.outlier_fused_calls),
        }
    }

    pub fn total_calls(&self) -> u64 {
        // simd_* are subsets of the path counters; a8 is its own path.
        self.direct_calls + self.panel_calls + self.lut_calls + self.a8_calls
    }
}

static DIRECT_CALLS: AtomicU64 = AtomicU64::new(0);
static PANEL_CALLS: AtomicU64 = AtomicU64::new(0);
static LUT_CALLS: AtomicU64 = AtomicU64::new(0);
static LUT_NIBBLE_CALLS: AtomicU64 = AtomicU64::new(0);
static LUT_BYTE_CALLS: AtomicU64 = AtomicU64::new(0);
static PANEL_UNPACKS: AtomicU64 = AtomicU64::new(0);
static LUT_BUILDS: AtomicU64 = AtomicU64::new(0);
static LANE_BUILDS: AtomicU64 = AtomicU64::new(0);
static SIMD_DIRECT_CALLS: AtomicU64 = AtomicU64::new(0);
static SIMD_PANEL_CALLS: AtomicU64 = AtomicU64::new(0);
static SIMD_LUT_CALLS: AtomicU64 = AtomicU64::new(0);
static A8_CALLS: AtomicU64 = AtomicU64::new(0);
static OUTLIER_COLS: AtomicU64 = AtomicU64::new(0);
static OUTLIER_FUSED_CALLS: AtomicU64 = AtomicU64::new(0);

/// A shareable per-path accumulator for per-owner attribution (see the
/// module docs). Read with [`KernelPathSink::stats`].
#[derive(Debug, Default)]
pub struct KernelPathSink {
    direct_calls: AtomicU64,
    panel_calls: AtomicU64,
    lut_calls: AtomicU64,
    lut_nibble_calls: AtomicU64,
    lut_byte_calls: AtomicU64,
    panel_unpacks: AtomicU64,
    lut_builds: AtomicU64,
    lane_builds: AtomicU64,
    simd_direct_calls: AtomicU64,
    simd_panel_calls: AtomicU64,
    simd_lut_calls: AtomicU64,
    a8_calls: AtomicU64,
    outlier_cols: AtomicU64,
    outlier_fused_calls: AtomicU64,
}

impl KernelPathSink {
    pub fn stats(&self) -> KernelPathStats {
        KernelPathStats {
            direct_calls: self.direct_calls.load(Ordering::Relaxed),
            panel_calls: self.panel_calls.load(Ordering::Relaxed),
            lut_calls: self.lut_calls.load(Ordering::Relaxed),
            lut_nibble_calls: self.lut_nibble_calls.load(Ordering::Relaxed),
            lut_byte_calls: self.lut_byte_calls.load(Ordering::Relaxed),
            panel_unpacks: self.panel_unpacks.load(Ordering::Relaxed),
            lut_builds: self.lut_builds.load(Ordering::Relaxed),
            lane_builds: self.lane_builds.load(Ordering::Relaxed),
            simd_direct_calls: self.simd_direct_calls.load(Ordering::Relaxed),
            simd_panel_calls: self.simd_panel_calls.load(Ordering::Relaxed),
            simd_lut_calls: self.simd_lut_calls.load(Ordering::Relaxed),
            a8_calls: self.a8_calls.load(Ordering::Relaxed),
            outlier_cols: self.outlier_cols.load(Ordering::Relaxed),
            outlier_fused_calls: self.outlier_fused_calls.load(Ordering::Relaxed),
        }
    }

    /// Fold one call's stats in — all but `lane_builds`, which arrives
    /// through [`KernelPathSink::add_lane_build`] at conversion time
    /// (see [`record`] for why re-adding it would double-count).
    fn add(&self, s: &DqKernelStats) {
        self.direct_calls.fetch_add(s.direct_calls as u64, Ordering::Relaxed);
        self.panel_calls.fetch_add(s.panel_calls as u64, Ordering::Relaxed);
        self.lut_calls.fetch_add(s.lut_calls as u64, Ordering::Relaxed);
        self.lut_nibble_calls.fetch_add(s.lut_nibble_calls as u64, Ordering::Relaxed);
        self.lut_byte_calls.fetch_add(s.lut_byte_calls as u64, Ordering::Relaxed);
        self.panel_unpacks.fetch_add(s.panel_unpacks as u64, Ordering::Relaxed);
        self.lut_builds.fetch_add(s.lut_builds as u64, Ordering::Relaxed);
        self.simd_direct_calls.fetch_add(s.simd_direct_calls as u64, Ordering::Relaxed);
        self.simd_panel_calls.fetch_add(s.simd_panel_calls as u64, Ordering::Relaxed);
        self.simd_lut_calls.fetch_add(s.simd_lut_calls as u64, Ordering::Relaxed);
        self.a8_calls.fetch_add(s.a8_calls as u64, Ordering::Relaxed);
        self.outlier_cols.fetch_add(s.outlier_cols as u64, Ordering::Relaxed);
        self.outlier_fused_calls.fetch_add(s.outlier_fused_calls as u64, Ordering::Relaxed);
    }

    fn add_lane_build(&self) {
        self.lane_builds.fetch_add(1, Ordering::Relaxed);
    }
}

thread_local! {
    static THREAD_SINKS: RefCell<Vec<Weak<KernelPathSink>>> = const { RefCell::new(Vec::new()) };
}

/// Make every later `dq_gemm` on the *calling thread* also count into
/// `sink` (weak registration: dies with the sink or the thread).
pub fn attach_thread_sink(sink: &Arc<KernelPathSink>) {
    THREAD_SINKS.with(|s| s.borrow_mut().push(Arc::downgrade(sink)));
}

/// Fold one call's stats into the process-wide accumulator and any sinks
/// attached to this thread (the `dq_gemm` dispatcher calls this once per
/// call). `lane_builds` is deliberately **not** folded here: the actual
/// conversion already counted itself through [`record_lane_build`] when
/// `PackedWeight::interleaved` ran it — the per-call field is
/// informational (cold-call attribution) and re-adding it would double
/// every build in the global/sink counters.
pub(crate) fn record(s: &DqKernelStats) {
    DIRECT_CALLS.fetch_add(s.direct_calls as u64, Ordering::Relaxed);
    PANEL_CALLS.fetch_add(s.panel_calls as u64, Ordering::Relaxed);
    LUT_CALLS.fetch_add(s.lut_calls as u64, Ordering::Relaxed);
    LUT_NIBBLE_CALLS.fetch_add(s.lut_nibble_calls as u64, Ordering::Relaxed);
    LUT_BYTE_CALLS.fetch_add(s.lut_byte_calls as u64, Ordering::Relaxed);
    PANEL_UNPACKS.fetch_add(s.panel_unpacks as u64, Ordering::Relaxed);
    LUT_BUILDS.fetch_add(s.lut_builds as u64, Ordering::Relaxed);
    SIMD_DIRECT_CALLS.fetch_add(s.simd_direct_calls as u64, Ordering::Relaxed);
    SIMD_PANEL_CALLS.fetch_add(s.simd_panel_calls as u64, Ordering::Relaxed);
    SIMD_LUT_CALLS.fetch_add(s.simd_lut_calls as u64, Ordering::Relaxed);
    A8_CALLS.fetch_add(s.a8_calls as u64, Ordering::Relaxed);
    OUTLIER_COLS.fetch_add(s.outlier_cols as u64, Ordering::Relaxed);
    OUTLIER_FUSED_CALLS.fetch_add(s.outlier_fused_calls as u64, Ordering::Relaxed);
    THREAD_SINKS.with(|sinks| {
        sinks.borrow_mut().retain(|w| match w.upgrade() {
            Some(sink) => {
                sink.add(s);
                true
            }
            None => false,
        });
    });
}

/// Count one `planes_to_interleaved` lane-cache build. Called by
/// `PackedWeight::interleaved` when the conversion actually runs (not on
/// cache hits or when the lane image was seeded from an archive), so
/// "zero cold conversions" is checkable via [`snapshot`].
pub(crate) fn record_lane_build() {
    LANE_BUILDS.fetch_add(1, Ordering::Relaxed);
    THREAD_SINKS.with(|sinks| {
        sinks.borrow_mut().retain(|w| match w.upgrade() {
            Some(sink) => {
                sink.add_lane_build();
                true
            }
            None => false,
        });
    });
}

/// Current process-wide counters.
pub fn snapshot() -> KernelPathStats {
    KernelPathStats {
        direct_calls: DIRECT_CALLS.load(Ordering::Relaxed),
        panel_calls: PANEL_CALLS.load(Ordering::Relaxed),
        lut_calls: LUT_CALLS.load(Ordering::Relaxed),
        lut_nibble_calls: LUT_NIBBLE_CALLS.load(Ordering::Relaxed),
        lut_byte_calls: LUT_BYTE_CALLS.load(Ordering::Relaxed),
        panel_unpacks: PANEL_UNPACKS.load(Ordering::Relaxed),
        lut_builds: LUT_BUILDS.load(Ordering::Relaxed),
        lane_builds: LANE_BUILDS.load(Ordering::Relaxed),
        simd_direct_calls: SIMD_DIRECT_CALLS.load(Ordering::Relaxed),
        simd_panel_calls: SIMD_PANEL_CALLS.load(Ordering::Relaxed),
        simd_lut_calls: SIMD_LUT_CALLS.load(Ordering::Relaxed),
        a8_calls: A8_CALLS.load(Ordering::Relaxed),
        outlier_cols: OUTLIER_COLS.load(Ordering::Relaxed),
        outlier_fused_calls: OUTLIER_FUSED_CALLS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_subtracts_fieldwise() {
        let base = KernelPathStats { direct_calls: 2, lut_calls: 1, ..Default::default() };
        let now = KernelPathStats {
            direct_calls: 5,
            lut_calls: 4,
            lut_builds: 7,
            lane_builds: 2,
            outlier_cols: 40,
            outlier_fused_calls: 3,
            ..Default::default()
        };
        let d = now.delta_from(base);
        assert_eq!(d.direct_calls, 3);
        assert_eq!(d.lut_calls, 3);
        assert_eq!(d.lut_builds, 7);
        assert_eq!(d.lane_builds, 2);
        assert_eq!(d.outlier_cols, 40);
        assert_eq!(d.outlier_fused_calls, 3);
        assert_eq!(d.total_calls(), 6);
    }

    #[test]
    fn thread_sink_counts_only_its_thread() {
        let sink = Arc::new(KernelPathSink::default());
        let s = Arc::clone(&sink);
        std::thread::spawn(move || {
            attach_thread_sink(&s);
            record(&DqKernelStats { direct_calls: 1, ..Default::default() });
            record(&DqKernelStats {
                lut_calls: 1,
                lut_byte_calls: 1,
                lut_builds: 2,
                ..Default::default()
            });
            record_lane_build();
        })
        .join()
        .unwrap();
        // This thread never attached the sink: its records don't land.
        record(&DqKernelStats { panel_calls: 1, ..Default::default() });
        record_lane_build();
        let got = sink.stats();
        assert_eq!(got.direct_calls, 1);
        assert_eq!(got.lut_calls, 1);
        assert_eq!(got.lut_byte_calls, 1);
        assert_eq!(got.lut_nibble_calls, 0);
        assert_eq!(got.lut_builds, 2);
        assert_eq!(got.lane_builds, 1);
        assert_eq!(got.panel_calls, 0);
    }

    #[test]
    fn record_moves_global_counters() {
        let base = snapshot();
        record(&DqKernelStats {
            lut_calls: 1,
            lut_nibble_calls: 1,
            lut_builds: 3,
            ..Default::default()
        });
        record(&DqKernelStats { panel_calls: 1, ..Default::default() });
        record_lane_build();
        let d = snapshot().delta_from(base);
        // Other tests may run kernels concurrently; counters only grow.
        assert!(d.lut_calls >= 1 && d.lut_nibble_calls >= 1 && d.lut_builds >= 3);
        assert!(d.panel_calls >= 1);
        assert!(d.lane_builds >= 1);
    }
}
