//! Runtime-ISA-detected SIMD tier for the fused-dequant kernel family.
//!
//! Every primitive here vectorizes across the **output-column axis**:
//! one vector lane holds one output column's accumulator, there is no
//! cross-lane reduction, and multiplies/adds are emitted separately
//! (never fused into an FMA). Per column, the SIMD tiers therefore
//! execute the *same rounded FP expression in the same order* as the
//! scalar reference — the whole tier is bit-identical to scalar by
//! construction, which is what lets `Auto` turn it on everywhere
//! without perturbing the crate's bit-identical-at-any-thread-count
//! contract (`tests/parallel.rs` pins this per path).
//!
//! Tiers, in probe order:
//!
//! * **avx2** — x86_64 with runtime `is_x86_feature_detected!("avx2")`;
//!   8-lane f32/i32 intrinsics, plus the LUT gather
//!   (`_mm256_i32gather_ps`) for 8-column table lookups.
//! * **neon** — aarch64 (NEON is architecturally mandatory there);
//!   4-lane intrinsics, mul+add kept separate (no `vfma`) for
//!   bit-identity.
//! * **portable** — fixed-width `[f32; 8]` / `[u32; 8]` chunk loops the
//!   autovectorizer can lower on any ISA; always correct, always
//!   scalar-identical.
//! * **off** — the plain scalar loops (the reference the other tiers
//!   are pinned against).
//!
//! Resolution mirrors `KernelPath`: the CLI `--simd` override if set,
//! else `LIEQ_SIMD=off|auto|avx2|neon|portable`, else `auto` (probe).
//! Forcing a tier the running CPU cannot execute (`avx2` on aarch64,
//! `neon` on x86_64, `avx2` on a pre-AVX2 x86) resolves to **portable**
//! — a forced override changes speed, never correctness.

use std::sync::atomic::{AtomicU8, Ordering};

/// One concrete SIMD capability level. `Off` is the scalar reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    Off,
    Portable,
    Avx2,
    Neon,
}

/// Requested tier: `Auto` probes the CPU, `Force` pins one (falling
/// back to `Portable` when the pinned ISA is unavailable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    Auto,
    Force(SimdTier),
}

#[cfg(target_arch = "x86_64")]
fn probe_arch() -> SimdTier {
    if std::arch::is_x86_feature_detected!("avx2") {
        SimdTier::Avx2
    } else {
        SimdTier::Portable
    }
}

#[cfg(target_arch = "aarch64")]
fn probe_arch() -> SimdTier {
    SimdTier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn probe_arch() -> SimdTier {
    SimdTier::Portable
}

impl SimdTier {
    pub fn name(&self) -> &'static str {
        match self {
            SimdTier::Off => "off",
            SimdTier::Portable => "portable",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Can the running CPU execute this tier's code?
    pub fn available(self) -> bool {
        match self {
            SimdTier::Off | SimdTier::Portable => true,
            SimdTier::Avx2 => matches!(probe_arch(), SimdTier::Avx2),
            SimdTier::Neon => matches!(probe_arch(), SimdTier::Neon),
        }
    }
}

impl SimdMode {
    pub fn name(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Force(t) => t.name(),
        }
    }

    pub fn from_name(s: &str) -> Option<SimdMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(SimdMode::Auto),
            "off" => Some(SimdMode::Force(SimdTier::Off)),
            "portable" => Some(SimdMode::Force(SimdTier::Portable)),
            "avx2" => Some(SimdMode::Force(SimdTier::Avx2)),
            "neon" => Some(SimdMode::Force(SimdTier::Neon)),
            _ => None,
        }
    }
}

/// Process-wide tier override; 0 = unset (fall through to env).
static GLOBAL_SIMD: AtomicU8 = AtomicU8::new(0);

fn mode_to_code(m: SimdMode) -> u8 {
    match m {
        SimdMode::Auto => 1,
        SimdMode::Force(SimdTier::Off) => 2,
        SimdMode::Force(SimdTier::Portable) => 3,
        SimdMode::Force(SimdTier::Avx2) => 4,
        SimdMode::Force(SimdTier::Neon) => 5,
    }
}

fn mode_from_code(c: u8) -> Option<SimdMode> {
    match c {
        1 => Some(SimdMode::Auto),
        2 => Some(SimdMode::Force(SimdTier::Off)),
        3 => Some(SimdMode::Force(SimdTier::Portable)),
        4 => Some(SimdMode::Force(SimdTier::Avx2)),
        5 => Some(SimdMode::Force(SimdTier::Neon)),
        _ => None,
    }
}

/// Set the process-wide SIMD mode (the CLI `--simd` flag lands here).
pub fn set_global_simd(mode: SimdMode) {
    GLOBAL_SIMD.store(mode_to_code(mode), Ordering::SeqCst);
}

/// Mode used by [`KernelPolicy::current`](super::KernelPolicy::current):
/// the [`set_global_simd`] override if set, else `LIEQ_SIMD`, else
/// `Auto`.
pub fn global_simd() -> SimdMode {
    if let Some(m) = mode_from_code(GLOBAL_SIMD.load(Ordering::SeqCst)) {
        return m;
    }
    if let Ok(v) = std::env::var("LIEQ_SIMD") {
        if let Some(m) = SimdMode::from_name(&v) {
            return m;
        }
    }
    SimdMode::Auto
}

/// Resolve a mode to the tier that will actually run: `Auto` probes,
/// a forced-but-unavailable ISA degrades to `Portable`.
pub fn resolve(mode: SimdMode) -> SimdTier {
    match mode {
        SimdMode::Auto => probe_arch(),
        SimdMode::Force(t) => {
            if t.available() {
                t
            } else {
                SimdTier::Portable
            }
        }
    }
}

/// The tier the process-wide mode resolves to right now.
pub fn current_tier() -> SimdTier {
    resolve(global_simd())
}

// ---------------------------------------------------------------------------
// f32 primitives. Each dispatches on the tier; every implementation of a
// primitive computes the identical per-element FP expression, so results
// are bit-identical across tiers.
// ---------------------------------------------------------------------------

/// `dst[i] += a * src[i]` (direct min-term, panel GEMM update).
#[inline]
pub fn axpy(tier: SimdTier, dst: &mut [f32], src: &[f32], a: f32) {
    match tier {
        SimdTier::Off => axpy_scalar(dst, src, a),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { axpy_avx2(dst, src, a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { axpy_neon(dst, src, a) },
        _ => axpy_portable(dst, src, a),
    }
}

fn axpy_scalar(dst: &mut [f32], src: &[f32], a: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += a * s;
    }
}

fn axpy_portable(dst: &mut [f32], src: &[f32], a: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut sc = src.chunks_exact(8);
    for (d, s) in (&mut dc).zip(&mut sc) {
        for l in 0..8 {
            d[l] += a * s[l];
        }
    }
    for (d, &s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d += a * s;
    }
}

/// `dst[i] += s[i] * acc[i]` (direct path per-group scale application).
#[inline]
pub fn mul_acc(tier: SimdTier, dst: &mut [f32], s: &[f32], acc: &[f32]) {
    match tier {
        SimdTier::Off => mul_acc_scalar(dst, s, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { mul_acc_avx2(dst, s, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { mul_acc_neon(dst, s, acc) },
        _ => mul_acc_portable(dst, s, acc),
    }
}

fn mul_acc_scalar(dst: &mut [f32], s: &[f32], acc: &[f32]) {
    for (i, d) in dst.iter_mut().enumerate() {
        *d += s[i] * acc[i];
    }
}

fn mul_acc_portable(dst: &mut [f32], s: &[f32], acc: &[f32]) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut sc = s.chunks_exact(8);
    let mut ac = acc.chunks_exact(8);
    for ((d, sv), av) in (&mut dc).zip(&mut sc).zip(&mut ac) {
        for l in 0..8 {
            d[l] += sv[l] * av[l];
        }
    }
    let (sr, ar) = (sc.remainder(), ac.remainder());
    for (i, d) in dc.into_remainder().iter_mut().enumerate() {
        *d += sr[i] * ar[i];
    }
}

/// `dst[i] += s[i] * acc[i] + mn[i] * gs` (LUT per-group affine).
#[inline]
pub fn affine_acc(tier: SimdTier, dst: &mut [f32], s: &[f32], acc: &[f32], mn: &[f32], gs: f32) {
    match tier {
        SimdTier::Off => affine_acc_scalar(dst, s, acc, mn, gs),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { affine_acc_avx2(dst, s, acc, mn, gs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { affine_acc_neon(dst, s, acc, mn, gs) },
        _ => affine_acc_portable(dst, s, acc, mn, gs),
    }
}

fn affine_acc_scalar(dst: &mut [f32], s: &[f32], acc: &[f32], mn: &[f32], gs: f32) {
    for (i, d) in dst.iter_mut().enumerate() {
        *d += s[i] * acc[i] + mn[i] * gs;
    }
}

fn affine_acc_portable(dst: &mut [f32], s: &[f32], acc: &[f32], mn: &[f32], gs: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut sc = s.chunks_exact(8);
    let mut ac = acc.chunks_exact(8);
    let mut mc = mn.chunks_exact(8);
    for (((d, sv), av), mv) in (&mut dc).zip(&mut sc).zip(&mut ac).zip(&mut mc) {
        for l in 0..8 {
            d[l] += sv[l] * av[l] + mv[l] * gs;
        }
    }
    let (sr, ar, mr) = (sc.remainder(), ac.remainder(), mc.remainder());
    for (i, d) in dc.into_remainder().iter_mut().enumerate() {
        *d += sr[i] * ar[i] + mr[i] * gs;
    }
}

/// `dst[i] = a * i as f32` (LUT single-code table rows, pair-table lo
/// ramp). Integer lane indices < 2^24 convert exactly, so the ramp is
/// identical to the scalar `i as f32` loop.
#[inline]
pub fn ramp_scale(tier: SimdTier, dst: &mut [f32], a: f32) {
    match tier {
        SimdTier::Off => ramp_scale_scalar(dst, a),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { ramp_scale_avx2(dst, a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { ramp_scale_neon(dst, a) },
        _ => ramp_scale_portable(dst, a),
    }
}

fn ramp_scale_scalar(dst: &mut [f32], a: f32) {
    for (i, d) in dst.iter_mut().enumerate() {
        *d = a * i as f32;
    }
}

fn ramp_scale_portable(dst: &mut [f32], a: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut base = 0usize;
    for d in &mut dc {
        for l in 0..8 {
            d[l] = a * (base + l) as f32;
        }
        base += 8;
    }
    for (l, d) in dc.into_remainder().iter_mut().enumerate() {
        *d = a * (base + l) as f32;
    }
}

/// `dst[i] = a + src[i]` (LUT pair-table hi rows).
#[inline]
pub fn add_bcast(tier: SimdTier, dst: &mut [f32], src: &[f32], a: f32) {
    match tier {
        SimdTier::Off => add_bcast_scalar(dst, src, a),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { add_bcast_avx2(dst, src, a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { add_bcast_neon(dst, src, a) },
        _ => add_bcast_portable(dst, src, a),
    }
}

fn add_bcast_scalar(dst: &mut [f32], src: &[f32], a: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = a + s;
    }
}

fn add_bcast_portable(dst: &mut [f32], src: &[f32], a: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut sc = src.chunks_exact(8);
    for (d, s) in (&mut dc).zip(&mut sc) {
        for l in 0..8 {
            d[l] = a + s[l];
        }
    }
    for (d, &s) in dc.into_remainder().iter_mut().zip(sc.remainder()) {
        *d = a + s;
    }
}

/// `dst[c] = c as f32 * s + mn` (panel per-group dequant table).
#[inline]
pub fn ramp_affine(tier: SimdTier, dst: &mut [f32], s: f32, mn: f32) {
    match tier {
        SimdTier::Off => ramp_affine_scalar(dst, s, mn),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { ramp_affine_avx2(dst, s, mn) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { ramp_affine_neon(dst, s, mn) },
        _ => ramp_affine_portable(dst, s, mn),
    }
}

fn ramp_affine_scalar(dst: &mut [f32], s: f32, mn: f32) {
    for (c, d) in dst.iter_mut().enumerate() {
        *d = c as f32 * s + mn;
    }
}

fn ramp_affine_portable(dst: &mut [f32], s: f32, mn: f32) {
    let mut dc = dst.chunks_exact_mut(8);
    let mut base = 0usize;
    for d in &mut dc {
        for l in 0..8 {
            d[l] = (base + l) as f32 * s + mn;
        }
        base += 8;
    }
    for (l, d) in dc.into_remainder().iter_mut().enumerate() {
        *d = (base + l) as f32 * s + mn;
    }
}

/// Direct-path code term for one 32-row plane word and one `bit`:
/// `acc[i] += xv * c_i as f32` where `c_i` reassembles one code from
/// the plane rows (`planes[j][i]` contributes bit j). Integer
/// reassembly is exact, so only the final mul+add order matters — and
/// it is identical in every tier.
#[inline]
pub fn decode_accum(tier: SimdTier, acc: &mut [f32], xv: f32, planes: &[&[u32]], bit: u32) {
    match tier {
        SimdTier::Off => decode_accum_scalar(acc, xv, planes, bit),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `resolve` only yields Avx2 after runtime detection.
        SimdTier::Avx2 => unsafe { decode_accum_avx2(acc, xv, planes, bit) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally present on aarch64.
        SimdTier::Neon => unsafe { decode_accum_neon(acc, xv, planes, bit) },
        _ => decode_accum_portable(acc, xv, planes, bit),
    }
}

fn decode_accum_scalar(acc: &mut [f32], xv: f32, planes: &[&[u32]], bit: u32) {
    // Specialized reassembly for the common widths (the pre-SIMD direct
    // path had these exact arms); the generic arm covers 1..=8 bits.
    match planes {
        [p0, p1] => {
            for (i, a) in acc.iter_mut().enumerate() {
                let c = ((p0[i] >> bit) & 1) | (((p1[i] >> bit) & 1) << 1);
                *a += xv * c as f32;
            }
        }
        [p0, p1, p2] => {
            for (i, a) in acc.iter_mut().enumerate() {
                let c = ((p0[i] >> bit) & 1)
                    | (((p1[i] >> bit) & 1) << 1)
                    | (((p2[i] >> bit) & 1) << 2);
                *a += xv * c as f32;
            }
        }
        [p0, p1, p2, p3] => {
            for (i, a) in acc.iter_mut().enumerate() {
                let c = ((p0[i] >> bit) & 1)
                    | (((p1[i] >> bit) & 1) << 1)
                    | (((p2[i] >> bit) & 1) << 2)
                    | (((p3[i] >> bit) & 1) << 3);
                *a += xv * c as f32;
            }
        }
        _ => {
            for (i, a) in acc.iter_mut().enumerate() {
                let mut c = 0u32;
                for (j, p) in planes.iter().enumerate() {
                    c |= ((p[i] >> bit) & 1) << j;
                }
                *a += xv * c as f32;
            }
        }
    }
}

fn decode_accum_portable(acc: &mut [f32], xv: f32, planes: &[&[u32]], bit: u32) {
    let n = acc.len();
    let mut i = 0;
    while i + 8 <= n {
        let mut c = [0u32; 8];
        for (j, p) in planes.iter().enumerate() {
            let pw = &p[i..i + 8];
            for l in 0..8 {
                c[l] |= ((pw[l] >> bit) & 1) << j;
            }
        }
        let a = &mut acc[i..i + 8];
        for l in 0..8 {
            a[l] += xv * c[l] as f32;
        }
        i += 8;
    }
    while i < n {
        let mut c = 0u32;
        for (j, p) in planes.iter().enumerate() {
            c |= ((p[i] >> bit) & 1) << j;
        }
        acc[i] += xv * c as f32;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// AVX2 tier (x86_64, runtime-detected). Mul and add stay separate
// instructions — vfmadd would fuse the rounding and break bit-identity
// with the scalar reference.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    // SAFETY: caller guarantees AVX2 (runtime-detected in `resolve`);
    // all pointer arithmetic stays inside the borrowed slices.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, _mm256_mul_ps(av, s)));
            i += 8;
        }
        while i < n {
            dst[i] += a * src[i];
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2; slices are equal-length per the
    // dispatching wrapper's contract.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul_acc_avx2(dst: &mut [f32], s: &[f32], acc: &[f32]) {
        let n = dst.len();
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let sv = _mm256_loadu_ps(s.as_ptr().add(i));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, _mm256_mul_ps(sv, av)));
            i += 8;
        }
        while i < n {
            dst[i] += s[i] * acc[i];
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2; slices are equal-length.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn affine_acc_avx2(
        dst: &mut [f32],
        s: &[f32],
        acc: &[f32],
        mn: &[f32],
        gs: f32,
    ) {
        let n = dst.len();
        let gv = _mm256_set1_ps(gs);
        let mut i = 0;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let sv = _mm256_loadu_ps(s.as_ptr().add(i));
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            let mv = _mm256_loadu_ps(mn.as_ptr().add(i));
            let t = _mm256_add_ps(_mm256_mul_ps(sv, av), _mm256_mul_ps(mv, gv));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, t));
            i += 8;
        }
        while i < n {
            dst[i] += s[i] * acc[i] + mn[i] * gs;
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn ramp_scale_avx2(dst: &mut [f32], a: f32) {
        let n = dst.len();
        let av = _mm256_set1_ps(a);
        let step = _mm256_set1_ps(8.0);
        // Lane indices < 2^24: the running +8.0 ramp stays exact.
        let mut idx = _mm256_setr_ps(0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0);
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_mul_ps(av, idx));
            idx = _mm256_add_ps(idx, step);
            i += 8;
        }
        while i < n {
            dst[i] = a * i as f32;
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2; slices are equal-length.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn add_bcast_avx2(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(av, s));
            i += 8;
        }
        while i < n {
            dst[i] = a + src[i];
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn ramp_affine_avx2(dst: &mut [f32], s: f32, mn: f32) {
        let n = dst.len();
        let sv = _mm256_set1_ps(s);
        let mv = _mm256_set1_ps(mn);
        let step = _mm256_set1_ps(8.0);
        let mut idx = _mm256_setr_ps(0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_add_ps(_mm256_mul_ps(idx, sv), mv);
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
            idx = _mm256_add_ps(idx, step);
            i += 8;
        }
        while i < n {
            dst[i] = i as f32 * s + mn;
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2; every plane row has the same
    // length as `acc`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn decode_accum_avx2(acc: &mut [f32], xv: f32, planes: &[&[u32]], bit: u32) {
        let n = acc.len();
        let xvv = _mm256_set1_ps(xv);
        let one = _mm256_set1_epi32(1);
        let shr = _mm_cvtsi32_si128(bit as i32);
        let mut i = 0;
        while i + 8 <= n {
            let mut code = _mm256_setzero_si256();
            for (j, p) in planes.iter().enumerate() {
                let v = _mm256_loadu_si256(p.as_ptr().add(i) as *const __m256i);
                let b = _mm256_and_si256(_mm256_srl_epi32(v, shr), one);
                code = _mm256_or_si256(code, _mm256_sll_epi32(b, _mm_cvtsi32_si128(j as i32)));
            }
            // Codes are < 256, so the signed i32→f32 conversion is exact.
            let cf = _mm256_cvtepi32_ps(code);
            let av = _mm256_loadu_ps(acc.as_ptr().add(i));
            _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_add_ps(av, _mm256_mul_ps(xvv, cf)));
            i += 8;
        }
        while i < n {
            let mut c = 0u32;
            for (j, p) in planes.iter().enumerate() {
                c |= ((p[i] >> bit) & 1) << j;
            }
            acc[i] += xv * c as f32;
            i += 1;
        }
    }

    // SAFETY: caller guarantees AVX2, `tg` holds `ll` 256-entry tables,
    // and each of the 8 lane slices has at least `ll` bytes.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn lut_octet_avx2(tg: &[f32], lanes: &[&[u8]; 8], ll: usize) -> [f32; 8] {
        let mut acc = _mm256_setzero_ps();
        for p in 0..ll {
            let idx = _mm256_setr_epi32(
                lanes[0][p] as i32,
                lanes[1][p] as i32,
                lanes[2][p] as i32,
                lanes[3][p] as i32,
                lanes[4][p] as i32,
                lanes[5][p] as i32,
                lanes[6][p] as i32,
                lanes[7][p] as i32,
            );
            let t = _mm256_i32gather_ps::<4>(tg.as_ptr().add(p * 256), idx);
            acc = _mm256_add_ps(acc, t);
        }
        let mut out = [0f32; 8];
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
        out
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{
    add_bcast_avx2, affine_acc_avx2, axpy_avx2, decode_accum_avx2, mul_acc_avx2, ramp_affine_avx2,
    ramp_scale_avx2,
};
#[cfg(target_arch = "x86_64")]
pub(crate) use avx2::lut_octet_avx2;

// ---------------------------------------------------------------------------
// NEON tier (aarch64). 4-lane; mul+add kept separate (no vfmaq) for
// bit-identity with the scalar reference.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    // SAFETY: NEON is mandatory on aarch64; pointer arithmetic stays
    // inside the borrowed slices.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn axpy_neon(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let s = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, vmulq_f32(av, s)));
            i += 4;
        }
        while i < n {
            dst[i] += a * src[i];
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64; slices are equal-length.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mul_acc_neon(dst: &mut [f32], s: &[f32], acc: &[f32]) {
        let n = dst.len();
        let mut i = 0;
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let sv = vld1q_f32(s.as_ptr().add(i));
            let av = vld1q_f32(acc.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, vmulq_f32(sv, av)));
            i += 4;
        }
        while i < n {
            dst[i] += s[i] * acc[i];
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64; slices are equal-length.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn affine_acc_neon(
        dst: &mut [f32],
        s: &[f32],
        acc: &[f32],
        mn: &[f32],
        gs: f32,
    ) {
        let n = dst.len();
        let gv = vdupq_n_f32(gs);
        let mut i = 0;
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let sv = vld1q_f32(s.as_ptr().add(i));
            let av = vld1q_f32(acc.as_ptr().add(i));
            let mv = vld1q_f32(mn.as_ptr().add(i));
            let t = vaddq_f32(vmulq_f32(sv, av), vmulq_f32(mv, gv));
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, t));
            i += 4;
        }
        while i < n {
            dst[i] += s[i] * acc[i] + mn[i] * gs;
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn ramp_scale_neon(dst: &mut [f32], a: f32) {
        let n = dst.len();
        let av = vdupq_n_f32(a);
        let step = vdupq_n_f32(4.0);
        let ramp: [f32; 4] = [0.0, 1.0, 2.0, 3.0];
        let mut idx = vld1q_f32(ramp.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(dst.as_mut_ptr().add(i), vmulq_f32(av, idx));
            idx = vaddq_f32(idx, step);
            i += 4;
        }
        while i < n {
            dst[i] = a * i as f32;
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64; slices are equal-length.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn add_bcast_neon(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len().min(src.len());
        let av = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let s = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(av, s));
            i += 4;
        }
        while i < n {
            dst[i] = a + src[i];
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn ramp_affine_neon(dst: &mut [f32], s: f32, mn: f32) {
        let n = dst.len();
        let sv = vdupq_n_f32(s);
        let mv = vdupq_n_f32(mn);
        let step = vdupq_n_f32(4.0);
        let ramp: [f32; 4] = [0.0, 1.0, 2.0, 3.0];
        let mut idx = vld1q_f32(ramp.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(vmulq_f32(idx, sv), mv));
            idx = vaddq_f32(idx, step);
            i += 4;
        }
        while i < n {
            dst[i] = i as f32 * s + mn;
            i += 1;
        }
    }

    // SAFETY: NEON is mandatory on aarch64; every plane row has the
    // same length as `acc`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn decode_accum_neon(acc: &mut [f32], xv: f32, planes: &[&[u32]], bit: u32) {
        let n = acc.len();
        let xvv = vdupq_n_f32(xv);
        let one = vdupq_n_u32(1);
        let shr = vdupq_n_s32(-(bit as i32));
        let mut i = 0;
        while i + 4 <= n {
            let mut code = vdupq_n_u32(0);
            for (j, p) in planes.iter().enumerate() {
                let v = vld1q_u32(p.as_ptr().add(i));
                let b = vandq_u32(vshlq_u32(v, shr), one);
                code = vorrq_u32(code, vshlq_u32(b, vdupq_n_s32(j as i32)));
            }
            // Codes are < 256, so the u32→f32 conversion is exact.
            let cf = vcvtq_f32_u32(code);
            let av = vld1q_f32(acc.as_ptr().add(i));
            vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(av, vmulq_f32(xvv, cf)));
            i += 4;
        }
        while i < n {
            let mut c = 0u32;
            for (j, p) in planes.iter().enumerate() {
                c |= ((p[i] >> bit) & 1) << j;
            }
            acc[i] += xv * c as f32;
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
use neon::{
    add_bcast_neon, affine_acc_neon, axpy_neon, decode_accum_neon, mul_acc_neon, ramp_affine_neon,
    ramp_scale_neon,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Tiers exercisable on the running CPU: the scalar reference,
    /// portable, and (when present) the native ISA.
    fn live_tiers() -> Vec<SimdTier> {
        let mut v = vec![SimdTier::Off, SimdTier::Portable];
        let native = resolve(SimdMode::Auto);
        if !v.contains(&native) {
            v.push(native);
        }
        v
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [
            SimdMode::Auto,
            SimdMode::Force(SimdTier::Off),
            SimdMode::Force(SimdTier::Portable),
            SimdMode::Force(SimdTier::Avx2),
            SimdMode::Force(SimdTier::Neon),
        ] {
            assert_eq!(SimdMode::from_name(m.name()), Some(m));
        }
        assert_eq!(SimdMode::from_name("bogus"), None);
    }

    #[test]
    fn forced_unavailable_degrades_to_portable() {
        // At most one of avx2/neon is available on any CPU, so at least
        // one of these resolves through the portable fallback.
        for t in [SimdTier::Avx2, SimdTier::Neon] {
            let r = resolve(SimdMode::Force(t));
            if t.available() {
                assert_eq!(r, t);
            } else {
                assert_eq!(r, SimdTier::Portable);
            }
        }
        assert_eq!(resolve(SimdMode::Force(SimdTier::Off)), SimdTier::Off);
    }

    /// Every primitive is bit-identical across every live tier,
    /// including non-multiple-of-8 lengths (remainder lanes).
    #[test]
    fn primitives_bit_identical_across_tiers() {
        let mut rng = Rng::new(77);
        for n in [1usize, 7, 8, 16, 37, 256] {
            let src = rand_vec(&mut rng, n);
            let s = rand_vec(&mut rng, n);
            let acc = rand_vec(&mut rng, n);
            let mn = rand_vec(&mut rng, n);
            let base = rand_vec(&mut rng, n);
            let a = rng.normal_f32();
            let gs = rng.normal_f32();

            let run = |tier: SimdTier| {
                let mut d1 = base.clone();
                axpy(tier, &mut d1, &src, a);
                let mut d2 = base.clone();
                mul_acc(tier, &mut d2, &s, &acc);
                let mut d3 = base.clone();
                affine_acc(tier, &mut d3, &s, &acc, &mn, gs);
                let mut d4 = vec![0f32; n];
                ramp_scale(tier, &mut d4, a);
                let mut d5 = vec![0f32; n];
                add_bcast(tier, &mut d5, &src, a);
                let mut d6 = vec![0f32; n];
                ramp_affine(tier, &mut d6, a, gs);
                [d1, d2, d3, d4, d5, d6]
            };
            let reference = run(SimdTier::Off);
            for tier in live_tiers() {
                let got = run(tier);
                for (gi, (g, r)) in got.iter().zip(&reference).enumerate() {
                    let same = g.iter().zip(r.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "primitive {gi} diverges on tier {} n={n}", tier.name());
                }
            }
        }
    }

    #[test]
    fn decode_accum_bit_identical_across_tiers() {
        let mut rng = Rng::new(78);
        for bits in [1usize, 2, 3, 4, 5, 8] {
            for n in [5usize, 8, 19, 64] {
                let plane_data: Vec<Vec<u32>> = (0..bits)
                    .map(|_| (0..n).map(|_| rng.below(u32::MAX as usize) as u32).collect())
                    .collect();
                let planes: Vec<&[u32]> = plane_data.iter().map(|p| p.as_slice()).collect();
                let base = rand_vec(&mut rng, n);
                let xv = rng.normal_f32();
                for bit in [0u32, 7, 31] {
                    let mut reference = base.clone();
                    decode_accum(SimdTier::Off, &mut reference, xv, &planes, bit);
                    for tier in live_tiers() {
                        let mut got = base.clone();
                        decode_accum(tier, &mut got, xv, &planes, bit);
                        let same =
                            got.iter().zip(&reference).all(|(x, y)| x.to_bits() == y.to_bits());
                        assert!(same, "decode b{bits} n{n} bit{bit} tier {}", tier.name());
                    }
                }
            }
        }
    }
}
