//! Runtime kernel dispatch policy for the fused-dequant GEMM family.
//!
//! Three concrete paths (see the sibling modules):
//!
//! * **direct** — bit-plane reassembly GEMV/small-M, column-block
//!   parallel (the reference CPU path; always available).
//! * **lut** — interleaved-lane GEMV with per-row code-pair tables and
//!   the per-group affine (dequant-grid) application; needs nibble lanes
//!   (`bits <= 4`, even group) and enough columns to amortize the table
//!   build.
//! * **panel** — register-blocked row-panel GEMM for prefill-like M,
//!   tiling (M x 32) x (32 x Ncol) updates into cache-resident blocks.
//!
//! [`KernelPolicy::current`] resolves the process-wide override (CLI
//! `--kernel`, then `LIEQ_KERNEL`, then `Auto`), mirroring how
//! `util::pool` resolves the worker count. `Auto` picks by shape:
//! `m >= panel_min_m` -> panel, else lut when eligible, else direct.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::quant::PackedWeight;

/// Requested dispatch: `Auto` resolves per shape; the rest force a path
/// (with a documented fallback when a forced path cannot decode the
/// weight, e.g. `Lut` on byte lanes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    Auto,
    Direct,
    Lut,
    Panel,
}

impl KernelPath {
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Auto => "auto",
            KernelPath::Direct => "direct",
            KernelPath::Lut => "lut",
            KernelPath::Panel => "panel",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelPath> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(KernelPath::Auto),
            "direct" => Some(KernelPath::Direct),
            "lut" => Some(KernelPath::Lut),
            "panel" => Some(KernelPath::Panel),
            _ => None,
        }
    }

    fn to_code(self) -> u8 {
        match self {
            KernelPath::Auto => 0,
            KernelPath::Direct => 1,
            KernelPath::Lut => 2,
            KernelPath::Panel => 3,
        }
    }

    fn from_code(c: u8) -> KernelPath {
        match c {
            1 => KernelPath::Direct,
            2 => KernelPath::Lut,
            3 => KernelPath::Panel,
            _ => KernelPath::Auto,
        }
    }
}

/// Process-wide path override; 0 = Auto/unset (fall through to env).
static GLOBAL_PATH: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide kernel path (the CLI `--kernel` flag lands
/// here). `Auto` resets to env/auto resolution.
pub fn set_global_kernel(path: KernelPath) {
    GLOBAL_PATH.store(path.to_code(), Ordering::SeqCst);
}

/// Path used by [`KernelPolicy::current`]: the [`set_global_kernel`]
/// override if set, else `LIEQ_KERNEL`, else `Auto`.
pub fn global_kernel() -> KernelPath {
    let c = GLOBAL_PATH.load(Ordering::SeqCst);
    if c != 0 {
        return KernelPath::from_code(c);
    }
    if let Ok(v) = std::env::var("LIEQ_KERNEL") {
        if let Some(p) = KernelPath::from_name(&v) {
            return p;
        }
    }
    KernelPath::Auto
}

/// Shape/bits thresholds for `Auto` dispatch.
#[derive(Clone, Copy, Debug)]
pub struct KernelPolicy {
    pub path: KernelPath,
    /// M at or above which the row-panel path amortizes its unpacks.
    pub panel_min_m: usize,
    /// Minimum N for the LUT path: the per-row code-pair tables cost
    /// ~150 ops per K-pair, amortized over N columns.
    pub lut_min_n: usize,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        KernelPolicy { path: KernelPath::Auto, panel_min_m: 8, lut_min_n: 64 }
    }
}

impl KernelPolicy {
    /// Policy with the process-wide path override applied.
    pub fn current() -> KernelPolicy {
        KernelPolicy { path: global_kernel(), ..Default::default() }
    }

    pub fn with_path(path: KernelPath) -> KernelPolicy {
        KernelPolicy { path, ..Default::default() }
    }

    /// True when the LUT kernel can decode this weight (nibble lanes).
    pub fn lut_eligible(w: &PackedWeight) -> bool {
        w.nibble_lanes()
    }

    /// Resolve the concrete path for an `m x (k x n)` call. Never returns
    /// `Auto`; a forced `Lut` on a non-nibble weight falls back to
    /// `Direct` (the only path that decodes every plane layout at small
    /// M).
    pub fn select(&self, m: usize, w: &PackedWeight) -> KernelPath {
        match self.path {
            KernelPath::Direct => KernelPath::Direct,
            KernelPath::Panel => KernelPath::Panel,
            KernelPath::Lut => {
                if Self::lut_eligible(w) {
                    KernelPath::Lut
                } else {
                    KernelPath::Direct
                }
            }
            KernelPath::Auto => {
                if m >= self.panel_min_m {
                    KernelPath::Panel
                } else if Self::lut_eligible(w) && w.n >= self.lut_min_n {
                    KernelPath::Lut
                } else {
                    KernelPath::Direct
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_weight;

    fn weight(k: usize, n: usize, g: usize, bits: u8) -> PackedWeight {
        let mut rng = crate::util::Rng::new(2);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        pack_weight(&w, k, n, g, bits)
    }

    #[test]
    fn path_names_roundtrip() {
        for p in [KernelPath::Auto, KernelPath::Direct, KernelPath::Lut, KernelPath::Panel] {
            assert_eq!(KernelPath::from_name(p.name()), Some(p));
        }
        assert_eq!(KernelPath::from_name("bogus"), None);
    }

    #[test]
    fn auto_selects_by_shape() {
        let pol = KernelPolicy::default();
        let wide = weight(64, 256, 32, 2);
        assert_eq!(pol.select(1, &wide), KernelPath::Lut);
        assert_eq!(pol.select(32, &wide), KernelPath::Panel);
        let narrow = weight(64, 16, 32, 2);
        assert_eq!(pol.select(1, &narrow), KernelPath::Direct, "narrow N skips table build");
    }

    #[test]
    fn forced_lut_falls_back_on_byte_lanes() {
        let w5 = weight(64, 128, 32, 5); // 5-bit codes: byte lanes
        assert_eq!(KernelPolicy::with_path(KernelPath::Lut).select(1, &w5), KernelPath::Direct);
        assert_eq!(KernelPolicy::with_path(KernelPath::Panel).select(1, &w5), KernelPath::Panel);
    }
}
