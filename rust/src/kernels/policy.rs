//! Runtime kernel dispatch policy for the fused-dequant GEMM family.
//!
//! Three concrete paths (see the sibling modules):
//!
//! * **direct** — bit-plane reassembly GEMV/small-M, column-block
//!   parallel (the reference CPU path; always available).
//! * **lut** — interleaved-lane GEMV with per-row tables and the
//!   per-group affine (dequant-grid) application. Every bit-width is
//!   eligible: nibble lanes (`bits <= 4`, even group) decode through
//!   code-pair tables, byte lanes (bits 5–8, or odd groups) through
//!   single-code tables; the only gate is enough columns to amortize
//!   the table build.
//! * **panel** — register-blocked row-panel GEMM for prefill-like M,
//!   decoding interleaved lanes into cache-resident (32 x Ncol) tiles.
//!
//! A fourth path sits beside them on a different precision axis:
//!
//! * **a8** ([`super::a8`]) — integer W·A8 GEMV: activations quantized
//!   to INT8 (calibrated or dynamic), i32 dot products over the lane
//!   bytes, one affine rescale per group. Forced via `--kernel a8`, or
//!   preferred on decode shapes via `--kernel auto-a8` (prefill still
//!   panels — the f32 panel path wins once the tile decode amortizes).
//!
//! [`KernelPolicy::current`] resolves the process-wide override (CLI
//! `--kernel`, then `LIEQ_KERNEL`, then `Auto`), mirroring how
//! `util::pool` resolves the worker count. `Auto` picks by shape:
//! `m >= panel_min_m` -> panel, else lut when N clears the
//! table-amortization gate (`lut_min_n` on nibble lanes,
//! `lut_min_n_byte` — 2x, the tables cost double — on byte lanes),
//! else direct.
//!
//! Orthogonally, [`KernelPolicy::simd`] carries the resolved
//! [`SimdTier`] (CLI `--simd`, then `LIEQ_SIMD`, then probe — see
//! [`super::simd`]) that the selected f32 path's inner loops run on.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::quant::PackedWeight;

use super::simd::{self, SimdTier};

/// Requested dispatch: `Auto` resolves per shape; the rest force a
/// path. Every path decodes every packed layout (the LUT family picks
/// its table flavor from the weight's lane kind), so forcing never
/// falls back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    Auto,
    Direct,
    Lut,
    Panel,
    /// Integer W·A8 GEMV (quantized activations). A *precision* choice,
    /// not just a loop shape: outputs differ from the f32 paths by the
    /// activation rounding error (pinned by tolerance tests).
    A8,
}

impl KernelPath {
    pub fn name(&self) -> &'static str {
        match self {
            KernelPath::Auto => "auto",
            KernelPath::Direct => "direct",
            KernelPath::Lut => "lut",
            KernelPath::Panel => "panel",
            KernelPath::A8 => "a8",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelPath> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(KernelPath::Auto),
            "direct" => Some(KernelPath::Direct),
            "lut" => Some(KernelPath::Lut),
            "panel" => Some(KernelPath::Panel),
            "a8" => Some(KernelPath::A8),
            _ => None,
        }
    }

    fn to_code(self) -> u8 {
        match self {
            KernelPath::Auto => 0,
            KernelPath::Direct => 1,
            KernelPath::Lut => 2,
            KernelPath::Panel => 3,
            KernelPath::A8 => 4,
        }
    }

    fn from_code(c: u8) -> KernelPath {
        match c {
            1 => KernelPath::Direct,
            2 => KernelPath::Lut,
            3 => KernelPath::Panel,
            4 => KernelPath::A8,
            _ => KernelPath::Auto,
        }
    }
}

/// Parse a `--kernel` / `LIEQ_KERNEL` spec into (path, a8 preference):
/// every path name as-is, plus `auto-a8` — auto shape dispatch that
/// prefers the integer path on decode shapes.
pub fn parse_kernel_spec(s: &str) -> Option<(KernelPath, bool)> {
    if s.eq_ignore_ascii_case("auto-a8") {
        return Some((KernelPath::Auto, true));
    }
    KernelPath::from_name(s).map(|p| (p, p == KernelPath::A8))
}

/// Process-wide path override; 0 = Auto/unset (fall through to env).
/// Bits 0–2 hold the `KernelPath` code, bit 3 the `auto-a8` preference.
static GLOBAL_PATH: AtomicU8 = AtomicU8::new(0);

const A8_PREF_BIT: u8 = 1 << 3;

/// Set the process-wide kernel path (the CLI `--kernel` flag lands
/// here). `Auto` resets to env/auto resolution.
pub fn set_global_kernel(path: KernelPath) {
    set_global_kernel_pref(path, path == KernelPath::A8);
}

/// [`set_global_kernel`] with an explicit a8 preference (`auto-a8`:
/// `Auto` path + `a8 = true`).
pub fn set_global_kernel_pref(path: KernelPath, a8: bool) {
    let pref = if a8 { A8_PREF_BIT } else { 0 };
    GLOBAL_PATH.store(path.to_code() | pref, Ordering::SeqCst);
}

/// (path, a8 preference) used by [`KernelPolicy::current`]: the
/// [`set_global_kernel_pref`] override if set, else `LIEQ_KERNEL`, else
/// `(Auto, false)`.
pub fn global_kernel_pref() -> (KernelPath, bool) {
    let c = GLOBAL_PATH.load(Ordering::SeqCst);
    if c != 0 {
        return (KernelPath::from_code(c & !A8_PREF_BIT), c & A8_PREF_BIT != 0);
    }
    if let Ok(v) = std::env::var("LIEQ_KERNEL") {
        if let Some(spec) = parse_kernel_spec(&v) {
            return spec;
        }
    }
    (KernelPath::Auto, false)
}

/// Path half of [`global_kernel_pref`].
pub fn global_kernel() -> KernelPath {
    global_kernel_pref().0
}

/// Shape/bits thresholds for `Auto` dispatch.
#[derive(Clone, Copy, Debug)]
pub struct KernelPolicy {
    pub path: KernelPath,
    /// Under `Auto`, prefer the integer A8 path on decode shapes
    /// (`auto-a8`). Prefill still panels.
    pub a8: bool,
    /// Resolved SIMD tier the f32 paths' inner loops run on (`--simd` /
    /// `LIEQ_SIMD` / probe; see [`super::simd`]). `Off` = the scalar
    /// reference loops.
    pub simd: SimdTier,
    /// M at or above which the row-panel path amortizes its unpacks.
    pub panel_min_m: usize,
    /// Minimum N for the nibble-lane LUT path: the per-row code-pair
    /// tables cost ~150 ops per K-*pair*, amortized over N columns.
    pub lut_min_n: usize,
    /// Minimum N for the byte-lane LUT path. Single-code tables are one
    /// 256-entry table per K *row* — double the build work and footprint
    /// of the pair tables — so byte lanes need ~2x the columns before
    /// the table build beats the direct path's per-weight reassembly.
    pub lut_min_n_byte: usize,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        KernelPolicy {
            path: KernelPath::Auto,
            a8: false,
            simd: simd::current_tier(),
            panel_min_m: 8,
            lut_min_n: 64,
            lut_min_n_byte: 128,
        }
    }
}

impl KernelPolicy {
    /// Policy with the process-wide path and SIMD overrides applied.
    pub fn current() -> KernelPolicy {
        let (path, a8) = global_kernel_pref();
        KernelPolicy { path, a8, ..Default::default() }
    }

    pub fn with_path(path: KernelPath) -> KernelPolicy {
        KernelPolicy { path, ..Default::default() }
    }

    /// Pin the SIMD tier (benches/tests compare tiers this way without
    /// touching process-wide state).
    pub fn with_simd(mut self, tier: SimdTier) -> KernelPolicy {
        self.simd = tier;
        self
    }

    /// True when the LUT kernel can decode this weight. Always true
    /// since the byte-lane tables landed: nibble lanes take code-pair
    /// tables, everything else takes single-code tables. Kept as an API
    /// (callers and tests gate on it) and as the single place a future
    /// ineligible layout would be declared.
    pub fn lut_eligible(_w: &PackedWeight) -> bool {
        true
    }

    /// Resolve the concrete path for an `m x (k x n)` call. Never
    /// returns `Auto`; forced paths are honored as-is (every path
    /// decodes every layout).
    pub fn select(&self, m: usize, w: &PackedWeight) -> KernelPath {
        match self.path {
            KernelPath::Direct => KernelPath::Direct,
            KernelPath::Panel => KernelPath::Panel,
            KernelPath::Lut => KernelPath::Lut,
            KernelPath::A8 => KernelPath::A8,
            KernelPath::Auto => {
                let min_n =
                    if w.nibble_lanes() { self.lut_min_n } else { self.lut_min_n_byte };
                if m >= self.panel_min_m {
                    KernelPath::Panel
                } else if self.a8 {
                    KernelPath::A8
                } else if Self::lut_eligible(w) && w.n >= min_n {
                    KernelPath::Lut
                } else {
                    KernelPath::Direct
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_weight;

    fn weight(k: usize, n: usize, g: usize, bits: u8) -> PackedWeight {
        let mut rng = crate::util::Rng::new(2);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        pack_weight(&w, k, n, g, bits)
    }

    #[test]
    fn path_names_roundtrip() {
        for p in [
            KernelPath::Auto,
            KernelPath::Direct,
            KernelPath::Lut,
            KernelPath::Panel,
            KernelPath::A8,
        ] {
            assert_eq!(KernelPath::from_name(p.name()), Some(p));
        }
        assert_eq!(KernelPath::from_name("bogus"), None);
    }

    #[test]
    fn kernel_specs_parse_a8_variants() {
        assert_eq!(parse_kernel_spec("a8"), Some((KernelPath::A8, true)));
        assert_eq!(parse_kernel_spec("auto-a8"), Some((KernelPath::Auto, true)));
        assert_eq!(parse_kernel_spec("auto"), Some((KernelPath::Auto, false)));
        assert_eq!(parse_kernel_spec("lut"), Some((KernelPath::Lut, false)));
        assert_eq!(parse_kernel_spec("bogus"), None);
    }

    /// `auto-a8`: decode shapes take the integer path, prefill still
    /// panels; plain auto never picks A8.
    #[test]
    fn auto_a8_prefers_integer_decode() {
        let pol = KernelPolicy { a8: true, ..KernelPolicy::default() };
        let w = weight(64, 256, 32, 2);
        assert_eq!(pol.select(1, &w), KernelPath::A8);
        assert_eq!(pol.select(32, &w), KernelPath::Panel);
        assert_eq!(KernelPolicy::default().select(1, &w), KernelPath::Lut);
        assert_eq!(KernelPolicy::with_path(KernelPath::A8).select(32, &w), KernelPath::A8);
    }

    #[test]
    fn auto_selects_by_shape() {
        let pol = KernelPolicy::default();
        let wide = weight(64, 256, 32, 2);
        assert_eq!(pol.select(1, &wide), KernelPath::Lut);
        assert_eq!(pol.select(32, &wide), KernelPath::Panel);
        let narrow = weight(64, 16, 32, 2);
        assert_eq!(pol.select(1, &narrow), KernelPath::Direct, "narrow N skips table build");
    }

    /// Acceptance: every bit-width 2–8 dispatches to a LUT or panel path
    /// under auto on decode shapes — no silent direct fallback for the
    /// high-precision (5–8 bit) layers LieQ's allocator protects. Byte
    /// lanes amortize their doubled table-build cost over more columns,
    /// so their auto gate sits at `lut_min_n_byte`.
    #[test]
    fn auto_covers_every_bit_width_on_decode_shapes() {
        let pol = KernelPolicy::default();
        for bits in 2u8..=8 {
            let w = weight(64, 256, 32, bits);
            assert!(KernelPolicy::lut_eligible(&w));
            assert_eq!(pol.select(1, &w), KernelPath::Lut, "b{bits} decode must take LUT");
            assert_eq!(pol.select(32, &w), KernelPath::Panel, "b{bits} prefill must panel");
        }
        // Moderate N: nibble lanes already LUT, byte lanes stay direct
        // (table build would dominate) until lut_min_n_byte.
        let w4 = weight(64, 96, 32, 4);
        let w6 = weight(64, 96, 32, 6);
        assert_eq!(pol.select(1, &w4), KernelPath::Lut);
        assert_eq!(pol.select(1, &w6), KernelPath::Direct, "byte lanes gate at 2x N");
    }

    #[test]
    fn forced_lut_honored_on_byte_lanes() {
        let w5 = weight(64, 128, 32, 5); // 5-bit codes: byte lanes
        assert!(!w5.nibble_lanes());
        assert_eq!(KernelPolicy::with_path(KernelPath::Lut).select(1, &w5), KernelPath::Lut);
        assert_eq!(KernelPolicy::with_path(KernelPath::Panel).select(1, &w5), KernelPath::Panel);
    }
}
