//! Sparse outlier fusion: the shared pre-pass and accumulate helper
//! that fuse a `PackedWeight`'s fp16 outlier sidecar into every dense
//! kernel path (direct / panel / LUT / A8, all SIMD tiers).
//!
//! Contract (mirrors `quant::pack::OutlierSide`): the extracted rows
//! were zeroed in the dense grid, but a zeroed row still decodes to a
//! grid point *near* zero — adding a sparse term on top of the dense
//! result would double-count. The fusion therefore masks activations
//! instead: one pre-pass over `x` ([`prepare`]) gathers the outlier-row
//! activations into `xg` and zeroes them in a dense copy `xm`, in the
//! same sweep, so no kernel path reads the activations twice. The dense
//! kernels run unmodified on `xm` — a zero activation contributes
//! exactly zero through every path: the f32 paths multiply by (or skip)
//! it, and the A8 path's zero-inclusive grid quantizes 0.0 to centered
//! code 0 — and each path adds the sparse product
//! `Σ_i xg[i] · vals[i, ·]` into its own output block in ascending
//! sidecar order ([`sparse_accum`]). The per-column FP expression and
//! its evaluation order are fixed regardless of how a path chunks rows
//! or columns, so every path stays bit-identical at any thread count
//! with outliers fused.

use crate::quant::pack::OutlierSide;
use crate::quant::PackedWeight;

use super::simd::{self, SimdTier};

/// Masked-activation images for one fused call.
pub(crate) struct OutlierFusion {
    /// `x` with the outlier rows zeroed (`m x k`): the dense input.
    pub xm: Vec<f32>,
    /// Gathered outlier-row activations (`m x nc`): the sparse input.
    pub xg: Vec<f32>,
    /// Sidecar width (`cols.len()`).
    pub nc: usize,
}

/// Build the masked images in one pass over `x`. Returns `None` when
/// `w` is purely dense — the caller then runs the zero-overhead dense
/// paths on `x` itself.
pub(crate) fn prepare(x: &[f32], m: usize, w: &PackedWeight) -> Option<OutlierFusion> {
    let side = w.outliers.as_ref()?;
    let nc = side.cols.len();
    if nc == 0 {
        return None;
    }
    let k = w.k;
    let mut xm = x.to_vec();
    let mut xg = vec![0f32; m * nc];
    for row in 0..m {
        let xrow = &mut xm[row * k..(row + 1) * k];
        let grow = &mut xg[row * nc..(row + 1) * nc];
        for (i, &c) in side.cols.iter().enumerate() {
            grow[i] = xrow[c as usize];
            xrow[c as usize] = 0.0;
        }
    }
    Some(OutlierFusion { xm, xg, nc })
}

/// Borrowed sparse arguments a kernel path threads to its inner loops
/// (`Copy`, so parallel closures capture it by value).
#[derive(Clone, Copy)]
pub(crate) struct SparseArgs<'a> {
    /// Sidecar values, `nc x n` row-major (`n` is the row stride).
    pub vals: &'a [f32],
    /// Gathered activations for the rows this call covers, `rows x nc`.
    pub xg: &'a [f32],
    /// Sidecar width.
    pub nc: usize,
    /// Output width `n`.
    pub n: usize,
}

impl<'a> SparseArgs<'a> {
    pub fn new(side: &'a OutlierSide, fusion: &'a OutlierFusion, n: usize) -> SparseArgs<'a> {
        SparseArgs { vals: &side.vals, xg: &fusion.xg, nc: fusion.nc, n }
    }

    /// The same arguments restricted to output rows `[r0, r0 + rows)`
    /// (the panel path's row-chunk fan-out).
    pub fn rows(&self, r0: usize, rows: usize) -> SparseArgs<'a> {
        SparseArgs { xg: &self.xg[r0 * self.nc..(r0 + rows) * self.nc], ..*self }
    }

    /// Gathered activations of one output row.
    pub fn xg_row(&self, row: usize) -> &'a [f32] {
        &self.xg[row * self.nc..(row + 1) * self.nc]
    }
}

/// `orow += Σ_i xg_row[i] · vals[i, c0..c0+orow.len()]`, ascending `i`.
///
/// The zero-skip matches the dense paths' `xv == 0.0` skips (identical
/// FP result — adding `0.0 * v` only differs for NaN/inf sidecars, which
/// validation rejects), and `simd::axpy` is bit-identical across tiers,
/// so the fused output is invariant to tier and to how the caller
/// chunked its columns.
pub(crate) fn sparse_accum(
    tier: SimdTier,
    sp: &SparseArgs,
    xg_row: &[f32],
    c0: usize,
    orow: &mut [f32],
) {
    let bw = orow.len();
    for (i, &xv) in xg_row.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let vrow = &sp.vals[i * sp.n + c0..i * sp.n + c0 + bw];
        simd::axpy(tier, orow, vrow, xv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_weight_outlier;
    use crate::util::Rng;

    #[test]
    fn prepare_masks_and_gathers_in_one_pass() {
        let mut rng = Rng::new(91);
        let (k, n, g, m) = (64usize, 16usize, 32usize, 3usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight_outlier(&w, k, n, g, 2, 4.0 / k as f64, None);
        let side = pw.outliers.clone().unwrap();
        assert_eq!(side.cols.len(), 4);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let f = prepare(&x, m, &pw).unwrap();
        assert_eq!(f.nc, 4);
        for row in 0..m {
            for (i, &c) in side.cols.iter().enumerate() {
                assert_eq!(f.xg[row * 4 + i], x[row * k + c as usize]);
                assert_eq!(f.xm[row * k + c as usize], 0.0);
            }
            // Non-outlier entries pass through untouched.
            for kk in 0..k {
                if !side.cols.contains(&(kk as u32)) {
                    assert_eq!(f.xm[row * k + kk], x[row * k + kk]);
                }
            }
        }
        // Dense weights need no fusion.
        let dense = crate::quant::pack::pack_weight(&w, k, n, g, 2);
        assert!(prepare(&x, m, &dense).is_none());
    }

    #[test]
    fn sparse_accum_matches_naive_product() {
        let mut rng = Rng::new(93);
        let (n, nc) = (24usize, 5usize);
        let vals: Vec<f32> = (0..nc * n).map(|_| rng.normal_f32()).collect();
        let xg: Vec<f32> = (0..nc).map(|_| rng.normal_f32()).collect();
        let side = OutlierSide { cols: (0..nc as u32).collect(), vals: vals.clone() };
        let fusion = OutlierFusion { xm: vec![], xg: xg.clone(), nc };
        let sp = SparseArgs::new(&side, &fusion, n);
        // Full row and a chunked evaluation must agree bit-for-bit.
        let mut full = vec![0f32; n];
        sparse_accum(SimdTier::Off, &sp, &xg, 0, &mut full);
        let mut chunked = vec![0f32; n];
        sparse_accum(SimdTier::Off, &sp, &xg, 0, &mut chunked[..10]);
        sparse_accum(SimdTier::Off, &sp, &xg, 10, &mut chunked[10..]);
        for c in 0..n {
            let mut want = 0f32;
            for i in 0..nc {
                want += xg[i] * vals[i * n + c];
            }
            assert!((full[c] - want).abs() < 1e-5);
            assert_eq!(full[c].to_bits(), chunked[c].to_bits(), "chunking must not change bits");
        }
    }
}
