//! CPU deployment kernels: the fused dequantize-GEMM family over
//! packed weights — the measurable half of the paper's Fig. 4 latency
//! story.
//!
//! At GEMV-like shapes (small M) the computation is bound by weight
//! bytes streamed from memory; 2-bit planes move 8x fewer bytes than
//! f32, which is the same lever the paper's CUDA kernels pull on HBM.
//! Uniform bit-width inside a layer keeps this a single
//! contiguous-stride kernel — the whole point of LieQ's layout
//! (contrast per-element mixed formats).
//!
//! Three concrete paths behind the [`KernelPolicy`] dispatcher (CLI
//! `--kernel`, `LIEQ_KERNEL`, or shape-based auto):
//!
//! * [`gemm`] **direct** — bit-plane reassembly, the reference path;
//! * [`lut`] — interleaved-lane GEMV through per-row tables (code-pair
//!   tables on nibble lanes for bits <= 4, single-code tables on byte
//!   lanes for bits 5–8 / odd groups) plus the per-group dequant grid —
//!   every bit-width 1–8 has a LUT decode path;
//! * [`gemm`] **panel** — cache-tiled 32-row panel GEMM decoding the
//!   interleaved lanes directly (prefill shapes, no plane reassembly).
//!
//! All paths are bit-identical at any thread count; per-path traffic is
//! accounted in [`DqKernelStats`] and the process-wide
//! [`stats::snapshot`] counters that `ServerReport` / `PipelineResult`
//! surface — including `lane_builds`, the count of lazy
//! `planes_to_interleaved` conversions that `.lieq` v2 archives with
//! persisted lane images eliminate on cold load.

pub mod gemm;
pub mod lut;
pub mod policy;
pub mod stats;

pub use gemm::{dq_gemm, dq_gemm_with, gemm_f32};
pub use policy::{global_kernel, set_global_kernel, KernelPath, KernelPolicy};
pub use stats::{
    attach_thread_sink, snapshot as kernel_path_stats, DqKernelStats, KernelPathSink,
    KernelPathStats,
};
