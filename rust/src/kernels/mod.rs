//! CPU deployment kernels: fused dequantize-GEMM over bit-plane-packed
//! weights — the measurable half of the paper's Fig. 4 latency story.
//!
//! At GEMV-like shapes (small M) the computation is bound by weight bytes
//! streamed from memory; 2-bit planes move 8x fewer bytes than f32, which
//! is the same lever the paper's CUDA kernels pull on HBM. Uniform
//! bit-width inside a layer keeps this a single contiguous-stride kernel —
//! the whole point of LieQ's layout (contrast per-element mixed formats).

pub mod gemm;

pub use gemm::{dq_gemm, gemm_f32, DqKernelStats};
