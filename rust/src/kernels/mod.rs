//! CPU deployment kernels: the fused dequantize-GEMM family over
//! packed weights — the measurable half of the paper's Fig. 4 latency
//! story.
//!
//! At GEMV-like shapes (small M) the computation is bound by weight
//! bytes streamed from memory; 2-bit planes move 8x fewer bytes than
//! f32, which is the same lever the paper's CUDA kernels pull on HBM.
//! Uniform bit-width inside a layer keeps this a single
//! contiguous-stride kernel — the whole point of LieQ's layout
//! (contrast per-element mixed formats).
//!
//! Three concrete paths behind the [`KernelPolicy`] dispatcher (CLI
//! `--kernel`, `LIEQ_KERNEL`, or shape-based auto):
//!
//! * [`gemm`] **direct** — bit-plane reassembly, the reference path;
//! * [`lut`] — interleaved-lane GEMV through per-row tables (code-pair
//!   tables on nibble lanes for bits <= 4, single-code tables on byte
//!   lanes for bits 5–8 / odd groups) plus the per-group dequant grid —
//!   every bit-width 1–8 has a LUT decode path;
//! * [`gemm`] **panel** — cache-tiled 32-row panel GEMM decoding the
//!   interleaved lanes directly (prefill shapes, no plane reassembly).
//!
//! Two orthogonal axes refine the f32 paths:
//!
//! * [`simd`] — a runtime-ISA-detected tier (AVX2 / NEON / portable
//!   chunks / off) the inner loops of all three paths run on. Every
//!   tier computes the identical per-column FP expression (mul and add
//!   never fused), so the whole f32 family stays **bit-identical to the
//!   scalar reference** on every tier. `--simd` / `LIEQ_SIMD` override
//!   the probe; a forced-unavailable ISA degrades to portable.
//! * [`a8`] — the integer W·A8 GEMV (`--kernel a8` / `auto-a8`):
//!   activations quantized to INT8 by [`crate::quant::act`]
//!   (calibrated or dynamic), i8×i8→i32 dot products over the lane
//!   bytes, one affine rescale per (group, column). Deterministic and
//!   thread-count bit-identical; differs from f32 only by the pinned
//!   activation-rounding tolerance.
//!
//! A weight with an fp16 outlier sidecar ([`outlier`]) fuses its sparse
//! GEMV into whichever path the dispatcher selects: one pre-pass masks
//! the outlier-row activations out of the dense input and gathers them,
//! the dense kernel runs unmodified, and the sparse product lands in the
//! same output blocks — no path reads activations twice, and the
//! per-path bit-identity contract is preserved (`outlier_cols` /
//! `outlier_fused_calls` account the fused traffic).
//!
//! All paths are bit-identical at any thread count; per-path traffic is
//! accounted in [`DqKernelStats`] and the process-wide
//! [`stats::snapshot`] counters that `ServerReport` / `PipelineResult`
//! surface — including `lane_builds`, the count of lazy
//! `planes_to_interleaved` conversions that `.lieq` v2 archives with
//! persisted lane images eliminate on cold load, and the per-tier
//! `simd_*_calls` / `a8_calls` attribution.

pub mod a8;
pub mod gemm;
pub mod lut;
pub mod outlier;
pub mod policy;
pub mod simd;
pub mod stats;

pub use gemm::{dq_gemm, dq_gemm_with, gemm_f32};
pub use policy::{
    global_kernel, global_kernel_pref, parse_kernel_spec, set_global_kernel,
    set_global_kernel_pref, KernelPath, KernelPolicy,
};
pub use simd::{current_tier, global_simd, resolve, set_global_simd, SimdMode, SimdTier};
pub use stats::{
    attach_thread_sink, snapshot as kernel_path_stats, DqKernelStats, KernelPathSink,
    KernelPathStats,
};
