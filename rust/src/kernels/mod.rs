//! CPU deployment kernels: the fused dequantize-GEMM family over
//! packed weights — the measurable half of the paper's Fig. 4 latency
//! story.
//!
//! At GEMV-like shapes (small M) the computation is bound by weight
//! bytes streamed from memory; 2-bit planes move 8x fewer bytes than
//! f32, which is the same lever the paper's CUDA kernels pull on HBM.
//! Uniform bit-width inside a layer keeps this a single
//! contiguous-stride kernel — the whole point of LieQ's layout
//! (contrast per-element mixed formats).
//!
//! Three concrete paths behind the [`KernelPolicy`] dispatcher (CLI
//! `--kernel`, `LIEQ_KERNEL`, or shape-based auto):
//!
//! * [`gemm`] **direct** — bit-plane reassembly, the reference path;
//! * [`lut`] — interleaved-lane GEMV through per-row code-pair tables
//!   plus the per-group dequant grid (decode shapes);
//! * [`gemm`] **panel** — cache-tiled 32-row panel GEMM (prefill
//!   shapes).
//!
//! All paths are bit-identical at any thread count; per-path traffic is
//! accounted in [`DqKernelStats`] and the process-wide
//! [`stats::snapshot`] counters that `ServerReport` / `PipelineResult`
//! surface.

pub mod gemm;
pub mod lut;
pub mod policy;
pub mod stats;

pub use gemm::{dq_gemm, dq_gemm_with, gemm_f32};
pub use policy::{global_kernel, set_global_kernel, KernelPath, KernelPolicy};
pub use stats::{
    attach_thread_sink, snapshot as kernel_path_stats, DqKernelStats, KernelPathSink,
    KernelPathStats,
};
