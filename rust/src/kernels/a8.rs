//! W·A8 GEMV: INT8-quantized activations against the packed low-bit
//! weights, streamed straight from the interleaved code lanes.
//!
//! Per x-row: quantize the row to centered codes `c_x = q - zp`
//! ([`ActQuant::quantize_centered`] — the weight's calibrated
//! parameters when present, else dynamic per-row parameters from the
//! same symmetry-score recipe), then per (group, column)
//!
//! ```text
//! acc    = Σ_{k∈g} c_x[k] · c_w[k,col]          (i32 dot product)
//! y[col] += s_x · (scale_w[g,col] · acc as f32 + min_w[g,col] · Σ c_x)
//! ```
//!
//! — one affine rescale per group, no per-weight float math at all.
//! The inner dot product runs on fixed-width `[i32; 8]` lanes; integer
//! addition is exact, so (unlike the f32 tiers) there is no
//! scalar/SIMD split to pin: any chunking gives the same `acc`. The
//! per-column float finalization is a fixed expression evaluated once
//! per (group, column), so output is **bit-identical at any thread
//! count** — only the (pinned, tolerance-tested) activation rounding
//! separates A8 from the f32 paths.
//!
//! Overflow: `|c_x| ≤ 255`, `c_w ≤ 255`, so a group contributes at most
//! `group_size · 65025` to the i32 accumulator — safe for any
//! `group_size ≤ 33 000` (real group sizes are 16–128).

use crate::quant::act::ActQuant;
use crate::quant::PackedWeight;
use crate::util::Pool;

use super::gemm::{DIRECT_PAR_MIN_WORK, MIN_COL_BLOCK};
use super::outlier::{self, SparseArgs};
use super::simd::SimdTier;
use super::stats::DqKernelStats;

/// out[M][N] = quantize(x)[M][K] · dequant(W) through the integer path.
/// Each row is quantized independently (dynamic parameters are
/// per-row), so any M is accepted — `Auto` only routes decode-like M
/// here, but a forced `--kernel a8` stays on this path for prefill too.
/// A fused outlier sidecar (`sp`) keeps full f32 precision: the masked
/// activations were zeroed *before* quantization (code 0 exactly on the
/// zero-inclusive grid), and the sparse product is added in f32 after
/// the integer rescale.
pub(crate) fn dq_gemm_a8(
    x: &[f32],
    m: usize,
    w: &PackedWeight,
    sp: Option<SparseArgs<'_>>,
    out: &mut [f32],
) -> DqKernelStats {
    let (k, n, g) = (w.k, w.n, w.group_size);
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    let lane_cold = !w.lanes_built();
    let lanes = w.interleaved();
    let ll = w.lane_len();
    let groups = k / g;

    let pool = Pool::current();
    let chunk = if pool.workers() == 1 || n / MIN_COL_BLOCK < 2 || m * k * n < DIRECT_PAR_MIN_WORK
    {
        n
    } else {
        ((n + pool.workers() * 2 - 1) / (pool.workers() * 2)).max(MIN_COL_BLOCK)
    };

    let mut qx = vec![0i32; k];
    let mut gsums = vec![0i32; groups];
    for row in 0..m {
        let xrow = &x[row * k..(row + 1) * k];
        let act = match w.act {
            Some(a) => a,
            None => ActQuant::dynamic(xrow),
        };
        act.quantize_centered(xrow, &mut qx);
        for (gi, gs) in gsums.iter_mut().enumerate() {
            *gs = qx[gi * g..(gi + 1) * g].iter().sum();
        }
        let orow = &mut out[row * n..(row + 1) * n];
        let (qx, gsums) = (&qx, &gsums);
        pool.par_chunks_mut(orow, chunk, |ci, ochunk| {
            a8_cols(w, lanes, ll, qx, gsums, act.scale, ci * chunk, ochunk);
            if let Some(sp) = sp {
                // Scalar accumulate: the integer path has no SIMD tier
                // to match, and Off is bit-identical everywhere.
                outlier::sparse_accum(SimdTier::Off, &sp, sp.xg_row(row), ci * chunk, ochunk);
            }
        });
    }

    let mut s = DqKernelStats::for_lanes(w, m);
    s.a8_calls = 1;
    s.lane_builds = lane_cold as usize;
    s
}

/// One output chunk (columns `[c0, c0 + ochunk.len())`) for one
/// quantized x-row. `qx` holds centered codes, `gsums` their per-group
/// sums, `sx` the activation scale.
fn a8_cols(
    w: &PackedWeight,
    lanes: &[u8],
    ll: usize,
    qx: &[i32],
    gsums: &[i32],
    sx: f32,
    c0: usize,
    ochunk: &mut [f32],
) {
    let n = w.n;
    let g = w.group_size;
    let nibble = w.nibble_lanes();
    let bw = ochunk.len();
    ochunk.fill(0.0);
    for (gi, &gs) in gsums.iter().enumerate() {
        let q = &qx[gi * g..(gi + 1) * g];
        let gsf = gs as f32;
        let srow = &w.stats.scale[gi * n + c0..gi * n + c0 + bw];
        let mrow = &w.stats.minv[gi * n + c0..gi * n + c0 + bw];
        let glanes = &lanes[(gi * n + c0) * ll..(gi * n + c0 + bw) * ll];
        for (c, o) in ochunk.iter_mut().enumerate() {
            let lane = &glanes[c * ll..(c + 1) * ll];
            let acc = if nibble { dot_nibble(q, lane) } else { dot_byte(q, lane) };
            *o += sx * (srow[c] * acc as f32 + mrow[c] * gsf);
        }
    }
}

/// i32 dot product over nibble lanes: lane byte `p` holds codes for K
/// rows `(2p, 2p+1)` (low nibble first). Fixed `[i32; 8]` partial lanes
/// for the autovectorizer; integer addition is exact, so the chunking
/// never changes the result.
fn dot_nibble(q: &[i32], lane: &[u8]) -> i32 {
    let ll = lane.len();
    let mut accv = [0i32; 8];
    let mut p = 0;
    while p + 8 <= ll {
        let lb = &lane[p..p + 8];
        let qq = &q[2 * p..2 * p + 16];
        for l in 0..8 {
            let b = lb[l];
            accv[l] += qq[2 * l] * ((b & 0xF) as i32) + qq[2 * l + 1] * ((b >> 4) as i32);
        }
        p += 8;
    }
    let mut acc: i32 = accv.iter().sum();
    while p < ll {
        let b = lane[p];
        acc += q[2 * p] * ((b & 0xF) as i32) + q[2 * p + 1] * ((b >> 4) as i32);
        p += 1;
    }
    acc
}

/// i32 dot product over byte lanes: one code per lane byte.
fn dot_byte(q: &[i32], lane: &[u8]) -> i32 {
    let ll = lane.len();
    let mut accv = [0i32; 8];
    let mut p = 0;
    while p + 8 <= ll {
        let lb = &lane[p..p + 8];
        let qq = &q[p..p + 8];
        for l in 0..8 {
            accv[l] += qq[l] * (lb[l] as i32);
        }
        p += 8;
    }
    let mut acc: i32 = accv.iter().sum();
    while p < ll {
        acc += q[p] * (lane[p] as i32);
        p += 1;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{dequantize, pack_weight, quantize_group};
    use crate::util::Rng;

    /// A8 vs the f32 reference: error bounded by the analytic
    /// activation-rounding bound `Σ_k |x_k - x̂_k| · |w_k,col|` with
    /// `|x - x̂| ≤ scale` (zero-point rounding included), plus fp slack.
    #[test]
    fn a8_matches_f32_within_activation_bound() {
        let mut rng = Rng::new(41);
        for (m, k, n, g, bits) in [
            (1usize, 128usize, 96usize, 32usize, 2u8),
            (1, 128, 130, 64, 4),
            (2, 96, 70, 32, 5),
            (1, 128, 64, 64, 8),
            (1, 1056, 40, 33, 3), // odd group: byte lanes
        ] {
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let pw = pack_weight(&w, k, n, g, bits);
            let (codes, stats) = quantize_group(&w, k, n, g, bits);
            let wdq = dequantize(&codes, &stats, k, n, g);
            let mut out = vec![0f32; m * n];
            let s = dq_gemm_a8(&x, m, &pw, None, &mut out);
            assert_eq!(s.a8_calls, 1);
            let mut out_ref = vec![0f32; m * n];
            crate::kernels::gemm_f32(&x, m, &wdq, k, n, &mut out_ref);
            for row in 0..m {
                let xrow = &x[row * k..(row + 1) * k];
                let act = ActQuant::dynamic(xrow);
                for col in 0..n {
                    let bound: f32 =
                        (0..k).map(|kk| wdq[kk * n + col].abs()).sum::<f32>() * act.scale + 1e-3;
                    let err = (out[row * n + col] - out_ref[row * n + col]).abs();
                    assert!(
                        err <= bound,
                        "m{m} k{k} n{n} g{g} b{bits} col{col}: err {err} > bound {bound}"
                    );
                }
            }
        }
    }

    /// Calibrated parameters attached to the weight are honored (the
    /// kernel must not silently fall back to dynamic quantization).
    #[test]
    fn stored_act_params_are_used() {
        let mut rng = Rng::new(42);
        let (k, n, g, bits) = (64usize, 48usize, 32usize, 4u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, bits);
        let mut out_dyn = vec![0f32; n];
        dq_gemm_a8(&x, 1, &pw, None, &mut out_dyn);
        // A deliberately coarse calibrated scale must change the output.
        let coarse = ActQuant::from_moments(0.0, 1.0, -40.0, 40.0);
        let pw_cal = pack_weight(&w, k, n, g, bits).with_act(coarse);
        let mut out_cal = vec![0f32; n];
        dq_gemm_a8(&x, 1, &pw_cal, None, &mut out_cal);
        assert!(
            out_dyn.iter().zip(&out_cal).any(|(a, b)| a.to_bits() != b.to_bits()),
            "calibrated params had no effect"
        );
    }
}
