//! PB-LLM-like backend (Shang et al., 2023): partial binarization.
//!
//! A salient fraction of weights (largest magnitude) is kept at higher
//! precision (8-bit here), the rest is binarized (1-bit, per-group mean
//! magnitude as the scale). The effective bit budget `bits` controls the
//! salient fraction: budget = frac*8 + (1-frac)*1 → frac = (bits-1)/7.
//! This reproduces the baseline's characteristic failure on small models:
//! binarized bulk weights destroy fragile layers even when salient ones
//! are protected.

use super::pack::quant_dequant;
use super::saliency;

pub fn quantize_pbllm(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> Vec<f32> {
    let frac = ((bits as f32 - 1.0) / 7.0).clamp(0.0, 1.0);
    let total = k * n;
    let n_salient = ((total as f32) * frac) as usize;

    // Salience threshold = magnitude of the n_salient-th largest weight
    // (shared with the outlier extractor; see `quant::saliency`).
    let thresh = saliency::magnitude_threshold(w, n_salient);

    // 8-bit RTN for the whole tensor (salient values will be taken from it).
    let q8 = quant_dequant(w, k, n, group, 8);

    // Binarize the rest per (group, column): sign * mean|w| over the group's
    // non-salient entries.
    let groups = k / group;
    let mut out = vec![0f32; total];
    for gi in 0..groups {
        for col in 0..n {
            let mut sum = 0f64;
            let mut count = 0usize;
            for r in 0..group {
                let idx = (gi * group + r) * n + col;
                if w[idx].abs() < thresh {
                    sum += w[idx].abs() as f64;
                    count += 1;
                }
            }
            let alpha = if count > 0 { (sum / count as f64) as f32 } else { 0.0 };
            for r in 0..group {
                let idx = (gi * group + r) * n + col;
                out[idx] = if w[idx].abs() >= thresh {
                    q8[idx]
                } else {
                    alpha * w[idx].signum()
                };
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mae(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
    }

    #[test]
    fn higher_budget_more_salient_lower_error() {
        let mut rng = Rng::new(5);
        let (k, n) = (64, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let e2 = mae(&w, &quantize_pbllm(&w, k, n, 32, 2));
        let e3 = mae(&w, &quantize_pbllm(&w, k, n, 32, 3));
        assert!(e3 < e2, "e3={e3} e2={e2}");
    }

    #[test]
    fn worse_than_rtn_at_same_budget_on_gaussian() {
        // The binarized bulk hurts when weights aren't outlier-dominated —
        // exactly the paper's observed PB-LLM collapse pattern.
        let mut rng = Rng::new(6);
        let (k, n) = (64, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let e_pb = mae(&w, &quantize_pbllm(&w, k, n, 32, 2));
        let e_rtn = mae(&w, &quant_dequant(&w, k, n, 32, 2));
        assert!(e_pb > e_rtn * 0.8, "pb={e_pb} rtn={e_rtn}");
    }

    /// The hoisted `saliency::magnitude_threshold` must reproduce the
    /// pre-refactor inline sort bit-for-bit, so PB-LLM output is pinned
    /// unchanged across the refactor.
    #[test]
    fn shared_threshold_is_bit_identical_to_inline_sort() {
        let mut rng = Rng::new(11);
        let w: Vec<f32> = (0..64 * 32).map(|_| rng.normal_f32()).collect();
        for n_salient in [0usize, 1, 17, 500, 64 * 32] {
            let mut mags: Vec<f32> = w.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let inline = if n_salient == 0 {
                f32::INFINITY
            } else {
                mags[n_salient.saturating_sub(1)]
            };
            let shared = crate::quant::saliency::magnitude_threshold(&w, n_salient);
            assert_eq!(inline.to_bits(), shared.to_bits(), "n_salient={n_salient}");
        }
    }

    #[test]
    fn salient_weights_preserved() {
        let mut rng = Rng::new(7);
        let (k, n) = (32, 8);
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.1).collect();
        w[5] = 50.0; // extreme outlier must survive nearly intact
        let q = quantize_pbllm(&w, k, n, 32, 3);
        assert!((q[5] - 50.0).abs() < 1.0, "outlier destroyed: {}", q[5]);
    }
}
