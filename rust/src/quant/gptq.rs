//! GPTQ backend (Frantar et al., 2022): error-compensated column-by-column
//! quantization using second-order (Hessian) information from calibration
//! activations.
//!
//! For a linear `y = x W` with W (K x N) quantized along K:
//! H = 2 X Xᵀ (K x K) from calibration inputs X; we process rows
//! k = 0..K in order, quantizing row k and distributing its quantization
//! error onto not-yet-quantized rows via the Cholesky factor of H⁻¹
//! (the standard GPTQ recursion, transposed to our x·W convention).
//!
//! Two fidelity points matching the reference implementation:
//!
//! * **Group grids are recomputed at each group boundary** from the
//!   error-compensated working weights — not frozen from the original
//!   weights up front — exactly like reference GPTQ's `find_params` call
//!   per group inside the recursion.
//! * **Blocked error propagation**: rows are processed in K-panels
//!   (multiples of the group size). Inside a panel the recursion is
//!   sequential; the trailing update onto rows beyond the panel is
//!   deferred to the panel boundary and fanned out over [`Pool`]. Each
//!   trailing element receives the same subtractions in the same source
//!   row order as the naive recursion, so the output is **bit-identical
//!   to the sequential algorithm at any thread count** (pinned by
//!   `rust/tests/parallel.rs`).
//!
//! The whole second-order setup is pooled with the same discipline: the
//! Hessian build rides `Mat::gram_pooled`, and `cholesky_inverse_upper`
//! now runs the blocked right-looking Cholesky plus per-column solve
//! fan-out from `linalg::chol` — every piece bit-identical to its
//! sequential counterpart, so a GPTQ run is reproducible at any
//! `--threads` setting end to end.

use anyhow::{ensure, Result};

use crate::linalg::{cholesky_inverse_upper, Mat};
use crate::util::Pool;

use super::pack::QuantStats;

/// Dampening fraction of mean diagonal (GPTQ default 0.01).
const PERCDAMP: f64 = 0.01;

/// Target K-panel length for the blocked recursion (rounded up to a
/// multiple of the group size so every group's grid is computed from
/// fully-compensated rows). Reference GPTQ uses 128.
const PANEL_TARGET: usize = 128;

/// Simulated-quantized weights with Hessian compensation. `x_calib` is the
/// calibration input matrix (rows = samples, cols = K); falls back to RTN
/// when absent (identity Hessian). Errs on malformed `group`/`k` instead
/// of asserting deep inside the packing primitives.
pub fn quantize_gptq(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    x_calib: Option<&[f32]>,
) -> Result<Vec<f32>> {
    Ok(quantize_gptq_with_stats(w, k, n, group, bits, x_calib)?.0)
}

/// [`quantize_gptq`] plus the per-group affine grids actually used (the
/// grids derived from the compensated working weights).
pub fn quantize_gptq_with_stats(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    x_calib: Option<&[f32]>,
) -> Result<(Vec<f32>, QuantStats)> {
    ensure!(group > 0, "GPTQ: group size must be positive");
    ensure!(
        group <= k,
        "GPTQ: group size {group} exceeds input dim K={k} (shrink --group or pick a wider \
         linear)"
    );
    ensure!(k % group == 0, "GPTQ: K={k} not divisible by group={group}");
    ensure!(w.len() == k * n, "GPTQ: weight len {} != K*N = {}", w.len(), k * n);
    ensure!((1..=8).contains(&bits), "GPTQ: unsupported bit-width {bits}");

    let pool = Pool::current();
    let hinv_u = match x_calib {
        Some(x) => {
            let samples = x.len() / k;
            let xm = Mat::from_f32(x, samples, k);
            let mut h = xm.gram_pooled(&pool); // XᵀX (K x K), bit-identical to gram()
            h.scale(2.0);
            let mean_diag = (0..k).map(|i| h[(i, i)]).sum::<f64>() / k as f64;
            h.add_diag((PERCDAMP * mean_diag).max(1e-8));
            match cholesky_inverse_upper(&h) {
                Ok(u) => Some(u),
                Err(e) => {
                    log::warn!("GPTQ cholesky failed ({e}); falling back to RTN");
                    None
                }
            }
        }
        None => None,
    };
    let Some(hinv_u) = hinv_u else {
        // RTN fallback (= quantize_rtn bit-for-bit, without quantizing
        // the matrix a second time just to recover the stats).
        let (codes, stats) = super::pack::quantize_group(w, k, n, group, bits);
        let q = super::pack::dequantize(&codes, &stats, k, n, group);
        return Ok((q, stats));
    };

    // Working copy of W in f64; rows are quantized in K order.
    let mut wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut q = vec![0f32; k * n];
    let levels = ((1u32 << bits) - 1) as f64;
    let groups = k / group;
    let mut scale = vec![0f32; groups * n];
    let mut minv = vec![0f32; groups * n];

    // Panel = whole groups, so a group's grid is always computed after its
    // rows got every update from earlier panels (trailing, at panel ends)
    // and earlier in-panel rows (eager).
    let panel = group * (PANEL_TARGET / group).max(1);

    let mut p0 = 0usize;
    while p0 < k {
        let p1 = (p0 + panel).min(k);
        let rows_in_panel = p1 - p0;
        // Per-row quantization errors of this panel, for the deferred
        // trailing update: err[(row - p0) * n + col].
        let mut errs = vec![0f64; rows_in_panel * n];

        for row in p0..p1 {
            let gi = row / group;
            if row % group == 0 {
                // Group boundary: derive the affine grid from the current
                // (error-compensated) working weights of this group.
                for col in 0..n {
                    let mut mx = f64::NEG_INFINITY;
                    let mut mn = f64::INFINITY;
                    for r in 0..group {
                        let v = wf[(gi * group + r) * n + col];
                        mx = mx.max(v);
                        mn = mn.min(v);
                    }
                    scale[gi * n + col] = (((mx - mn) / levels) as f32).max(1e-8);
                    minv[gi * n + col] = mn as f32;
                }
            }
            let d = hinv_u[(row, row)];
            // Quantize row `row` with its group's grid.
            for col in 0..n {
                let s = scale[gi * n + col] as f64;
                let mn = minv[gi * n + col] as f64;
                let v = wf[row * n + col];
                let c = ((v - mn) / s).round().clamp(0.0, levels);
                let vq = c * s + mn;
                q[row * n + col] = vq as f32;
                errs[(row - p0) * n + col] = (v - vq) / d;
            }
            // Propagate eagerly *within* the panel (the recursion needs
            // row+1.. compensated before they quantize).
            for later in row + 1..p1 {
                let u = hinv_u[(row, later)];
                if u == 0.0 {
                    continue;
                }
                let e = &errs[(row - p0) * n..(row - p0 + 1) * n];
                let wrow = &mut wf[later * n..(later + 1) * n];
                for col in 0..n {
                    wrow[col] -= u * e[col];
                }
            }
        }

        // Deferred trailing update onto rows beyond the panel, fanned out
        // over the pool. Each later row applies the panel's errors in
        // source-row order — the exact FP operation sequence of the
        // sequential recursion — and rows are disjoint, so the result is
        // bit-identical at any thread count.
        if p1 < k {
            let errs = &errs;
            let hinv_u = &hinv_u;
            let trailing = &mut wf[p1 * n..k * n];
            pool.par_chunks_mut(trailing, 8 * n, |ci, chunk| {
                for (ri, wrow) in chunk.chunks_mut(n).enumerate() {
                    let later = p1 + ci * 8 + ri;
                    for r in p0..p1 {
                        let u = hinv_u[(r, later)];
                        if u == 0.0 {
                            continue;
                        }
                        let e = &errs[(r - p0) * n..(r - p0 + 1) * n];
                        for col in 0..n {
                            wrow[col] -= u * e[col];
                        }
                    }
                }
            });
        }
        p0 = p1;
    }

    Ok((q, QuantStats { scale, minv, groups, n }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reconstruction error ‖(W - Ŵ)ᵀX‖² — what GPTQ actually minimizes.
    fn task_error(w: &[f32], q: &[f32], x: &[f32], k: usize, n: usize) -> f64 {
        let samples = x.len() / k;
        let mut err = 0.0;
        for s in 0..samples {
            for col in 0..n {
                let mut acc = 0.0f64;
                for row in 0..k {
                    acc += x[s * k + row] as f64 * (w[row * n + col] - q[row * n + col]) as f64;
                }
                err += acc * acc;
            }
        }
        err
    }

    fn setup(seed: u64, k: usize, n: usize, samples: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        // Correlated calibration inputs (realistic: activations are not iid).
        let mut x = vec![0f32; samples * k];
        for s in 0..samples {
            let shared = rng.normal_f32();
            for col in 0..k {
                x[s * k + col] = 0.6 * shared + rng.normal_f32();
            }
        }
        (w, x)
    }

    #[test]
    fn beats_rtn_on_task_error() {
        let (k, n, samples) = (64, 48, 128);
        let mut wins = 0;
        for seed in 0..5 {
            let (w, x) = setup(seed, k, n, samples);
            let q_gptq = quantize_gptq(&w, k, n, 32, 2, Some(&x)).unwrap();
            let q_rtn = super::super::rtn::quantize_rtn(&w, k, n, 32, 2);
            let e_gptq = task_error(&w, &q_gptq, &x, k, n);
            let e_rtn = task_error(&w, &q_rtn, &x, k, n);
            if e_gptq < e_rtn {
                wins += 1;
            }
        }
        assert!(wins >= 4, "GPTQ won only {wins}/5 vs RTN");
    }

    #[test]
    fn falls_back_to_rtn_without_calib() {
        let (w, _) = setup(1, 32, 16, 8);
        let a = quantize_gptq(&w, 32, 16, 32, 3, None).unwrap();
        let b = super::super::rtn::quantize_rtn(&w, 32, 16, 32, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn output_on_own_quant_grid() {
        // Every output value must be expressible as c*scale+min for the
        // *recomputed* per-group grid the algorithm reports (grids come
        // from the compensated working weights, not the original W).
        let (w, x) = setup(2, 64, 8, 64);
        let (q, stats) = quantize_gptq_with_stats(&w, 64, 8, 64, 2, Some(&x)).unwrap();
        assert_eq!(stats.groups, 1);
        for row in 0..64 {
            for col in 0..8 {
                let s = stats.scale[col];
                let mn = stats.minv[col];
                let c = (q[row * 8 + col] - mn) / s;
                assert!((c - c.round()).abs() < 1e-3, "off grid: c={c}");
                assert!(c.round() >= 0.0 && c.round() <= 3.0);
            }
        }
    }

    #[test]
    fn recomputed_grids_do_not_hurt_task_error() {
        // The boundary-recomputed grids track the compensated weights, so
        // GPTQ must stay ahead of RTN with multiple groups per panel too.
        let (k, n, samples) = (128, 24, 96);
        let (w, x) = setup(9, k, n, samples);
        let q_gptq = quantize_gptq(&w, k, n, 32, 2, Some(&x)).unwrap();
        let q_rtn = super::super::rtn::quantize_rtn(&w, k, n, 32, 2);
        assert!(task_error(&w, &q_gptq, &x, k, n) < task_error(&w, &q_rtn, &x, k, n));
    }

    #[test]
    fn malformed_group_is_a_proper_error() {
        let (w, x) = setup(3, 32, 8, 16);
        // group > k
        let err = quantize_gptq(&w, 32, 8, 64, 2, Some(&x)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds input dim"), "{err:#}");
        // non-divisible K
        let err = quantize_gptq(&w, 32, 8, 24, 2, Some(&x)).unwrap_err();
        assert!(format!("{err:#}").contains("not divisible"), "{err:#}");
        // zero group
        assert!(quantize_gptq(&w, 32, 8, 0, 2, Some(&x)).is_err());
    }
}
