//! GPTQ backend (Frantar et al., 2022): error-compensated column-by-column
//! quantization using second-order (Hessian) information from calibration
//! activations.
//!
//! For a linear `y = x W` with W (K x N) quantized along K:
//! H = 2 X Xᵀ (K x K) from calibration inputs X; we process rows
//! k = 0..K in order, quantizing row k and distributing its quantization
//! error onto not-yet-quantized rows via the Cholesky factor of H⁻¹
//! (the standard GPTQ recursion, transposed to our x·W convention).

use crate::linalg::{cholesky_inverse_upper, Mat};

use super::pack::quantize_group;

/// Dampening fraction of mean diagonal (GPTQ default 0.01).
const PERCDAMP: f64 = 0.01;

/// Simulated-quantized weights with Hessian compensation. `x_calib` is the
/// calibration input matrix (rows = samples, cols = K); falls back to RTN
/// when absent (identity Hessian).
pub fn quantize_gptq(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    x_calib: Option<&[f32]>,
) -> Vec<f32> {
    let hinv_u = match x_calib {
        Some(x) => {
            let samples = x.len() / k;
            let xm = Mat::from_f32(x, samples, k);
            let mut h = xm.gram(); // XᵀX (K x K)
            h.scale(2.0);
            let mean_diag =
                (0..k).map(|i| h[(i, i)]).sum::<f64>() / k as f64;
            h.add_diag((PERCDAMP * mean_diag).max(1e-8));
            match cholesky_inverse_upper(&h) {
                Ok(u) => Some(u),
                Err(e) => {
                    log::warn!("GPTQ cholesky failed ({e}); falling back to RTN");
                    None
                }
            }
        }
        None => None,
    };
    let Some(hinv_u) = hinv_u else {
        return super::rtn::quantize_rtn(w, k, n, group, bits);
    };

    // Working copy of W in f64; rows are quantized in K order.
    let mut wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut q = vec![0f32; k * n];
    let levels = ((1u32 << bits) - 1) as f64;

    // Per-group affine stats must be fixed *before* compensation shifts the
    // remaining rows (standard GPTQ keeps grid from the original weights).
    let (_, stats) = quantize_group(w, k, n, group, bits);

    for row in 0..k {
        let gi = row / group;
        let d = hinv_u[(row, row)];
        // Quantize row `row` with its group's grid.
        let mut err = vec![0f64; n];
        for col in 0..n {
            let s = stats.scale[gi * n + col] as f64;
            let mn = stats.minv[gi * n + col] as f64;
            let v = wf[row * n + col];
            let c = ((v - mn) / s).round().clamp(0.0, levels);
            let vq = c * s + mn;
            q[row * n + col] = vq as f32;
            err[col] = (v - vq) / d;
        }
        // Propagate error to the remaining rows (columns of U beyond row).
        for later in row + 1..k {
            let u = hinv_u[(row, later)];
            if u == 0.0 {
                continue;
            }
            let wrow = &mut wf[later * n..(later + 1) * n];
            for col in 0..n {
                wrow[col] -= u * err[col];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reconstruction error ‖(W - Ŵ)ᵀX‖² — what GPTQ actually minimizes.
    fn task_error(w: &[f32], q: &[f32], x: &[f32], k: usize, n: usize) -> f64 {
        let samples = x.len() / k;
        let mut err = 0.0;
        for s in 0..samples {
            for col in 0..n {
                let mut acc = 0.0f64;
                for row in 0..k {
                    acc += x[s * k + row] as f64 * (w[row * n + col] - q[row * n + col]) as f64;
                }
                err += acc * acc;
            }
        }
        err
    }

    fn setup(seed: u64, k: usize, n: usize, samples: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        // Correlated calibration inputs (realistic: activations are not iid).
        let mut x = vec![0f32; samples * k];
        for s in 0..samples {
            let shared = rng.normal_f32();
            for col in 0..k {
                x[s * k + col] = 0.6 * shared + rng.normal_f32();
            }
        }
        (w, x)
    }

    #[test]
    fn beats_rtn_on_task_error() {
        let (k, n, samples) = (64, 48, 128);
        let mut wins = 0;
        for seed in 0..5 {
            let (w, x) = setup(seed, k, n, samples);
            let q_gptq = quantize_gptq(&w, k, n, 32, 2, Some(&x));
            let q_rtn = super::super::rtn::quantize_rtn(&w, k, n, 32, 2);
            let e_gptq = task_error(&w, &q_gptq, &x, k, n);
            let e_rtn = task_error(&w, &q_rtn, &x, k, n);
            if e_gptq < e_rtn {
                wins += 1;
            }
        }
        assert!(wins >= 4, "GPTQ won only {wins}/5 vs RTN");
    }

    #[test]
    fn falls_back_to_rtn_without_calib() {
        let (w, _) = setup(1, 32, 16, 8);
        let a = quantize_gptq(&w, 32, 16, 32, 3, None);
        let b = super::super::rtn::quantize_rtn(&w, 32, 16, 32, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn output_on_quant_grid() {
        let (w, x) = setup(2, 64, 8, 64);
        let q = quantize_gptq(&w, 64, 8, 64, 2, Some(&x));
        // Every output value must be expressible as c*scale+min for c in 0..4.
        let (_, stats) = quantize_group(&w, 64, 8, 64, 2);
        for row in 0..64 {
            for col in 0..8 {
                let s = stats.scale[col];
                let mn = stats.minv[col];
                let c = (q[row * 8 + col] - mn) / s;
                assert!((c - c.round()).abs() < 1e-3, "off grid: c={c}");
                assert!(c.round() >= 0.0 && c.round() <= 3.0);
            }
        }
    }
}
