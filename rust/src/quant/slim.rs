//! SliM-LLM-like backend (Huang et al., 2025): salience-driven per-group
//! mixed precision *within* a layer.
//!
//! Groups are ranked by salience (activation-weighted weight magnitude);
//! the top half gets `bits+1`, the bottom half `bits-1`, preserving the
//! average budget. This is the paper's "finer-grained mixed precision"
//! contrast class: better error than uniform RTN, but the per-group bit
//! map breaks tensor-contiguous layouts (which LieQ avoids).

use super::pack::quant_dequant;

pub fn quantize_slim(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    x_calib: Option<&[f32]>,
) -> Vec<f32> {
    let groups = k / group;
    // Per-input-channel activation magnitude (uniform without calibration).
    let act: Vec<f64> = match x_calib {
        Some(x) => {
            let samples = x.len() / k;
            let mut a = vec![0f64; k];
            for s in 0..samples {
                for col in 0..k {
                    a[col] += x[s * k + col].abs() as f64;
                }
            }
            a.iter().map(|v| v / samples as f64).collect()
        }
        None => vec![1.0; k],
    };

    // Group salience: Σ act_k · ‖W_k·‖₁ over the group's rows.
    let mut salience: Vec<(f64, usize)> = (0..groups)
        .map(|gi| {
            let mut s = 0.0;
            for r in 0..group {
                let row = gi * group + r;
                let wl1: f64 =
                    (0..n).map(|c| w[row * n + c].abs() as f64).sum();
                s += act[row] * wl1;
            }
            (s, gi)
        })
        .collect();
    salience.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let hi_bits = (bits + 1).min(8);
    let lo_bits = (bits - 1).max(1);
    let n_hi = groups / 2;

    // Quantize the full tensor at both precisions, then select per group.
    let q_hi = quant_dequant(w, k, n, group, hi_bits);
    let q_lo = quant_dequant(w, k, n, group, lo_bits);
    let mut out = vec![0f32; k * n];
    let mut is_hi = vec![false; groups];
    for (rank, &(_, gi)) in salience.iter().enumerate() {
        is_hi[gi] = rank < n_hi;
    }
    for gi in 0..groups {
        let src = if is_hi[gi] { &q_hi } else { &q_lo };
        let lo = gi * group * n;
        let hi = (gi + 1) * group * n;
        out[lo..hi].copy_from_slice(&src[lo..hi]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn budget_preserved_half_half() {
        // With groups split half/half between bits±1 the average is `bits`.
        let mut rng = Rng::new(8);
        let (k, n, g) = (128, 16, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let q = quantize_slim(&w, k, n, g, 3, None);
        assert_eq!(q.len(), w.len());
    }

    #[test]
    fn salient_groups_get_lower_error() {
        let mut rng = Rng::new(9);
        let (k, n, g) = (128, 24, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        // Make channels of group 0 highly salient.
        let samples = 64;
        let mut x = vec![0f32; samples * k];
        for s in 0..samples {
            for col in 0..k {
                let boost = if col < g { 10.0 } else { 1.0 };
                x[s * k + col] = rng.normal_f32() * boost;
            }
        }
        let q = quantize_slim(&w, k, n, g, 2, Some(&x));
        let err_g0: f32 = (0..g * n).map(|i| (w[i] - q[i]).abs()).sum::<f32>() / (g * n) as f32;
        let err_rest: f32 = (g * n..k * n).map(|i| (w[i] - q[i]).abs()).sum::<f32>()
            / ((k - g) * n) as f32;
        assert!(err_g0 < err_rest, "salient group err {err_g0} >= rest {err_rest}");
    }

    #[test]
    fn beats_uniform_rtn_with_salience_skew() {
        let mut rng = Rng::new(10);
        let (k, n, g) = (128, 16, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let samples = 64;
        let mut x = vec![0f32; samples * k];
        for s in 0..samples {
            for col in 0..k {
                let boost = if col < 2 * g { 6.0 } else { 0.3 };
                x[s * k + col] = rng.normal_f32() * boost;
            }
        }
        // Activation-weighted error.
        let werr = |q: &[f32]| -> f64 {
            let mut e = 0.0;
            for row in 0..k {
                let a: f64 = (0..samples).map(|s| x[s * k + row].abs() as f64).sum();
                for col in 0..n {
                    let d = (w[row * n + col] - q[row * n + col]) as f64;
                    e += a * d * d;
                }
            }
            e
        };
        let q_slim = quantize_slim(&w, k, n, g, 2, Some(&x));
        let q_rtn = quant_dequant(&w, k, n, g, 2);
        assert!(werr(&q_slim) < werr(&q_rtn));
    }
}
