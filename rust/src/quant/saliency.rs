//! Shared saliency scoring for the mixed-precision backends and the
//! outlier extractor.
//!
//! Two selection primitives live here so they are implemented exactly
//! once:
//!
//! * **Element-magnitude threshold** ([`magnitude_threshold`]) — the
//!   PB-LLM salient split: the |w| of the `n_salient`-th largest
//!   element, so `|w| >= threshold` selects the salient fraction.
//! * **Column impact** ([`column_scores`] / [`top_columns`]) — the
//!   high-impact-parameter rule the outlier-aware packer uses: per
//!   input feature k, the squared column norm `Σ_col W[k,col]²`
//!   weighted by the calibration activation energy `E[x_k²]`. Columns
//!   whose weights are large *and* whose activations carry energy are
//!   exactly the ones a sub-2-bit grid destroys first.
//!
//! Selection is deterministic: ties break on the lower column index, so
//! quantization output is reproducible across runs and thread counts.

/// Magnitude of the `n_salient`-th largest |w| — the PB-LLM salience
/// threshold. `n_salient == 0` returns +inf (nothing selected). The
/// sort mirrors `quant::pbllm`'s original descending `partial_cmp`
/// exactly, so the split is bit-identical to the pre-refactor backend.
pub fn magnitude_threshold(w: &[f32], n_salient: usize) -> f32 {
    if n_salient == 0 {
        return f32::INFINITY;
    }
    let mut mags: Vec<f32> = w.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    mags[n_salient.saturating_sub(1)]
}

/// Mean squared activation per input feature: `x` is a calibration
/// matrix of `x.len() / k` rows over `k` features (row-major). Empty
/// calibration yields unit energy (pure magnitude scoring).
pub fn activation_energy(x: &[f32], k: usize) -> Vec<f32> {
    let rows = x.len() / k;
    if rows == 0 {
        return vec![1.0; k];
    }
    let mut e = vec![0f64; k];
    for row in 0..rows {
        let xr = &x[row * k..(row + 1) * k];
        for (acc, &v) in e.iter_mut().zip(xr) {
            *acc += (v as f64) * (v as f64);
        }
    }
    e.iter().map(|&s| (s / rows as f64) as f32).collect()
}

/// Per-input-column impact score over `w` (K x N row-major):
/// `score[k] = (Σ_col W[k,col]²) · energy[k]`, with unit energy when no
/// calibration is supplied. Accumulation runs in f64 so the score is
/// independent of any future chunking of the column loop.
pub fn column_scores(w: &[f32], k: usize, n: usize, act_energy: Option<&[f32]>) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    if let Some(e) = act_energy {
        assert_eq!(e.len(), k);
    }
    let mut scores = Vec::with_capacity(k);
    for row in 0..k {
        let wr = &w[row * n..(row + 1) * n];
        let norm: f64 = wr.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let e = act_energy.map_or(1.0, |e| e[row] as f64);
        scores.push((norm * e) as f32);
    }
    scores
}

/// The `count` highest-scoring columns, deterministically tie-broken
/// (score descending, then index ascending — `total_cmp`, so NaN scores
/// cannot panic the sort), returned **ascending** for the kernels'
/// fixed fusion order.
pub fn top_columns(scores: &[f32], count: usize) -> Vec<u32> {
    let count = count.min(scores.len());
    if count == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        scores[b as usize]
            .total_cmp(&scores[a as usize])
            .then_with(|| a.cmp(&b))
    });
    idx.truncate(count);
    idx.sort_unstable();
    idx
}

/// Number of outlier columns a top-ε policy extracts from `k` input
/// features: `ceil(ε·k)`, clamped to `[0, k]`; non-positive ε selects
/// nothing (the ε=0 archive-compatibility contract).
pub fn outlier_count(k: usize, eps: f64) -> usize {
    if eps <= 0.0 || k == 0 {
        return 0;
    }
    ((eps * k as f64).ceil() as usize).min(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnitude_threshold_matches_sorted_rank() {
        let w = [0.5f32, -3.0, 1.0, -0.25, 2.0];
        assert_eq!(magnitude_threshold(&w, 0), f32::INFINITY);
        assert_eq!(magnitude_threshold(&w, 1), 3.0);
        assert_eq!(magnitude_threshold(&w, 2), 2.0);
        assert_eq!(magnitude_threshold(&w, 5), 0.25);
    }

    #[test]
    fn column_scores_weight_energy() {
        // K=2, N=2: row 0 = [1, 1] (norm 2), row 1 = [2, 0] (norm 4).
        let w = [1.0f32, 1.0, 2.0, 0.0];
        let plain = column_scores(&w, 2, 2, None);
        assert_eq!(plain, vec![2.0, 4.0]);
        // Energy flips the ranking: row 0 carries 10x the activation power.
        let e = [10.0f32, 1.0];
        let weighted = column_scores(&w, 2, 2, Some(&e));
        assert_eq!(weighted, vec![20.0, 4.0]);
        assert_eq!(top_columns(&plain, 1), vec![1]);
        assert_eq!(top_columns(&weighted, 1), vec![0]);
    }

    #[test]
    fn top_columns_deterministic_ties_ascending_output() {
        let scores = [1.0f32, 3.0, 3.0, 0.5, 3.0];
        // Three-way tie at 3.0: lower indices win.
        assert_eq!(top_columns(&scores, 2), vec![1, 2]);
        assert_eq!(top_columns(&scores, 3), vec![1, 2, 4]);
        // Output is ascending even though rank order is 1,2,4,0,3.
        assert_eq!(top_columns(&scores, 4), vec![0, 1, 2, 4]);
        assert_eq!(top_columns(&scores, 99).len(), 5);
    }

    #[test]
    fn outlier_count_ceil_and_clamp() {
        assert_eq!(outlier_count(2048, 0.01), 21); // ceil(20.48)
        assert_eq!(outlier_count(2048, 0.0), 0);
        assert_eq!(outlier_count(2048, -1.0), 0);
        assert_eq!(outlier_count(64, 1.0), 64);
        assert_eq!(outlier_count(64, 9.0), 64);
        assert_eq!(outlier_count(0, 0.5), 0);
    }

    #[test]
    fn activation_energy_means_squares() {
        // 2 rows x 3 features.
        let x = [1.0f32, 0.0, 2.0, 3.0, 0.0, 2.0];
        assert_eq!(activation_energy(&x, 3), vec![5.0, 0.0, 4.0]);
        assert_eq!(activation_energy(&[], 3), vec![1.0, 1.0, 1.0]);
    }
}
