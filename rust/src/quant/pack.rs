//! Quantization primitives: group-wise asymmetric uniform quantization and
//! u32 bit-plane packing (byte-identical to the Pallas kernel format).
//!
//! Layout for W (K x N, row-major, K = input dim):
//! * codes `c[k][n] in [0, 2^b - 1]`, `W ≈ c * scale + minv`
//! * `scale`/`minv`: `[K/g][N]` per (group, output-channel)
//! * planes: `u32[b][K/32][N]`; bit `k % 32` of `plane[j][k/32][n]` is bit
//!   `j` of `c[k][n]`.
//!
//! Two code layouts share the same quantization grid:
//!
//! * **Bit planes** (above) — the interchange/reference layout, shared
//!   byte-for-byte with the Pallas kernels and the `.lieq` deployment
//!   format. Decoding a weight reassembles its code bit-by-bit from
//!   `bits` separate plane words.
//! * **Interleaved lanes** — a derived acceleration layout for the LUT
//!   CPU kernels: per (group, column), the group's codes are stored as
//!   one contiguous byte lane. For `bits <= 4` with an even group size a
//!   lane packs two codes per byte (nibble lanes, low nibble = earlier
//!   row); otherwise one code per byte. Sequential lane reads replace
//!   per-weight bit reassembly in the GEMV inner loop.
//!
//! [`interleave_codes`] / [`deinterleave_codes`] and the plane-level
//! wrappers [`planes_to_interleaved`] / [`interleaved_to_planes`] are
//! lossless in both directions; `rust/src/quant/pack.rs` tests pin the
//! roundtrip for every supported bit-width and both lane kinds.

use std::sync::OnceLock;

use super::act::ActQuant;

/// Per-group affine stats.
#[derive(Clone, Debug)]
pub struct QuantStats {
    pub scale: Vec<f32>, // [K/g * N]
    pub minv: Vec<f32>,  // [K/g * N]
    pub groups: usize,
    pub n: usize,
}

/// Round an f32 to IEEE 754 binary16 (round-to-nearest-even, overflow to
/// ±inf), returning the 16-bit encoding. No half-float crate ships with
/// the crate, so the conversion is spelled out; `pack.rs` tests pin the
/// golden encodings and the Python oracle mirrors the bit math.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xFF) as i32;
    let man = b & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN (NaN keeps a payload bit so it stays NaN).
        let payload = if man != 0 { 0x200 } else { 0 };
        return sign | 0x7C00 | payload;
    }
    let e = exp - 127 + 15; // rebias for binary16
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal target (or underflow to signed zero).
        if e < -10 {
            return sign;
        }
        let m = man | 0x0080_0000; // implicit leading 1, 24 bits
        let shift = (14 - e) as u32; // 14..=24
        let mut v = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && v & 1 != 0) {
            v += 1; // may carry into the smallest normal — valid encoding
        }
        return sign | v as u16;
    }
    // Normal: drop 13 mantissa bits with round-to-nearest-even. A carry
    // propagates into the exponent field arithmetically (0x7C00 = inf).
    let mut v = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && v & 1 != 0) {
        v += 1;
    }
    sign | v as u16
}

/// Decode an IEEE 754 binary16 encoding to f32 (exact — every binary16
/// value is representable in binary32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // inf / NaN
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal: value = man * 2^-24; normalize into binary32.
            let msb = 31 - man.leading_zeros(); // 0..=9
            let e = msb + 103; // msb - 24 + 127
            sign | (e << 23) | ((man << (23 - msb)) & 0x007F_FFFF)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> nearest binary16 value -> f32 (the precision outlier sidecar
/// values are stored at).
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Sparse fp16 outlier sidecar: the top-ε high-impact **input features**
/// (rows of the K x N weight, i.e. the columns `x` multiplies) extracted
/// from the dense low-bit grid.
///
/// `cols` holds ascending, unique K-dim feature indices; `vals` is
/// `cols.len() x N` row-major with every value rounded through IEEE 754
/// binary16 ([`f16_round`]) — the storage precision. Semantics are
/// **replace**, not add: extraction zeroes these rows in the dense grid
/// before code assignment, and every decode path substitutes `vals`
/// wholesale for them (the fused kernels do it by zeroing the matching
/// `x` entries for the dense pass and adding the sparse product back).
#[derive(Clone, Debug, PartialEq)]
pub struct OutlierSide {
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl OutlierSide {
    /// Number of extracted input features.
    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    /// Deployment footprint for an N-wide linear: one u32 index plus N
    /// fp16 values per extracted column (the `.lieq` v4 payload size).
    pub fn side_bytes(&self, n: usize) -> usize {
        self.cols.len() * 4 + self.cols.len() * n * 2
    }

    /// Structural validity against a K x N weight: ascending unique
    /// in-range indices, matching value length, finite values. Untrusted
    /// (deserialized) sidecars must pass this before attaching.
    pub fn validate(&self, k: usize, n: usize) -> bool {
        self.vals.len() == self.cols.len().saturating_mul(n)
            && self.cols.windows(2).all(|pair| pair[0] < pair[1])
            && self.cols.last().map_or(true, |&c| (c as usize) < k)
            && self.vals.iter().all(|v| v.is_finite())
    }
}

/// A fully packed quantized weight (deployment format).
#[derive(Clone, Debug)]
pub struct PackedWeight {
    pub bits: u8,
    pub k: usize,
    pub n: usize,
    pub group_size: usize,
    /// u32[bits][K/32][N], flattened.
    pub planes: Vec<u32>,
    pub stats: QuantStats,
    /// Calibrated INT8 activation-quantization parameters for this
    /// linear's *input* (the W·A8 kernel path), when calibration ran.
    /// Persisted as a `.lieq` v3 side entry; `None` means the A8 kernel
    /// falls back to per-row dynamic quantization.
    pub act: Option<ActQuant>,
    /// Sparse fp16 outlier sidecar (top-ε high-impact input features,
    /// zeroed out of the dense grid at quantization time). Persisted as
    /// a `.lieq` v4 section; `None` means the linear is purely dense.
    pub outliers: Option<OutlierSide>,
    /// Lazily-built interleaved lane image of `planes` (see module docs).
    /// Derived, never serialized; built on first LUT-kernel use.
    lanes: OnceLock<Vec<u8>>,
}

impl PackedWeight {
    pub fn new(
        bits: u8,
        k: usize,
        n: usize,
        group_size: usize,
        planes: Vec<u32>,
        stats: QuantStats,
    ) -> PackedWeight {
        PackedWeight {
            bits,
            k,
            n,
            group_size,
            planes,
            stats,
            act: None,
            outliers: None,
            lanes: OnceLock::new(),
        }
    }

    /// Attach calibrated activation-quantization parameters (builder
    /// style; the quantization pipeline and the archive reader use this).
    pub fn with_act(mut self, act: ActQuant) -> PackedWeight {
        self.act = Some(act);
        self
    }

    /// Attach a sparse outlier sidecar (builder style). The sidecar must
    /// be structurally valid for this weight's shape — the extractor
    /// produces valid sidecars by construction, and the archive reader
    /// validates (and degrades to dense-only) before calling this.
    pub fn with_outliers(mut self, side: OutlierSide) -> PackedWeight {
        assert!(
            side.validate(self.k, self.n),
            "invalid outlier sidecar for {}x{} linear",
            self.k,
            self.n
        );
        self.outliers = Some(side);
        self
    }

    /// Number of extracted outlier columns (0 when purely dense).
    pub fn outlier_cols(&self) -> usize {
        self.outliers.as_ref().map_or(0, |o| o.n_cols())
    }

    /// Bytes held by the outlier sidecar (0 when purely dense).
    pub fn outlier_bytes(&self) -> usize {
        self.outliers.as_ref().map_or(0, |o| o.side_bytes(self.n))
    }

    /// Rehydrate a packed weight *with* a prebuilt interleaved lane image
    /// (the `.lieq` v2 archive read path): the lane cache is seeded, so
    /// later [`PackedWeight::interleaved`] calls perform no
    /// `planes_to_interleaved` conversion (and bump no `lane_builds`
    /// counter). Errs when `lanes` has the wrong length for the layout —
    /// callers with an unverifiable lane section should drop it and fall
    /// back to [`PackedWeight::new`] (on-demand conversion) instead.
    pub fn with_lanes(
        bits: u8,
        k: usize,
        n: usize,
        group_size: usize,
        planes: Vec<u32>,
        stats: QuantStats,
        lanes: Vec<u8>,
    ) -> anyhow::Result<PackedWeight> {
        let expect = (k / group_size) * n * lane_len(bits, group_size);
        anyhow::ensure!(
            lanes.len() == expect,
            "lane image length {} != expected {expect} (b{bits} k{k} n{n} g{group_size})",
            lanes.len()
        );
        let pw = PackedWeight::new(bits, k, n, group_size, planes, stats);
        pw.lanes.set(lanes).expect("fresh OnceLock");
        Ok(pw)
    }

    /// Packed size in bytes (planes + stats) — the *deployment* memory
    /// footprint, i.e. what ships in a `.lieq` archive's mandatory
    /// sections and what the compression-ratio ledgers compare against
    /// fp16. The interleaved lane cache is a derived acceleration
    /// structure (redundant with the planes) and is deliberately **not**
    /// counted here; use [`PackedWeight::resident_bytes`] for the
    /// in-memory total including a built lane image. The outlier sidecar
    /// **is** counted: it ships in the archive and is what the allocator
    /// charges the ε budget against.
    pub fn packed_bytes(&self) -> usize {
        self.planes.len() * 4 + self.stats.scale.len() * 8 + self.outlier_bytes()
    }

    /// Bytes currently held by the lane cache (0 until the first
    /// LUT/panel use builds it, or a v2 archive seeds it).
    pub fn lane_cache_bytes(&self) -> usize {
        self.lanes.get().map_or(0, |l| l.len())
    }

    /// Resident in-memory size: [`PackedWeight::packed_bytes`] plus the
    /// lane cache when built.
    pub fn resident_bytes(&self) -> usize {
        self.packed_bytes() + self.lane_cache_bytes()
    }

    /// True when the interleaved lane image is resident (built or
    /// seeded) — i.e. the next [`PackedWeight::interleaved`] is free.
    pub fn lanes_built(&self) -> bool {
        self.lanes.get().is_some()
    }

    pub fn fp16_bytes(&self) -> usize {
        self.k * self.n * 2
    }

    /// Decode back to simulated-dequantized f32 (`K x N` row-major) —
    /// what the artifact-backed scoring path consumes when serving a
    /// packed archive.
    pub fn dequantized(&self) -> Vec<f32> {
        let codes = unpack_planes(&self.planes, self.k, self.n, self.bits);
        let mut out = dequantize(&codes, &self.stats, self.k, self.n, self.group_size);
        // Outlier rows are *replaced* by their fp16 sidecar values — the
        // dense grid holds zeros there, but a zeroed row still decodes to
        // a grid point near (not at) zero, so substitution must be
        // wholesale for the roundtrip to be exact.
        if let Some(o) = &self.outliers {
            for (i, &c) in o.cols.iter().enumerate() {
                let row = c as usize * self.n;
                out[row..row + self.n].copy_from_slice(&o.vals[i * self.n..(i + 1) * self.n]);
            }
        }
        out
    }

    /// Interleaved code lanes, converted from the bit planes on first use
    /// and cached (thread-safe; the conversion is deterministic so a
    /// duplicate race-time build is identical). Each conversion that
    /// actually runs is counted in `kernels::kernel_path_stats()` as a
    /// `lane_builds` tick — zero on cache hits and on lane images seeded
    /// from a `.lieq` v2 archive.
    pub fn interleaved(&self) -> &[u8] {
        self.lanes.get_or_init(|| {
            crate::kernels::stats::record_lane_build();
            planes_to_interleaved(&self.planes, self.k, self.n, self.group_size, self.bits)
        })
    }

    /// Bytes per (group, column) lane in the interleaved layout.
    pub fn lane_len(&self) -> usize {
        lane_len(self.bits, self.group_size)
    }

    /// True when this weight's interleaved layout packs two codes per
    /// byte (nibble lanes) — the layout the LUT GEMV kernel decodes.
    pub fn nibble_lanes(&self) -> bool {
        nibble_lanes(self.bits, self.group_size)
    }
}

/// Nibble lanes (two codes per byte) apply when a code fits a nibble and
/// the group has an even row count; wider codes fall back to byte lanes.
pub fn nibble_lanes(bits: u8, group: usize) -> bool {
    bits <= 4 && group % 2 == 0
}

/// Bytes per (group, column) lane in the interleaved layout.
pub fn lane_len(bits: u8, group: usize) -> usize {
    if nibble_lanes(bits, group) {
        group / 2
    } else {
        group
    }
}

/// True when every code in a lane image is `< 2^bits` for its layout —
/// the content-validity check an untrusted (deserialized) lane image
/// must pass before the kernels may index dequant tables with it. Free
/// for 8-bit byte lanes and 4-bit nibble lanes (every byte pattern is a
/// valid code there).
pub fn lanes_codes_in_range(lanes: &[u8], bits: u8, group: usize) -> bool {
    if nibble_lanes(bits, group) {
        if bits == 4 {
            return true;
        }
        let mask = !(((1u8 << bits) - 1) | (((1u8 << bits) - 1) << 4));
        lanes.iter().all(|&b| b & mask == 0)
    } else {
        if bits == 8 {
            return true;
        }
        let limit = 1u8 << bits;
        lanes.iter().all(|&b| b < limit)
    }
}

/// Convert row-major codes (`u32[K*N]`, values < 2^bits) into interleaved
/// lanes: lane `(gi, col)` starts at `(gi * n + col) * lane_len` and holds
/// the group's codes for that column in row order (two per byte for
/// nibble lanes, low nibble first).
///
/// **Contract:** `K % group == 0` (asserted), matching
/// [`quantize_group`] — the whole packed pipeline has no ragged tail
/// group, so the lane layout deliberately doesn't model one either. A
/// K-tail would silently corrupt the `(gi * n + col) * lane_len`
/// addressing, hence the hard assert rather than a truncating loop;
/// `pack.rs` tests pin this for both converters.
pub fn interleave_codes(codes: &[u32], k: usize, n: usize, group: usize, bits: u8) -> Vec<u8> {
    assert_eq!(codes.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let groups = k / group;
    let ll = lane_len(bits, group);
    let mut lanes = vec![0u8; groups * n * ll];
    if nibble_lanes(bits, group) {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for p in 0..ll {
                    let c0 = codes[(gi * group + 2 * p) * n + col] as u8;
                    let c1 = codes[(gi * group + 2 * p + 1) * n + col] as u8;
                    lanes[base + p] = (c0 & 0xF) | (c1 << 4);
                }
            }
        }
    } else {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for r in 0..group {
                    lanes[base + r] = codes[(gi * group + r) * n + col] as u8;
                }
            }
        }
    }
    lanes
}

/// Inverse of [`interleave_codes`] (lossless for codes < 2^bits).
pub fn deinterleave_codes(lanes: &[u8], k: usize, n: usize, group: usize, bits: u8) -> Vec<u32> {
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let groups = k / group;
    let ll = lane_len(bits, group);
    assert_eq!(lanes.len(), groups * n * ll);
    let mut codes = vec![0u32; k * n];
    if nibble_lanes(bits, group) {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for p in 0..ll {
                    let b = lanes[base + p];
                    codes[(gi * group + 2 * p) * n + col] = (b & 0xF) as u32;
                    codes[(gi * group + 2 * p + 1) * n + col] = (b >> 4) as u32;
                }
            }
        }
    } else {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for r in 0..group {
                    codes[(gi * group + r) * n + col] = lanes[base + r] as u32;
                }
            }
        }
    }
    codes
}

/// Bit planes -> interleaved lanes (the planes stay the interchange
/// format; this derives the LUT-kernel acceleration layout).
pub fn planes_to_interleaved(
    planes: &[u32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> Vec<u8> {
    interleave_codes(&unpack_planes(planes, k, n, bits), k, n, group, bits)
}

/// Interleaved lanes -> bit planes (lossless inverse of
/// [`planes_to_interleaved`]).
pub fn interleaved_to_planes(
    lanes: &[u8],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> Vec<u32> {
    pack_planes(&deinterleave_codes(lanes, k, n, group, bits), k, n, bits)
}

/// Group-wise asymmetric uniform quantization of `w` (K x N row-major).
/// Returns (codes u32[K*N], stats).
pub fn quantize_group(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> (Vec<u32>, QuantStats) {
    assert_eq!(w.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let levels = ((1u32 << bits) - 1) as f32;
    let groups = k / group;
    let mut scale = vec![0f32; groups * n];
    let mut minv = vec![0f32; groups * n];
    let mut codes = vec![0u32; k * n];

    for gi in 0..groups {
        for col in 0..n {
            let mut mx = f32::NEG_INFINITY;
            let mut mn = f32::INFINITY;
            for r in 0..group {
                let v = w[(gi * group + r) * n + col];
                mx = mx.max(v);
                mn = mn.min(v);
            }
            let s = ((mx - mn) / levels).max(1e-8);
            scale[gi * n + col] = s;
            minv[gi * n + col] = mn;
            for r in 0..group {
                let idx = (gi * group + r) * n + col;
                let c = ((w[idx] - mn) / s).round().clamp(0.0, levels);
                codes[idx] = c as u32;
            }
        }
    }
    (codes, QuantStats { scale, minv, groups, n })
}

/// Dequantize codes back to f32 (simulated-quantization path).
pub fn dequantize(codes: &[u32], stats: &QuantStats, k: usize, n: usize, group: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    for row in 0..k {
        let gi = row / group;
        let srow = &stats.scale[gi * n..(gi + 1) * n];
        let mrow = &stats.minv[gi * n..(gi + 1) * n];
        for col in 0..n {
            out[row * n + col] = codes[row * n + col] as f32 * srow[col] + mrow[col];
        }
    }
    out
}

/// Pack codes into bit planes: u32[bits][K/32][N].
pub fn pack_planes(codes: &[u32], k: usize, n: usize, bits: u8) -> Vec<u32> {
    assert!(k % 32 == 0, "K={k} not divisible by 32");
    let kw = k / 32;
    let mut planes = vec![0u32; bits as usize * kw * n];
    for j in 0..bits as usize {
        let plane = &mut planes[j * kw * n..(j + 1) * kw * n];
        for word in 0..kw {
            for col in 0..n {
                let mut acc = 0u32;
                for bit in 0..32 {
                    let c = codes[(word * 32 + bit) * n + col];
                    acc |= ((c >> j) & 1) << bit;
                }
                plane[word * n + col] = acc;
            }
        }
    }
    planes
}

/// Inverse of [`pack_planes`].
pub fn unpack_planes(planes: &[u32], k: usize, n: usize, bits: u8) -> Vec<u32> {
    let kw = k / 32;
    let mut codes = vec![0u32; k * n];
    for j in 0..bits as usize {
        let plane = &planes[j * kw * n..(j + 1) * kw * n];
        for word in 0..kw {
            for col in 0..n {
                let w = plane[word * n + col];
                for bit in 0..32 {
                    codes[(word * 32 + bit) * n + col] |= ((w >> bit) & 1) << j;
                }
            }
        }
    }
    codes
}

/// One-call quantize + pack (deployment format).
pub fn pack_weight(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> PackedWeight {
    let (codes, stats) = quantize_group(w, k, n, group, bits);
    let planes = pack_planes(&codes, k, n, bits);
    PackedWeight::new(bits, k, n, group, planes, stats)
}

/// Pack an already-quantized tensor against its *native* grid.
///
/// `q` must lie on the affine grid described by `stats` (every value of
/// the form `code * scale + minv`); codes are recovered exactly by
/// rounding, so the packed archive reproduces the quantizer's output
/// bit-for-bit. This is how GPTQ results are captured without the lossy
/// RTN re-grid that [`pack_weight`] would apply.
pub fn pack_weight_with_grid(
    q: &[f32],
    stats: &QuantStats,
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> PackedWeight {
    assert_eq!(q.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    assert_eq!(stats.groups, k / group);
    assert_eq!(stats.n, n);
    let levels = ((1u32 << bits) - 1) as f32;
    let mut codes = vec![0u32; k * n];
    for row in 0..k {
        let gi = row / group;
        let srow = &stats.scale[gi * n..(gi + 1) * n];
        let mrow = &stats.minv[gi * n..(gi + 1) * n];
        for col in 0..n {
            let c = ((q[row * n + col] - mrow[col]) / srow[col]).round().clamp(0.0, levels);
            codes[row * n + col] = c as u32;
        }
    }
    let planes = pack_planes(&codes, k, n, bits);
    PackedWeight::new(bits, k, n, group, planes, stats.clone())
}

/// Extract the top-ε high-impact input features of `w` (K x N row-major)
/// into an fp16 sidecar, **zeroing them in `w`** so the dense grid
/// spends no bit budget on them (and its per-group ranges tighten).
/// Scores come from [`super::saliency::column_scores`] (squared column
/// magnitude × calibration activation energy) with deterministic
/// tie-breaking; `eps <= 0` — or an empty selection — returns `None` and
/// leaves `w` untouched, the ε=0 archive-compatibility contract.
pub fn extract_outliers(
    w: &mut [f32],
    k: usize,
    n: usize,
    eps: f64,
    act_energy: Option<&[f32]>,
) -> Option<OutlierSide> {
    let count = super::saliency::outlier_count(k, eps);
    if count == 0 {
        return None;
    }
    let scores = super::saliency::column_scores(w, k, n, act_energy);
    let cols = super::saliency::top_columns(&scores, count);
    let mut vals = Vec::with_capacity(cols.len() * n);
    for &c in &cols {
        let row = &mut w[c as usize * n..(c as usize + 1) * n];
        for v in row.iter_mut() {
            vals.push(f16_round(*v));
            *v = 0.0;
        }
    }
    Some(OutlierSide { cols, vals })
}

/// One-call outlier-aware quantize + pack: extract the ε sidecar, RTN
/// the zeroed remainder on the dense grid, attach the sidecar. `eps = 0`
/// is exactly [`pack_weight`] (bit-identical planes, no sidecar).
pub fn pack_weight_outlier(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    eps: f64,
    act_energy: Option<&[f32]>,
) -> PackedWeight {
    let mut dense = w.to_vec();
    let side = extract_outliers(&mut dense, k, n, eps, act_energy);
    let pw = pack_weight(&dense, k, n, group, bits);
    match side {
        Some(s) => pw.with_outliers(s),
        None => pw,
    }
}

/// Quantize-dequantize round trip (what table evals feed fwd_nll).
pub fn quant_dequant(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> Vec<f32> {
    let (codes, stats) = quantize_group(w, k, n, group, bits);
    dequantize(&codes, &stats, k, n, group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{draw, forall};

    #[test]
    fn pack_unpack_roundtrip() {
        forall(
            "unpack(pack(c)) == c",
            25,
            101,
            |rng| {
                let k = 32 * (1 + rng.below(4));
                let n = 1 + rng.below(40);
                let bits = [2u8, 3, 4][rng.below(3)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, bits, codes)
            },
            |(k, n, bits, codes)| {
                let planes = pack_planes(codes, *k, *n, *bits);
                if unpack_planes(&planes, *k, *n, *bits) == *codes {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn interleave_roundtrip_both_lane_kinds() {
        forall(
            "deinterleave(interleave(c)) == c",
            30,
            107,
            |rng| {
                let g = [32usize, 64, 33][rng.below(3)]; // 33 forces byte lanes
                let k = g * (1 + rng.below(3));
                let n = 1 + rng.below(24);
                let bits = [2u8, 3, 4, 5, 8][rng.below(5)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, g, bits, codes)
            },
            |(k, n, g, bits, codes)| {
                let lanes = interleave_codes(codes, *k, *n, *g, *bits);
                let expect_len = (*k / *g) * *n * lane_len(*bits, *g);
                if lanes.len() != expect_len {
                    return Err(format!("lane len {} != {expect_len}", lanes.len()));
                }
                if deinterleave_codes(&lanes, *k, *n, *g, *bits) == *codes {
                    Ok(())
                } else {
                    Err("code mismatch".into())
                }
            },
        );
    }

    #[test]
    fn plane_interleave_converters_lossless() {
        forall(
            "interleaved_to_planes(planes_to_interleaved(p)) == p",
            20,
            109,
            |rng| {
                let g = [32usize, 64][rng.below(2)];
                let k = g * (1 + rng.below(3));
                let n = 1 + rng.below(20);
                let bits = [2u8, 3, 4][rng.below(3)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, g, bits, pack_planes(&codes, k, n, bits))
            },
            |(k, n, g, bits, planes)| {
                let lanes = planes_to_interleaved(planes, *k, *n, *g, *bits);
                if interleaved_to_planes(&lanes, *k, *n, *g, *bits) == *planes {
                    Ok(())
                } else {
                    Err("plane mismatch".into())
                }
            },
        );
    }

    #[test]
    fn packed_weight_lane_cache_matches_planes() {
        let mut rng = crate::util::Rng::new(31);
        let (k, n, g) = (128usize, 40usize, 64usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        for bits in [2u8, 3, 4] {
            let pw = pack_weight(&w, k, n, g, bits);
            assert!(pw.nibble_lanes());
            assert_eq!(pw.lane_len(), g / 2);
            let lanes = pw.interleaved().to_vec();
            // Cache is stable and lossless back to the interchange planes.
            assert_eq!(pw.interleaved(), lanes.as_slice());
            assert_eq!(interleaved_to_planes(&lanes, k, n, g, bits), pw.planes);
        }
    }

    /// Byte lanes (bits 5–8, and odd groups at any bit-width) roundtrip
    /// losslessly and the lane-length accounting matches — the layout the
    /// byte-lane LUT GEMV streams.
    #[test]
    fn byte_lane_roundtrip_high_bits() {
        let mut rng = crate::util::Rng::new(77);
        for (g, bits) in [(32usize, 5u8), (64, 6), (32, 7), (64, 8), (33, 3), (33, 8)] {
            let k = g * 3;
            let n = 17;
            let codes: Vec<u32> =
                (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
            assert!(!nibble_lanes(bits, g), "g{g} b{bits} must take byte lanes");
            assert_eq!(lane_len(bits, g), g);
            let lanes = interleave_codes(&codes, k, n, g, bits);
            assert_eq!(lanes.len(), (k / g) * n * g);
            assert_eq!(deinterleave_codes(&lanes, k, n, g, bits), codes);
        }
    }

    /// Content-validity predicate for untrusted lane images, per layout.
    #[test]
    fn lane_code_range_check() {
        assert!(lanes_codes_in_range(&[0x33, 0x00], 2, 32)); // both nibbles <= 3
        assert!(!lanes_codes_in_range(&[0x40], 2, 32)); // high nibble = 4
        assert!(!lanes_codes_in_range(&[0x04], 2, 32)); // low nibble = 4
        assert!(lanes_codes_in_range(&[0xFF], 4, 32)); // 4-bit: all patterns valid
        assert!(lanes_codes_in_range(&[31, 0], 5, 32));
        assert!(!lanes_codes_in_range(&[32], 5, 32));
        assert!(lanes_codes_in_range(&[255], 8, 32)); // 8-bit: all patterns valid
        assert!(lanes_codes_in_range(&[7], 3, 33)); // odd group: byte lanes
        assert!(!lanes_codes_in_range(&[8], 3, 33));
    }

    /// K-tail regression (PR 5 audit): the lane converters share
    /// `quantize_group`'s `K % group == 0` contract and must refuse a
    /// ragged tail loudly instead of mis-addressing lanes.
    #[test]
    #[should_panic(expected = "not divisible")]
    fn interleave_rejects_k_tail() {
        let codes = vec![0u32; 40 * 2]; // K=40, group=32: ragged 8-row tail
        interleave_codes(&codes, 40, 2, 32, 2);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn deinterleave_rejects_k_tail() {
        let lanes = vec![0u8; 40];
        deinterleave_codes(&lanes, 40, 2, 32, 2);
    }

    /// `packed_bytes` excludes the lane cache (documented deployment
    /// footprint); `resident_bytes` includes it once built or seeded.
    #[test]
    fn lane_cache_accounting() {
        let mut rng = crate::util::Rng::new(13);
        let (k, n, g) = (64usize, 24usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 3);
        let packed = pw.packed_bytes();
        assert!(!pw.lanes_built());
        assert_eq!(pw.lane_cache_bytes(), 0);
        assert_eq!(pw.resident_bytes(), packed);
        let lane_len_total = pw.interleaved().len();
        assert!(pw.lanes_built());
        assert_eq!(pw.packed_bytes(), packed, "lane build must not change packed_bytes");
        assert_eq!(pw.lane_cache_bytes(), lane_len_total);
        assert_eq!(pw.resident_bytes(), packed + lane_len_total);
    }

    /// `with_lanes` seeds the cache (no conversion later) and validates
    /// the lane-image length.
    #[test]
    fn with_lanes_seeds_cache_and_validates() {
        let mut rng = crate::util::Rng::new(29);
        let (k, n, g, bits) = (64usize, 20usize, 32usize, 5u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let built = pack_weight(&w, k, n, g, bits);
        let lanes = built.interleaved().to_vec();
        let seeded = PackedWeight::with_lanes(
            bits,
            k,
            n,
            g,
            built.planes.clone(),
            built.stats.clone(),
            lanes.clone(),
        )
        .unwrap();
        assert!(seeded.lanes_built(), "seeded weight must not rebuild lanes");
        assert_eq!(seeded.interleaved(), lanes.as_slice());
        let bad = PackedWeight::with_lanes(
            bits,
            k,
            n,
            g,
            built.planes.clone(),
            built.stats.clone(),
            vec![0u8; 3],
        );
        assert!(bad.is_err(), "wrong lane length must be refused");
    }

    #[test]
    fn quantize_error_bounded_by_half_scale() {
        forall(
            "|w - dq(q(w))| <= scale/2",
            20,
            103,
            |rng| {
                let k = draw::dims(rng, 32, 128, 32);
                let n = 1 + rng.below(24);
                let w = draw::vec_f32(rng, k * n, 1.5);
                (k, n, w)
            },
            |(k, n, w)| {
                let group = 32;
                let (codes, stats) = quantize_group(w, *k, *n, group, 3);
                let dq = dequantize(&codes, &stats, *k, *n, group);
                for row in 0..*k {
                    let gi = row / group;
                    for col in 0..*n {
                        let err = (dq[row * n + col] - w[row * n + col]).abs();
                        let s = stats.scale[gi * n + col];
                        if err > s / 2.0 + 1e-5 {
                            return Err(format!("err {err} > scale/2 {}", s / 2.0));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = crate::util::Rng::new(7);
        let (k, n) = (64, 48);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let errs: Vec<f64> = [2u8, 3, 4]
            .iter()
            .map(|&b| {
                let dq = quant_dequant(&w, k, n, 32, b);
                w.iter().zip(&dq).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / w.len() as f64
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn packed_bytes_reflect_bits() {
        let mut rng = crate::util::Rng::new(9);
        let (k, n) = (128, 64);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let p2 = pack_weight(&w, k, n, 64, 2);
        let p4 = pack_weight(&w, k, n, 64, 4);
        assert_eq!(p4.planes.len(), 2 * p2.planes.len());
        assert!((p2.packed_bytes() as f64) < 0.25 * p2.fp16_bytes() as f64);
    }

    #[test]
    fn codes_in_range() {
        let mut rng = crate::util::Rng::new(11);
        let w: Vec<f32> = (0..64 * 8).map(|_| rng.normal_f32() * 10.0).collect();
        for bits in [2u8, 3, 4] {
            let (codes, _) = quantize_group(&w, 64, 8, 32, bits);
            assert!(codes.iter().all(|&c| c < (1 << bits)));
        }
    }

    /// Native-grid packing reproduces a quantizer's output exactly where
    /// the RTN re-grid of [`pack_weight`] is lossy: codes {0,1,2} at
    /// 2 bits span only 2/3 of the RTN range, so re-gridding moves the
    /// interior point off its original value.
    #[test]
    fn native_grid_packing_roundtrips_exactly() {
        let (k, n, g, bits) = (32usize, 1usize, 32usize, 2u8);
        let stats = QuantStats { scale: vec![0.5], minv: vec![0.0], groups: 1, n: 1 };
        // Grid points for codes 0/1/2 (code 3 deliberately unused).
        let q: Vec<f32> = (0..k).map(|r| 0.5 * (r % 3) as f32).collect();

        let native = pack_weight_with_grid(&q, &stats, k, n, g, bits);
        let codes = unpack_planes(&native.planes, k, n, bits);
        let dq = dequantize(&codes, &native.stats, k, n, g);
        for (a, b) in q.iter().zip(&dq) {
            assert_eq!(a.to_bits(), b.to_bits(), "native grid must be bit-exact");
        }

        let regrid = pack_weight(&q, k, n, g, bits);
        let rcodes = unpack_planes(&regrid.planes, k, n, bits);
        let rdq = dequantize(&rcodes, &regrid.stats, k, n, g);
        assert!(
            q.iter().zip(&rdq).any(|(a, b)| a != b),
            "RTN re-grid should be lossy on this fixture; native packing must beat it"
        );
    }

    /// `act` metadata: absent by default, attached by the builder, and
    /// carried through clones (the archive reader's path).
    #[test]
    fn with_act_attaches_metadata() {
        let mut rng = crate::util::Rng::new(41);
        let (k, n, g) = (64usize, 12usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 4);
        assert!(pw.act.is_none(), "no act metadata unless calibrated");
        let aq = ActQuant::dynamic(&w[..k]);
        let with = pw.with_act(aq);
        assert_eq!(with.act, Some(aq));
        assert_eq!(with.clone().act, Some(aq));
    }

    /// Golden IEEE 754 binary16 encodings for the hand-written converter
    /// (mirrored by the Python oracle, which uses numpy float16).
    #[test]
    fn f16_conversion_goldens() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // max finite
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds to +inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001); // min subnormal
        assert_eq!(f32_to_f16_bits(2f32.powi(-14)), 0x0400); // min normal
        assert_eq!(f32_to_f16_bits(2f32.powi(-26)), 0x0000); // underflow
        // Round-to-nearest-even: 1 + 2^-11 is halfway, ties to even (1.0);
        // 1 + 3*2^-11 ties up to 1 + 2^-9.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), 0x3C00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)), 0x3C02);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
        // Decode side: exact values, and every encoding roundtrips.
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x0001), 2f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x0400), 2f32.powi(-14));
        assert_eq!(f16_bits_to_f32(0xFBFF), -65504.0);
        for h in (0u16..=0xFFFF).step_by(7) {
            let v = f16_bits_to_f32(h);
            if v.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(v), h, "h={h:#06x} v={v}");
        }
        // Idempotence: rounding an already-representable value is exact.
        let mut rng = crate::util::Rng::new(17);
        for _ in 0..200 {
            let v = f16_round(rng.normal_f32() * 30.0);
            assert_eq!(f16_round(v).to_bits(), v.to_bits());
        }
    }

    /// Outlier roundtrip (tentpole contract): extraction zeroes the dense
    /// rows, and `dequantized()` re-inserts the fp16 sidecar **exactly**
    /// for every extracted column.
    #[test]
    fn outlier_roundtrip_exact_for_extracted_columns() {
        let mut rng = crate::util::Rng::new(23);
        let (k, n, g, bits) = (128usize, 24usize, 32usize, 2u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let eps = 0.05; // ceil(6.4) = 7 columns
        let pw = pack_weight_outlier(&w, k, n, g, bits, eps, None);
        let side = pw.outliers.as_ref().expect("eps>0 must extract");
        assert_eq!(side.n_cols(), 7);
        assert!(side.validate(k, n));
        let dq = pw.dequantized();
        for (i, &c) in side.cols.iter().enumerate() {
            for col in 0..n {
                let orig = f16_round(w[c as usize * n + col]);
                let got = dq[c as usize * n + col];
                assert_eq!(
                    orig.to_bits(),
                    got.to_bits(),
                    "extracted ({c},{col}) must roundtrip exactly"
                );
                assert_eq!(side.vals[i * n + col].to_bits(), orig.to_bits());
            }
        }
    }

    /// ε=0 is the dense path, bit for bit: same planes, same grid, no
    /// sidecar (the archive byte-compatibility contract rests on this).
    #[test]
    fn eps_zero_is_bit_identical_to_dense_packing() {
        let mut rng = crate::util::Rng::new(37);
        let (k, n, g, bits) = (64usize, 16usize, 32usize, 3u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let dense = pack_weight(&w, k, n, g, bits);
        let zero = pack_weight_outlier(&w, k, n, g, bits, 0.0, None);
        assert!(zero.outliers.is_none());
        assert_eq!(zero.planes, dense.planes);
        assert_eq!(zero.stats.scale, dense.stats.scale);
        assert_eq!(zero.stats.minv, dense.stats.minv);
        assert_eq!(zero.packed_bytes(), dense.packed_bytes());
    }

    /// Pinned acceptance criterion: at ε=1%, a 2-bit outlier-packed
    /// linear reconstructs with strictly lower Frobenius error than dense
    /// 2-bit RTN on the same weights.
    #[test]
    fn outlier_packing_beats_dense_rtn_frobenius_at_2bit() {
        let mut rng = crate::util::Rng::new(43);
        let (k, n, g) = (512usize, 64usize, 32usize);
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.05).collect();
        // Outlier-dominated rows — the distribution shape sub-2-bit grids
        // cliff on and the sidecar is built to absorb.
        for &row in &[3usize, 97, 200, 301, 418] {
            for col in 0..n {
                w[row * n + col] *= 25.0;
            }
        }
        let frob = |pw: &PackedWeight| -> f64 {
            let dq = pw.dequantized();
            w.iter().zip(&dq).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
        };
        let dense = frob(&pack_weight(&w, k, n, g, 2));
        let with_out = frob(&pack_weight_outlier(&w, k, n, g, 2, 0.01, None));
        assert!(
            with_out < dense,
            "eps=1% must strictly beat dense 2-bit RTN: outlier={with_out} dense={dense}"
        );
    }

    /// Calibration activation energy steers the selection: with weights
    /// tied, the column whose activations carry energy wins.
    #[test]
    fn extraction_follows_activation_energy() {
        let (k, n) = (32usize, 4usize);
        let w = vec![1.0f32; k * n]; // all columns tied on magnitude
        let mut energy = vec![1.0f32; k];
        energy[20] = 100.0;
        let mut dense = w.clone();
        let side = extract_outliers(&mut dense, k, n, 1.0 / k as f64, Some(&energy)).unwrap();
        assert_eq!(side.cols, vec![20]);
        assert!(dense[20 * n..21 * n].iter().all(|&v| v == 0.0));
        // Without energy the tie breaks deterministically to column 0.
        let mut dense2 = w.clone();
        let side2 = extract_outliers(&mut dense2, k, n, 1.0 / k as f64, None).unwrap();
        assert_eq!(side2.cols, vec![0]);
    }

    /// Sidecar accounting: `packed_bytes` (deployment footprint) includes
    /// the u32 index + N fp16 values per extracted column.
    #[test]
    fn outlier_bytes_counted_in_packed_bytes() {
        let mut rng = crate::util::Rng::new(47);
        let (k, n, g, bits) = (64usize, 16usize, 32usize, 2u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let dense = pack_weight(&w, k, n, g, bits);
        let pw = pack_weight_outlier(&w, k, n, g, bits, 2.0 / k as f64, None);
        assert_eq!(pw.outlier_cols(), 2);
        assert_eq!(pw.outlier_bytes(), 2 * 4 + 2 * n * 2);
        assert_eq!(pw.packed_bytes(), dense.packed_bytes() + pw.outlier_bytes());
    }

    /// Structural validation rejects the malformed sidecars the archive
    /// reader must degrade on.
    #[test]
    fn outlier_side_validation() {
        let ok = OutlierSide { cols: vec![1, 5], vals: vec![1.0; 8] };
        assert!(ok.validate(8, 4));
        let unsorted = OutlierSide { cols: vec![5, 1], vals: vec![1.0; 8] };
        assert!(!unsorted.validate(8, 4));
        let dup = OutlierSide { cols: vec![5, 5], vals: vec![1.0; 8] };
        assert!(!dup.validate(8, 4));
        let oob = OutlierSide { cols: vec![1, 8], vals: vec![1.0; 8] };
        assert!(!oob.validate(8, 4));
        let short = OutlierSide { cols: vec![1, 5], vals: vec![1.0; 7] };
        assert!(!short.validate(8, 4));
        let inf = OutlierSide { cols: vec![1], vals: vec![f32::INFINITY; 4] };
        assert!(!inf.validate(8, 4));
        let empty = OutlierSide { cols: vec![], vals: vec![] };
        assert!(empty.validate(8, 4));
    }

    #[test]
    fn matches_python_oracle_format() {
        // Golden check of the plane layout: code 0b101 at k=0 must set bit 0
        // of planes 0 and 2, word 0.
        let k = 32;
        let n = 1;
        let mut codes = vec![0u32; k];
        codes[0] = 0b101;
        codes[5] = 0b011;
        let planes = pack_planes(&codes, k, n, 3);
        let kw = 1;
        assert_eq!(planes[0 * kw + 0] & 1, 1); // plane 0, bit k=0
        assert_eq!((planes[0] >> 5) & 1, 1); // plane 0, bit k=5
        assert_eq!(planes[1 * kw * n] & 1, 0); // plane 1, k=0
        assert_eq!((planes[1 * kw * n] >> 5) & 1, 1); // plane 1, k=5
        assert_eq!(planes[2 * kw * n] & 1, 1); // plane 2, k=0
    }
}
