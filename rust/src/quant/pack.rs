//! Quantization primitives: group-wise asymmetric uniform quantization and
//! u32 bit-plane packing (byte-identical to the Pallas kernel format).
//!
//! Layout for W (K x N, row-major, K = input dim):
//! * codes `c[k][n] in [0, 2^b - 1]`, `W ≈ c * scale + minv`
//! * `scale`/`minv`: `[K/g][N]` per (group, output-channel)
//! * planes: `u32[b][K/32][N]`; bit `k % 32` of `plane[j][k/32][n]` is bit
//!   `j` of `c[k][n]`.
//!
//! Two code layouts share the same quantization grid:
//!
//! * **Bit planes** (above) — the interchange/reference layout, shared
//!   byte-for-byte with the Pallas kernels and the `.lieq` deployment
//!   format. Decoding a weight reassembles its code bit-by-bit from
//!   `bits` separate plane words.
//! * **Interleaved lanes** — a derived acceleration layout for the LUT
//!   CPU kernels: per (group, column), the group's codes are stored as
//!   one contiguous byte lane. For `bits <= 4` with an even group size a
//!   lane packs two codes per byte (nibble lanes, low nibble = earlier
//!   row); otherwise one code per byte. Sequential lane reads replace
//!   per-weight bit reassembly in the GEMV inner loop.
//!
//! [`interleave_codes`] / [`deinterleave_codes`] and the plane-level
//! wrappers [`planes_to_interleaved`] / [`interleaved_to_planes`] are
//! lossless in both directions; `rust/src/quant/pack.rs` tests pin the
//! roundtrip for every supported bit-width and both lane kinds.

use std::sync::OnceLock;

use super::act::ActQuant;

/// Per-group affine stats.
#[derive(Clone, Debug)]
pub struct QuantStats {
    pub scale: Vec<f32>, // [K/g * N]
    pub minv: Vec<f32>,  // [K/g * N]
    pub groups: usize,
    pub n: usize,
}

/// A fully packed quantized weight (deployment format).
#[derive(Clone, Debug)]
pub struct PackedWeight {
    pub bits: u8,
    pub k: usize,
    pub n: usize,
    pub group_size: usize,
    /// u32[bits][K/32][N], flattened.
    pub planes: Vec<u32>,
    pub stats: QuantStats,
    /// Calibrated INT8 activation-quantization parameters for this
    /// linear's *input* (the W·A8 kernel path), when calibration ran.
    /// Persisted as a `.lieq` v3 side entry; `None` means the A8 kernel
    /// falls back to per-row dynamic quantization.
    pub act: Option<ActQuant>,
    /// Lazily-built interleaved lane image of `planes` (see module docs).
    /// Derived, never serialized; built on first LUT-kernel use.
    lanes: OnceLock<Vec<u8>>,
}

impl PackedWeight {
    pub fn new(
        bits: u8,
        k: usize,
        n: usize,
        group_size: usize,
        planes: Vec<u32>,
        stats: QuantStats,
    ) -> PackedWeight {
        PackedWeight { bits, k, n, group_size, planes, stats, act: None, lanes: OnceLock::new() }
    }

    /// Attach calibrated activation-quantization parameters (builder
    /// style; the quantization pipeline and the archive reader use this).
    pub fn with_act(mut self, act: ActQuant) -> PackedWeight {
        self.act = Some(act);
        self
    }

    /// Rehydrate a packed weight *with* a prebuilt interleaved lane image
    /// (the `.lieq` v2 archive read path): the lane cache is seeded, so
    /// later [`PackedWeight::interleaved`] calls perform no
    /// `planes_to_interleaved` conversion (and bump no `lane_builds`
    /// counter). Errs when `lanes` has the wrong length for the layout —
    /// callers with an unverifiable lane section should drop it and fall
    /// back to [`PackedWeight::new`] (on-demand conversion) instead.
    pub fn with_lanes(
        bits: u8,
        k: usize,
        n: usize,
        group_size: usize,
        planes: Vec<u32>,
        stats: QuantStats,
        lanes: Vec<u8>,
    ) -> anyhow::Result<PackedWeight> {
        let expect = (k / group_size) * n * lane_len(bits, group_size);
        anyhow::ensure!(
            lanes.len() == expect,
            "lane image length {} != expected {expect} (b{bits} k{k} n{n} g{group_size})",
            lanes.len()
        );
        let pw = PackedWeight::new(bits, k, n, group_size, planes, stats);
        pw.lanes.set(lanes).expect("fresh OnceLock");
        Ok(pw)
    }

    /// Packed size in bytes (planes + stats) — the *deployment* memory
    /// footprint, i.e. what ships in a `.lieq` archive's mandatory
    /// sections and what the compression-ratio ledgers compare against
    /// fp16. The interleaved lane cache is a derived acceleration
    /// structure (redundant with the planes) and is deliberately **not**
    /// counted here; use [`PackedWeight::resident_bytes`] for the
    /// in-memory total including a built lane image.
    pub fn packed_bytes(&self) -> usize {
        self.planes.len() * 4 + self.stats.scale.len() * 8
    }

    /// Bytes currently held by the lane cache (0 until the first
    /// LUT/panel use builds it, or a v2 archive seeds it).
    pub fn lane_cache_bytes(&self) -> usize {
        self.lanes.get().map_or(0, |l| l.len())
    }

    /// Resident in-memory size: [`PackedWeight::packed_bytes`] plus the
    /// lane cache when built.
    pub fn resident_bytes(&self) -> usize {
        self.packed_bytes() + self.lane_cache_bytes()
    }

    /// True when the interleaved lane image is resident (built or
    /// seeded) — i.e. the next [`PackedWeight::interleaved`] is free.
    pub fn lanes_built(&self) -> bool {
        self.lanes.get().is_some()
    }

    pub fn fp16_bytes(&self) -> usize {
        self.k * self.n * 2
    }

    /// Decode back to simulated-dequantized f32 (`K x N` row-major) —
    /// what the artifact-backed scoring path consumes when serving a
    /// packed archive.
    pub fn dequantized(&self) -> Vec<f32> {
        let codes = unpack_planes(&self.planes, self.k, self.n, self.bits);
        dequantize(&codes, &self.stats, self.k, self.n, self.group_size)
    }

    /// Interleaved code lanes, converted from the bit planes on first use
    /// and cached (thread-safe; the conversion is deterministic so a
    /// duplicate race-time build is identical). Each conversion that
    /// actually runs is counted in `kernels::kernel_path_stats()` as a
    /// `lane_builds` tick — zero on cache hits and on lane images seeded
    /// from a `.lieq` v2 archive.
    pub fn interleaved(&self) -> &[u8] {
        self.lanes.get_or_init(|| {
            crate::kernels::stats::record_lane_build();
            planes_to_interleaved(&self.planes, self.k, self.n, self.group_size, self.bits)
        })
    }

    /// Bytes per (group, column) lane in the interleaved layout.
    pub fn lane_len(&self) -> usize {
        lane_len(self.bits, self.group_size)
    }

    /// True when this weight's interleaved layout packs two codes per
    /// byte (nibble lanes) — the layout the LUT GEMV kernel decodes.
    pub fn nibble_lanes(&self) -> bool {
        nibble_lanes(self.bits, self.group_size)
    }
}

/// Nibble lanes (two codes per byte) apply when a code fits a nibble and
/// the group has an even row count; wider codes fall back to byte lanes.
pub fn nibble_lanes(bits: u8, group: usize) -> bool {
    bits <= 4 && group % 2 == 0
}

/// Bytes per (group, column) lane in the interleaved layout.
pub fn lane_len(bits: u8, group: usize) -> usize {
    if nibble_lanes(bits, group) {
        group / 2
    } else {
        group
    }
}

/// True when every code in a lane image is `< 2^bits` for its layout —
/// the content-validity check an untrusted (deserialized) lane image
/// must pass before the kernels may index dequant tables with it. Free
/// for 8-bit byte lanes and 4-bit nibble lanes (every byte pattern is a
/// valid code there).
pub fn lanes_codes_in_range(lanes: &[u8], bits: u8, group: usize) -> bool {
    if nibble_lanes(bits, group) {
        if bits == 4 {
            return true;
        }
        let mask = !(((1u8 << bits) - 1) | (((1u8 << bits) - 1) << 4));
        lanes.iter().all(|&b| b & mask == 0)
    } else {
        if bits == 8 {
            return true;
        }
        let limit = 1u8 << bits;
        lanes.iter().all(|&b| b < limit)
    }
}

/// Convert row-major codes (`u32[K*N]`, values < 2^bits) into interleaved
/// lanes: lane `(gi, col)` starts at `(gi * n + col) * lane_len` and holds
/// the group's codes for that column in row order (two per byte for
/// nibble lanes, low nibble first).
///
/// **Contract:** `K % group == 0` (asserted), matching
/// [`quantize_group`] — the whole packed pipeline has no ragged tail
/// group, so the lane layout deliberately doesn't model one either. A
/// K-tail would silently corrupt the `(gi * n + col) * lane_len`
/// addressing, hence the hard assert rather than a truncating loop;
/// `pack.rs` tests pin this for both converters.
pub fn interleave_codes(codes: &[u32], k: usize, n: usize, group: usize, bits: u8) -> Vec<u8> {
    assert_eq!(codes.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let groups = k / group;
    let ll = lane_len(bits, group);
    let mut lanes = vec![0u8; groups * n * ll];
    if nibble_lanes(bits, group) {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for p in 0..ll {
                    let c0 = codes[(gi * group + 2 * p) * n + col] as u8;
                    let c1 = codes[(gi * group + 2 * p + 1) * n + col] as u8;
                    lanes[base + p] = (c0 & 0xF) | (c1 << 4);
                }
            }
        }
    } else {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for r in 0..group {
                    lanes[base + r] = codes[(gi * group + r) * n + col] as u8;
                }
            }
        }
    }
    lanes
}

/// Inverse of [`interleave_codes`] (lossless for codes < 2^bits).
pub fn deinterleave_codes(lanes: &[u8], k: usize, n: usize, group: usize, bits: u8) -> Vec<u32> {
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let groups = k / group;
    let ll = lane_len(bits, group);
    assert_eq!(lanes.len(), groups * n * ll);
    let mut codes = vec![0u32; k * n];
    if nibble_lanes(bits, group) {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for p in 0..ll {
                    let b = lanes[base + p];
                    codes[(gi * group + 2 * p) * n + col] = (b & 0xF) as u32;
                    codes[(gi * group + 2 * p + 1) * n + col] = (b >> 4) as u32;
                }
            }
        }
    } else {
        for gi in 0..groups {
            for col in 0..n {
                let base = (gi * n + col) * ll;
                for r in 0..group {
                    codes[(gi * group + r) * n + col] = lanes[base + r] as u32;
                }
            }
        }
    }
    codes
}

/// Bit planes -> interleaved lanes (the planes stay the interchange
/// format; this derives the LUT-kernel acceleration layout).
pub fn planes_to_interleaved(
    planes: &[u32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> Vec<u8> {
    interleave_codes(&unpack_planes(planes, k, n, bits), k, n, group, bits)
}

/// Interleaved lanes -> bit planes (lossless inverse of
/// [`planes_to_interleaved`]).
pub fn interleaved_to_planes(
    lanes: &[u8],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> Vec<u32> {
    pack_planes(&deinterleave_codes(lanes, k, n, group, bits), k, n, bits)
}

/// Group-wise asymmetric uniform quantization of `w` (K x N row-major).
/// Returns (codes u32[K*N], stats).
pub fn quantize_group(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> (Vec<u32>, QuantStats) {
    assert_eq!(w.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    let levels = ((1u32 << bits) - 1) as f32;
    let groups = k / group;
    let mut scale = vec![0f32; groups * n];
    let mut minv = vec![0f32; groups * n];
    let mut codes = vec![0u32; k * n];

    for gi in 0..groups {
        for col in 0..n {
            let mut mx = f32::NEG_INFINITY;
            let mut mn = f32::INFINITY;
            for r in 0..group {
                let v = w[(gi * group + r) * n + col];
                mx = mx.max(v);
                mn = mn.min(v);
            }
            let s = ((mx - mn) / levels).max(1e-8);
            scale[gi * n + col] = s;
            minv[gi * n + col] = mn;
            for r in 0..group {
                let idx = (gi * group + r) * n + col;
                let c = ((w[idx] - mn) / s).round().clamp(0.0, levels);
                codes[idx] = c as u32;
            }
        }
    }
    (codes, QuantStats { scale, minv, groups, n })
}

/// Dequantize codes back to f32 (simulated-quantization path).
pub fn dequantize(codes: &[u32], stats: &QuantStats, k: usize, n: usize, group: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    for row in 0..k {
        let gi = row / group;
        let srow = &stats.scale[gi * n..(gi + 1) * n];
        let mrow = &stats.minv[gi * n..(gi + 1) * n];
        for col in 0..n {
            out[row * n + col] = codes[row * n + col] as f32 * srow[col] + mrow[col];
        }
    }
    out
}

/// Pack codes into bit planes: u32[bits][K/32][N].
pub fn pack_planes(codes: &[u32], k: usize, n: usize, bits: u8) -> Vec<u32> {
    assert!(k % 32 == 0, "K={k} not divisible by 32");
    let kw = k / 32;
    let mut planes = vec![0u32; bits as usize * kw * n];
    for j in 0..bits as usize {
        let plane = &mut planes[j * kw * n..(j + 1) * kw * n];
        for word in 0..kw {
            for col in 0..n {
                let mut acc = 0u32;
                for bit in 0..32 {
                    let c = codes[(word * 32 + bit) * n + col];
                    acc |= ((c >> j) & 1) << bit;
                }
                plane[word * n + col] = acc;
            }
        }
    }
    planes
}

/// Inverse of [`pack_planes`].
pub fn unpack_planes(planes: &[u32], k: usize, n: usize, bits: u8) -> Vec<u32> {
    let kw = k / 32;
    let mut codes = vec![0u32; k * n];
    for j in 0..bits as usize {
        let plane = &planes[j * kw * n..(j + 1) * kw * n];
        for word in 0..kw {
            for col in 0..n {
                let w = plane[word * n + col];
                for bit in 0..32 {
                    codes[(word * 32 + bit) * n + col] |= ((w >> bit) & 1) << j;
                }
            }
        }
    }
    codes
}

/// One-call quantize + pack (deployment format).
pub fn pack_weight(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> PackedWeight {
    let (codes, stats) = quantize_group(w, k, n, group, bits);
    let planes = pack_planes(&codes, k, n, bits);
    PackedWeight::new(bits, k, n, group, planes, stats)
}

/// Pack an already-quantized tensor against its *native* grid.
///
/// `q` must lie on the affine grid described by `stats` (every value of
/// the form `code * scale + minv`); codes are recovered exactly by
/// rounding, so the packed archive reproduces the quantizer's output
/// bit-for-bit. This is how GPTQ results are captured without the lossy
/// RTN re-grid that [`pack_weight`] would apply.
pub fn pack_weight_with_grid(
    q: &[f32],
    stats: &QuantStats,
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
) -> PackedWeight {
    assert_eq!(q.len(), k * n);
    assert!(k % group == 0, "K={k} not divisible by group={group}");
    assert_eq!(stats.groups, k / group);
    assert_eq!(stats.n, n);
    let levels = ((1u32 << bits) - 1) as f32;
    let mut codes = vec![0u32; k * n];
    for row in 0..k {
        let gi = row / group;
        let srow = &stats.scale[gi * n..(gi + 1) * n];
        let mrow = &stats.minv[gi * n..(gi + 1) * n];
        for col in 0..n {
            let c = ((q[row * n + col] - mrow[col]) / srow[col]).round().clamp(0.0, levels);
            codes[row * n + col] = c as u32;
        }
    }
    let planes = pack_planes(&codes, k, n, bits);
    PackedWeight::new(bits, k, n, group, planes, stats.clone())
}

/// Quantize-dequantize round trip (what table evals feed fwd_nll).
pub fn quant_dequant(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> Vec<f32> {
    let (codes, stats) = quantize_group(w, k, n, group, bits);
    dequantize(&codes, &stats, k, n, group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{draw, forall};

    #[test]
    fn pack_unpack_roundtrip() {
        forall(
            "unpack(pack(c)) == c",
            25,
            101,
            |rng| {
                let k = 32 * (1 + rng.below(4));
                let n = 1 + rng.below(40);
                let bits = [2u8, 3, 4][rng.below(3)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, bits, codes)
            },
            |(k, n, bits, codes)| {
                let planes = pack_planes(codes, *k, *n, *bits);
                if unpack_planes(&planes, *k, *n, *bits) == *codes {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    fn interleave_roundtrip_both_lane_kinds() {
        forall(
            "deinterleave(interleave(c)) == c",
            30,
            107,
            |rng| {
                let g = [32usize, 64, 33][rng.below(3)]; // 33 forces byte lanes
                let k = g * (1 + rng.below(3));
                let n = 1 + rng.below(24);
                let bits = [2u8, 3, 4, 5, 8][rng.below(5)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, g, bits, codes)
            },
            |(k, n, g, bits, codes)| {
                let lanes = interleave_codes(codes, *k, *n, *g, *bits);
                let expect_len = (*k / *g) * *n * lane_len(*bits, *g);
                if lanes.len() != expect_len {
                    return Err(format!("lane len {} != {expect_len}", lanes.len()));
                }
                if deinterleave_codes(&lanes, *k, *n, *g, *bits) == *codes {
                    Ok(())
                } else {
                    Err("code mismatch".into())
                }
            },
        );
    }

    #[test]
    fn plane_interleave_converters_lossless() {
        forall(
            "interleaved_to_planes(planes_to_interleaved(p)) == p",
            20,
            109,
            |rng| {
                let g = [32usize, 64][rng.below(2)];
                let k = g * (1 + rng.below(3));
                let n = 1 + rng.below(20);
                let bits = [2u8, 3, 4][rng.below(3)];
                let codes: Vec<u32> =
                    (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
                (k, n, g, bits, pack_planes(&codes, k, n, bits))
            },
            |(k, n, g, bits, planes)| {
                let lanes = planes_to_interleaved(planes, *k, *n, *g, *bits);
                if interleaved_to_planes(&lanes, *k, *n, *g, *bits) == *planes {
                    Ok(())
                } else {
                    Err("plane mismatch".into())
                }
            },
        );
    }

    #[test]
    fn packed_weight_lane_cache_matches_planes() {
        let mut rng = crate::util::Rng::new(31);
        let (k, n, g) = (128usize, 40usize, 64usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        for bits in [2u8, 3, 4] {
            let pw = pack_weight(&w, k, n, g, bits);
            assert!(pw.nibble_lanes());
            assert_eq!(pw.lane_len(), g / 2);
            let lanes = pw.interleaved().to_vec();
            // Cache is stable and lossless back to the interchange planes.
            assert_eq!(pw.interleaved(), lanes.as_slice());
            assert_eq!(interleaved_to_planes(&lanes, k, n, g, bits), pw.planes);
        }
    }

    /// Byte lanes (bits 5–8, and odd groups at any bit-width) roundtrip
    /// losslessly and the lane-length accounting matches — the layout the
    /// byte-lane LUT GEMV streams.
    #[test]
    fn byte_lane_roundtrip_high_bits() {
        let mut rng = crate::util::Rng::new(77);
        for (g, bits) in [(32usize, 5u8), (64, 6), (32, 7), (64, 8), (33, 3), (33, 8)] {
            let k = g * 3;
            let n = 17;
            let codes: Vec<u32> =
                (0..k * n).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
            assert!(!nibble_lanes(bits, g), "g{g} b{bits} must take byte lanes");
            assert_eq!(lane_len(bits, g), g);
            let lanes = interleave_codes(&codes, k, n, g, bits);
            assert_eq!(lanes.len(), (k / g) * n * g);
            assert_eq!(deinterleave_codes(&lanes, k, n, g, bits), codes);
        }
    }

    /// Content-validity predicate for untrusted lane images, per layout.
    #[test]
    fn lane_code_range_check() {
        assert!(lanes_codes_in_range(&[0x33, 0x00], 2, 32)); // both nibbles <= 3
        assert!(!lanes_codes_in_range(&[0x40], 2, 32)); // high nibble = 4
        assert!(!lanes_codes_in_range(&[0x04], 2, 32)); // low nibble = 4
        assert!(lanes_codes_in_range(&[0xFF], 4, 32)); // 4-bit: all patterns valid
        assert!(lanes_codes_in_range(&[31, 0], 5, 32));
        assert!(!lanes_codes_in_range(&[32], 5, 32));
        assert!(lanes_codes_in_range(&[255], 8, 32)); // 8-bit: all patterns valid
        assert!(lanes_codes_in_range(&[7], 3, 33)); // odd group: byte lanes
        assert!(!lanes_codes_in_range(&[8], 3, 33));
    }

    /// K-tail regression (PR 5 audit): the lane converters share
    /// `quantize_group`'s `K % group == 0` contract and must refuse a
    /// ragged tail loudly instead of mis-addressing lanes.
    #[test]
    #[should_panic(expected = "not divisible")]
    fn interleave_rejects_k_tail() {
        let codes = vec![0u32; 40 * 2]; // K=40, group=32: ragged 8-row tail
        interleave_codes(&codes, 40, 2, 32, 2);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn deinterleave_rejects_k_tail() {
        let lanes = vec![0u8; 40];
        deinterleave_codes(&lanes, 40, 2, 32, 2);
    }

    /// `packed_bytes` excludes the lane cache (documented deployment
    /// footprint); `resident_bytes` includes it once built or seeded.
    #[test]
    fn lane_cache_accounting() {
        let mut rng = crate::util::Rng::new(13);
        let (k, n, g) = (64usize, 24usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 3);
        let packed = pw.packed_bytes();
        assert!(!pw.lanes_built());
        assert_eq!(pw.lane_cache_bytes(), 0);
        assert_eq!(pw.resident_bytes(), packed);
        let lane_len_total = pw.interleaved().len();
        assert!(pw.lanes_built());
        assert_eq!(pw.packed_bytes(), packed, "lane build must not change packed_bytes");
        assert_eq!(pw.lane_cache_bytes(), lane_len_total);
        assert_eq!(pw.resident_bytes(), packed + lane_len_total);
    }

    /// `with_lanes` seeds the cache (no conversion later) and validates
    /// the lane-image length.
    #[test]
    fn with_lanes_seeds_cache_and_validates() {
        let mut rng = crate::util::Rng::new(29);
        let (k, n, g, bits) = (64usize, 20usize, 32usize, 5u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let built = pack_weight(&w, k, n, g, bits);
        let lanes = built.interleaved().to_vec();
        let seeded = PackedWeight::with_lanes(
            bits,
            k,
            n,
            g,
            built.planes.clone(),
            built.stats.clone(),
            lanes.clone(),
        )
        .unwrap();
        assert!(seeded.lanes_built(), "seeded weight must not rebuild lanes");
        assert_eq!(seeded.interleaved(), lanes.as_slice());
        let bad = PackedWeight::with_lanes(
            bits,
            k,
            n,
            g,
            built.planes.clone(),
            built.stats.clone(),
            vec![0u8; 3],
        );
        assert!(bad.is_err(), "wrong lane length must be refused");
    }

    #[test]
    fn quantize_error_bounded_by_half_scale() {
        forall(
            "|w - dq(q(w))| <= scale/2",
            20,
            103,
            |rng| {
                let k = draw::dims(rng, 32, 128, 32);
                let n = 1 + rng.below(24);
                let w = draw::vec_f32(rng, k * n, 1.5);
                (k, n, w)
            },
            |(k, n, w)| {
                let group = 32;
                let (codes, stats) = quantize_group(w, *k, *n, group, 3);
                let dq = dequantize(&codes, &stats, *k, *n, group);
                for row in 0..*k {
                    let gi = row / group;
                    for col in 0..*n {
                        let err = (dq[row * n + col] - w[row * n + col]).abs();
                        let s = stats.scale[gi * n + col];
                        if err > s / 2.0 + 1e-5 {
                            return Err(format!("err {err} > scale/2 {}", s / 2.0));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = crate::util::Rng::new(7);
        let (k, n) = (64, 48);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let errs: Vec<f64> = [2u8, 3, 4]
            .iter()
            .map(|&b| {
                let dq = quant_dequant(&w, k, n, 32, b);
                w.iter().zip(&dq).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / w.len() as f64
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn packed_bytes_reflect_bits() {
        let mut rng = crate::util::Rng::new(9);
        let (k, n) = (128, 64);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let p2 = pack_weight(&w, k, n, 64, 2);
        let p4 = pack_weight(&w, k, n, 64, 4);
        assert_eq!(p4.planes.len(), 2 * p2.planes.len());
        assert!((p2.packed_bytes() as f64) < 0.25 * p2.fp16_bytes() as f64);
    }

    #[test]
    fn codes_in_range() {
        let mut rng = crate::util::Rng::new(11);
        let w: Vec<f32> = (0..64 * 8).map(|_| rng.normal_f32() * 10.0).collect();
        for bits in [2u8, 3, 4] {
            let (codes, _) = quantize_group(&w, 64, 8, 32, bits);
            assert!(codes.iter().all(|&c| c < (1 << bits)));
        }
    }

    /// Native-grid packing reproduces a quantizer's output exactly where
    /// the RTN re-grid of [`pack_weight`] is lossy: codes {0,1,2} at
    /// 2 bits span only 2/3 of the RTN range, so re-gridding moves the
    /// interior point off its original value.
    #[test]
    fn native_grid_packing_roundtrips_exactly() {
        let (k, n, g, bits) = (32usize, 1usize, 32usize, 2u8);
        let stats = QuantStats { scale: vec![0.5], minv: vec![0.0], groups: 1, n: 1 };
        // Grid points for codes 0/1/2 (code 3 deliberately unused).
        let q: Vec<f32> = (0..k).map(|r| 0.5 * (r % 3) as f32).collect();

        let native = pack_weight_with_grid(&q, &stats, k, n, g, bits);
        let codes = unpack_planes(&native.planes, k, n, bits);
        let dq = dequantize(&codes, &native.stats, k, n, g);
        for (a, b) in q.iter().zip(&dq) {
            assert_eq!(a.to_bits(), b.to_bits(), "native grid must be bit-exact");
        }

        let regrid = pack_weight(&q, k, n, g, bits);
        let rcodes = unpack_planes(&regrid.planes, k, n, bits);
        let rdq = dequantize(&rcodes, &regrid.stats, k, n, g);
        assert!(
            q.iter().zip(&rdq).any(|(a, b)| a != b),
            "RTN re-grid should be lossy on this fixture; native packing must beat it"
        );
    }

    /// `act` metadata: absent by default, attached by the builder, and
    /// carried through clones (the archive reader's path).
    #[test]
    fn with_act_attaches_metadata() {
        let mut rng = crate::util::Rng::new(41);
        let (k, n, g) = (64usize, 12usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pw = pack_weight(&w, k, n, g, 4);
        assert!(pw.act.is_none(), "no act metadata unless calibrated");
        let aq = ActQuant::dynamic(&w[..k]);
        let with = pw.with_act(aq);
        assert_eq!(with.act, Some(aq));
        assert_eq!(with.clone().act, Some(aq));
    }

    #[test]
    fn matches_python_oracle_format() {
        // Golden check of the plane layout: code 0b101 at k=0 must set bit 0
        // of planes 0 and 2, word 0.
        let k = 32;
        let n = 1;
        let mut codes = vec![0u32; k];
        codes[0] = 0b101;
        codes[5] = 0b011;
        let planes = pack_planes(&codes, k, n, 3);
        let kw = 1;
        assert_eq!(planes[0 * kw + 0] & 1, 1); // plane 0, bit k=0
        assert_eq!((planes[0] >> 5) & 1, 1); // plane 0, bit k=5
        assert_eq!(planes[1 * kw * n] & 1, 0); // plane 1, k=0
        assert_eq!((planes[1 * kw * n] >> 5) & 1, 1); // plane 1, k=5
        assert_eq!(planes[2 * kw * n] & 1, 1); // plane 2, k=0
    }
}
