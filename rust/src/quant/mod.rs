//! Post-training quantization: primitives, bit-plane packing, backends.
//!
//! Format contract (shared with the Pallas kernels, see
//! `python/compile/kernels/ref.py`): group-wise asymmetric uniform
//! quantization along the input dimension K, codes packed into u32 bit
//! planes. Uniform bit-width *within* a layer, mixed *across* layers —
//! the paper's hardware-friendly scheme (one GEMM kernel per layer).
//!
//! Backends (each one paper baseline):
//! * [`rtn`] — round-to-nearest (the primitive itself).
//! * [`gptq`] — Hessian-compensated column quantization (GPTQ).
//! * [`awq`] — activation-aware per-channel scaling (AWQ).
//! * [`pbllm`] — partial binarization (PB-LLM-like).
//! * [`slim`] — salience-driven per-group mixed precision (SliM-LLM-like).
//!
//! [`act`] is the activation side: calibration-based INT8 parameters
//! (symmetric/asymmetric by distribution symmetry) consumed by the
//! W·A8 kernel path ([`crate::kernels::a8`]).

pub mod act;
pub mod awq;
pub mod codebook;
pub mod gptq;
pub mod pack;
pub mod pbllm;
pub mod rtn;
pub mod saliency;
pub mod schemes;
pub mod slim;

pub use act::{ActCalib, ActMode, ActQuant};
pub use pack::{
    dequantize, extract_outliers, pack_planes, pack_weight_outlier, quantize_group,
    unpack_planes, OutlierSide, PackedWeight, QuantStats,
};

use crate::model::{ModelConfig, ParamStore};
use crate::tensor::Tensor;

/// Which backend produces the simulated-quantized weights for a linear.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rtn,
    Gptq,
    Awq,
    PbLlm,
    SlimLlm,
    /// Scalar k-means codebook (AQLM/QUIP#-class comparison row).
    Codebook,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Rtn => "RTN",
            Backend::Gptq => "GPTQ",
            Backend::Awq => "AWQ",
            Backend::PbLlm => "PB-LLM",
            Backend::SlimLlm => "SliM-LLM",
            Backend::Codebook => "Codebook",
        }
    }

    pub fn from_name(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Backend::Rtn),
            "gptq" => Some(Backend::Gptq),
            "awq" => Some(Backend::Awq),
            "pb-llm" | "pbllm" => Some(Backend::PbLlm),
            "slim-llm" | "slim" => Some(Backend::SlimLlm),
            "codebook" | "aqlm" => Some(Backend::Codebook),
            _ => None,
        }
    }
}

/// Per-layer quantization decision: bit-width for every linear in layer ℓ.
/// Uniform within the layer (the paper's structured scheme).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerBits(pub Vec<u8>);

impl LayerBits {
    pub fn uniform(n_layers: usize, bits: u8) -> LayerBits {
        LayerBits(vec![bits; n_layers])
    }

    /// Average bits weighted by per-layer quantizable parameter count
    /// (paper Eq. 12 with FP16 reference handled by caller).
    pub fn avg_bits(&self, cfg: &ModelConfig) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (l, &b) in self.0.iter().enumerate() {
            let n = cfg.layer_linear_param_count(l) as f64;
            num += b as f64 * n;
            den += n;
        }
        num / den
    }

    /// Compression ratio vs FP16 (Eq. 12).
    pub fn compression_ratio(&self, cfg: &ModelConfig) -> f64 {
        self.avg_bits(cfg) / 16.0
    }
}

/// Quantize every linear of every layer with the given backend and
/// per-layer bits, returning a new (simulated-dequantized f32) ParamStore.
/// `calib` supplies per-linear calibration activations for GPTQ/AWQ.
///
/// The (layer, linear) grid fans out on [`Pool::current`]: every job is
/// independent (reads `params`/`calib`, writes its own tensor) and results
/// merge back in grid order, so output is identical at any thread count.
pub fn quantize_model(
    cfg: &ModelConfig,
    params: &ParamStore,
    bits: &LayerBits,
    backend: Backend,
    calib: Option<&crate::diagnostics::capture::CaptureSet>,
) -> anyhow::Result<ParamStore> {
    use crate::model::config::ALL_LINEARS;
    use crate::model::LinearKind;
    use crate::util::Pool;

    let mut jobs: Vec<(usize, LinearKind)> = Vec::new();
    for layer in 0..cfg.n_layers {
        if bits.0[layer] >= 16 {
            continue; // FP16 layer: untouched
        }
        for &kind in ALL_LINEARS.iter() {
            jobs.push((layer, kind));
        }
    }

    let quantized = Pool::current().par_map(jobs, |(layer, kind)| {
        let b = bits.0[layer];
        let name = cfg.linear_name(layer, kind);
        let w = params.get(&name)?;
        let (k, n) = (w.shape[0], w.shape[1]);
        let wq: Vec<f32> = match backend {
            Backend::Rtn => rtn::quantize_rtn(w.f32_slice(), k, n, cfg.group_size, b),
            Backend::Gptq => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                gptq::quantize_gptq(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())?
            }
            Backend::Awq => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                awq::quantize_awq(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())
            }
            Backend::PbLlm => pbllm::quantize_pbllm(w.f32_slice(), k, n, cfg.group_size, b),
            Backend::SlimLlm => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                slim::quantize_slim(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())
            }
            Backend::Codebook => {
                codebook::quantize_codebook(w.f32_slice(), k, n, cfg.group_size, b)
            }
        };
        anyhow::Ok((name, Tensor::from_f32(wq, &[k, n])))
    });

    let mut out = params.clone();
    for job in quantized {
        let (name, t) = job?;
        out.set(&name, t);
    }
    Ok(out)
}

/// Pack a (simulated-quantized) model into `.lieq` v2 archive entries:
/// every linear of a layer with `bits < 16` becomes a packed-weight
/// entry at that layer's bit-width; everything else (embeddings, norms,
/// FP16-kept layers) stays a plain tensor. Entries come back in store
/// order. Packing fans out per linear on
/// [`crate::util::Pool::current`]; results merge in order, so the
/// archive is identical at any thread count.
///
/// **Fidelity:** by default packing re-derives a per-group affine grid
/// from the store's values (`pack_weight`). For [`Backend::Rtn`] output
/// this is an exact re-encoding (every group attains codes 0 and
/// 2^bits-1, so the re-derived grid coincides). For [`Backend::Gptq`],
/// pass the *original* fp16 store as `fp16` (plus the same `calib` the
/// quantizer saw): the backend is replayed deterministically via
/// [`gptq::quantize_gptq_with_stats`] and its **native** grids and codes
/// are packed ([`pack::pack_weight_with_grid`]) — the archive then
/// reproduces the GPTQ checkpoint bit-for-bit. Other backends (AWQ's
/// folded per-row scales are not on a per-group affine grid at all)
/// fall back to the lossy re-grid; `lieq quantize --packed` warns for
/// those.
///
/// When `calib` is given, every packed linear also gets INT8
/// activation-quantization parameters calibrated from its captured
/// inputs ([`ActCalib`]) — the metadata the W·A8 kernel path consumes.
///
/// **Outliers:** `outlier_eps > 0` extracts the top-ε high-impact input
/// features of every packed linear into a sparse fp16 sidecar
/// ([`OutlierSide`], a `.lieq` v4 section), scored by squared column
/// magnitude × calibration activation energy when `calib` is present
/// (pure magnitude otherwise) and zeroed out of the dense grid before
/// code assignment. For the GPTQ native-replay path extraction happens
/// on the fp16 weights *before* the replay, so Hessian compensation
/// operates on the post-extraction weights. `outlier_eps = 0` is
/// bit-identical to the dense pipeline.
pub fn pack_model_entries(
    cfg: &ModelConfig,
    params: &ParamStore,
    bits: &LayerBits,
    backend: Backend,
    fp16: Option<&ParamStore>,
    calib: Option<&crate::diagnostics::capture::CaptureSet>,
    outlier_eps: f64,
) -> anyhow::Result<Vec<(String, crate::tensor::ArchiveEntry)>> {
    use crate::model::config::ALL_LINEARS;
    use crate::model::LinearKind;
    use crate::tensor::ArchiveEntry;
    use crate::util::Pool;
    use std::collections::BTreeMap;

    let mut linear_bits: BTreeMap<String, (usize, LinearKind, u8)> = BTreeMap::new();
    for layer in 0..cfg.n_layers {
        let b = bits.0[layer];
        if b >= 16 {
            continue;
        }
        for &kind in ALL_LINEARS.iter() {
            linear_bits.insert(cfg.linear_name(layer, kind), (layer, kind, b));
        }
    }

    let jobs: Vec<(String, Option<(usize, LinearKind, u8)>)> = params
        .order
        .iter()
        .map(|name| (name.clone(), linear_bits.get(name).copied()))
        .collect();
    let entries = Pool::current().par_map(jobs, |(name, job)| {
        let t = params.get(&name)?;
        let entry = match job {
            Some((layer, kind, b)) => {
                let (k, n) = (t.shape[0], t.shape[1]);
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                let energy =
                    x.as_deref().map(|xm| saliency::activation_energy(xm, k));
                let mut pw = match (backend, fp16) {
                    (Backend::Gptq, Some(orig)) => {
                        // Deterministic replay from the fp16 weights +
                        // the same calibration: identical compensated
                        // values, so the native grid packs exactly.
                        // Outliers come off the fp16 weights *first* so
                        // the replay compensates the post-extraction
                        // residual (and the sidecar keeps fp16 values).
                        let w = orig.get(&name)?;
                        let mut wv = w.f32_slice().to_vec();
                        let side = pack::extract_outliers(
                            &mut wv,
                            k,
                            n,
                            outlier_eps,
                            energy.as_deref(),
                        );
                        let (q, stats) = gptq::quantize_gptq_with_stats(
                            &wv,
                            k,
                            n,
                            cfg.group_size,
                            b,
                            x.as_deref(),
                        )?;
                        let pw =
                            pack::pack_weight_with_grid(&q, &stats, k, n, cfg.group_size, b);
                        match side {
                            Some(s) => pw.with_outliers(s),
                            None => pw,
                        }
                    }
                    _ => pack::pack_weight_outlier(
                        t.f32_slice(),
                        k,
                        n,
                        cfg.group_size,
                        b,
                        outlier_eps,
                        energy.as_deref(),
                    ),
                };
                if let Some(x) = &x {
                    let mut ac = ActCalib::new();
                    ac.observe(x);
                    if let Some(aq) = ac.finish() {
                        pw = pw.with_act(aq);
                    }
                }
                // Build the lane image here, on the pool worker: these
                // entries head for a lanes-persisting v2 archive, and
                // building lazily inside write_archive_v2 would serialize
                // every conversion on the writer thread.
                pw.interleaved();
                ArchiveEntry::Packed(pw)
            }
            None => ArchiveEntry::Tensor(t.clone()),
        };
        anyhow::Ok((name, entry))
    });
    entries.into_iter().collect()
}

/// Rebuild a serving [`ParamStore`] from archive entries (v1 or v2):
/// packed weights dequantize to f32 for the artifact-backed scoring
/// path. The store is validated against `cfg`. Callers that also want
/// the packed weights should borrow them from the entries themselves
/// (`ArchiveEntry::Packed`) — `cmd_serve`'s readiness pass does — or
/// use [`entries_to_store`] for owned clones.
pub fn store_from_entries(
    cfg: &ModelConfig,
    entries: &[(String, crate::tensor::ArchiveEntry)],
) -> anyhow::Result<ParamStore> {
    use crate::tensor::ArchiveEntry;

    let mut tensors = Vec::with_capacity(entries.len());
    for (name, entry) in entries {
        match entry {
            ArchiveEntry::Tensor(t) => tensors.push((name.clone(), t.clone())),
            ArchiveEntry::Packed(pw) => tensors.push((
                name.clone(),
                Tensor::from_f32(pw.dequantized(), &[pw.k, pw.n]),
            )),
        }
    }
    ParamStore::from_named(cfg, tensors)
}

/// [`store_from_entries`] plus **deep clones** of the packed weights
/// (planes + grids + any seeded lane image — the lane cache survives
/// the clone). Prefer borrowing `ArchiveEntry::Packed` from the entries
/// when the clones would only serve a transient pass; the clone cost is
/// the full packed payload.
pub fn entries_to_store(
    cfg: &ModelConfig,
    entries: &[(String, crate::tensor::ArchiveEntry)],
) -> anyhow::Result<(ParamStore, Vec<(String, PackedWeight)>)> {
    use crate::tensor::ArchiveEntry;

    let store = store_from_entries(cfg, entries)?;
    let packed = entries
        .iter()
        .filter_map(|(name, e)| match e {
            ArchiveEntry::Packed(pw) => Some((name.clone(), pw.clone())),
            ArchiveEntry::Tensor(_) => None,
        })
        .collect();
    Ok((store, packed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            Backend::Rtn,
            Backend::Gptq,
            Backend::Awq,
            Backend::PbLlm,
            Backend::SlimLlm,
            Backend::Codebook,
        ] {
            assert_eq!(Backend::from_name(b.name()), Some(b));
        }
    }

    #[test]
    fn uniform_bits() {
        let lb = LayerBits::uniform(4, 2);
        assert_eq!(lb.0, vec![2, 2, 2, 2]);
    }

    /// Quantize -> pack -> entries -> store roundtrip: linears of
    /// quantized layers become packed entries, FP16 layers and non-linear
    /// params stay tensors, and the rebuilt store is value-identical
    /// (the packed grid re-encodes the already-on-grid values).
    #[test]
    fn pack_model_entries_roundtrip_store() {
        use crate::tensor::ArchiveEntry;

        let cfg = ModelConfig::synthetic(2, 128, 384);
        let mut rng = crate::util::Rng::new(99);
        let tensors: Vec<Tensor> = cfg
            .params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                let data: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.05).collect();
                Tensor::from_f32(data, &p.shape)
            })
            .collect();
        let params = ParamStore::from_positional(&cfg, tensors).unwrap();
        let mut bits = LayerBits::uniform(cfg.n_layers, 3);
        bits.0[1] = 16; // FP16-kept layer: must stay a tensor entry
        let q = quantize_model(&cfg, &params, &bits, Backend::Rtn, None).unwrap();

        let entries = pack_model_entries(&cfg, &q, &bits, Backend::Rtn, None, None, 0.0).unwrap();
        assert_eq!(entries.len(), cfg.params.len());
        let n_packed = entries
            .iter()
            .filter(|(_, e)| matches!(e, ArchiveEntry::Packed(_)))
            .count();
        assert_eq!(n_packed, 7, "one packed entry per linear of the quantized layer");
        for (name, e) in &entries {
            if name.starts_with("layers.1.") || !name.starts_with("layers.") {
                assert!(matches!(e, ArchiveEntry::Tensor(_)), "{name} must stay a tensor");
            }
        }

        let (store, packed) = entries_to_store(&cfg, &entries).unwrap();
        assert_eq!(packed.len(), 7);
        for p in &cfg.params {
            let a = q.get(&p.name).unwrap().f32_slice();
            let b = store.get(&p.name).unwrap().f32_slice();
            let max_err = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < 2e-3, "{}: packed roundtrip err {max_err}", p.name);
        }
    }

    /// Native-grid GPTQ capture: with the fp16 store supplied, packing
    /// replays the backend deterministically and the archive entries
    /// dequantize bit-for-bit to the quantized checkpoint — no RTN
    /// re-grid shift.
    #[test]
    fn gptq_native_packing_is_bit_exact() {
        let cfg = ModelConfig::synthetic(2, 128, 384);
        let mut rng = crate::util::Rng::new(53);
        let tensors: Vec<Tensor> = cfg
            .params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                let data: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.05).collect();
                Tensor::from_f32(data, &p.shape)
            })
            .collect();
        let params = ParamStore::from_positional(&cfg, tensors).unwrap();
        let bits = LayerBits::uniform(cfg.n_layers, 3);
        let q = quantize_model(&cfg, &params, &bits, Backend::Gptq, None).unwrap();

        let entries =
            pack_model_entries(&cfg, &q, &bits, Backend::Gptq, Some(&params), None, 0.0).unwrap();
        let store = store_from_entries(&cfg, &entries).unwrap();
        for p in &cfg.params {
            let a = q.get(&p.name).unwrap().f32_slice();
            let b = store.get(&p.name).unwrap().f32_slice();
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{}[{i}]: native GPTQ packing must be bit-exact",
                    p.name
                );
            }
        }
    }

    /// With calibration supplied, every packed linear carries calibrated
    /// INT8 activation parameters for the W·A8 kernel path.
    #[test]
    fn pack_model_entries_attaches_act_metadata() {
        use crate::diagnostics::capture::CaptureSet;
        use crate::tensor::ArchiveEntry;

        let cfg = ModelConfig::synthetic(2, 128, 384);
        let mut rng = crate::util::Rng::new(71);
        let tensors: Vec<Tensor> = cfg
            .params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                let data: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.05).collect();
                Tensor::from_f32(data, &p.shape)
            })
            .collect();
        let params = ParamStore::from_positional(&cfg, tensors).unwrap();
        let bits = LayerBits::uniform(cfg.n_layers, 3);
        let q = quantize_model(&cfg, &params, &bits, Backend::Rtn, None).unwrap();

        let (l, rows, d, d_ctx, d_ff) = (cfg.n_layers, 8usize, cfg.d_model, cfg.d_model, cfg.d_ff);
        let act = |w: usize, rng: &mut crate::util::Rng| {
            let data: Vec<f32> = (0..l * rows * w).map(|_| rng.normal_f32()).collect();
            Tensor::from_f32(data, &[l, 1, rows, w])
        };
        let cap = CaptureSet::from_parts(
            l,
            rows,
            d,
            d_ctx,
            d_ff,
            act(d, &mut rng),
            act(d_ctx, &mut rng),
            act(d, &mut rng),
            act(d_ff, &mut rng),
        );

        let entries =
            pack_model_entries(&cfg, &q, &bits, Backend::Rtn, None, Some(&cap), 0.0).unwrap();
        let mut packed = 0;
        for (name, e) in &entries {
            if let ArchiveEntry::Packed(pw) = e {
                packed += 1;
                assert!(pw.act.is_some(), "{name}: calibrated entry must carry act params");
            }
        }
        assert_eq!(packed, 14, "every linear of both layers packs");
    }

    /// With `outlier_eps > 0` every packed linear carries a sidecar of
    /// exactly ceil(ε·K) columns, the dequantized entries reproduce the
    /// sidecar values exactly, and ε=0 entries stay sidecar-free.
    #[test]
    fn pack_model_entries_attaches_outlier_sidecars() {
        use crate::tensor::ArchiveEntry;

        let cfg = ModelConfig::synthetic(2, 128, 384);
        let mut rng = crate::util::Rng::new(83);
        let tensors: Vec<Tensor> = cfg
            .params
            .iter()
            .map(|p| {
                let len: usize = p.shape.iter().product();
                let data: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.05).collect();
                Tensor::from_f32(data, &p.shape)
            })
            .collect();
        let params = ParamStore::from_positional(&cfg, tensors).unwrap();
        let bits = LayerBits::uniform(cfg.n_layers, 2);
        let q = quantize_model(&cfg, &params, &bits, Backend::Rtn, None).unwrap();

        let eps = 0.02;
        let entries =
            pack_model_entries(&cfg, &q, &bits, Backend::Rtn, None, None, eps).unwrap();
        let mut packed = 0;
        for (name, e) in &entries {
            if let ArchiveEntry::Packed(pw) = e {
                packed += 1;
                let want = saliency::outlier_count(pw.k, eps);
                assert_eq!(pw.outlier_cols(), want, "{name}: ceil(eps*K) columns");
                let side = pw.outliers.as_ref().unwrap();
                assert!(side.validate(pw.k, pw.n));
                let dq = pw.dequantized();
                for (i, &c) in side.cols.iter().enumerate() {
                    let row = c as usize * pw.n;
                    assert_eq!(
                        &dq[row..row + pw.n],
                        &side.vals[i * pw.n..(i + 1) * pw.n],
                        "{name}: sidecar rows must re-insert exactly"
                    );
                }
            }
        }
        assert_eq!(packed, 14);

        let dense = pack_model_entries(&cfg, &q, &bits, Backend::Rtn, None, None, 0.0).unwrap();
        for (name, e) in &dense {
            if let ArchiveEntry::Packed(pw) = e {
                assert!(pw.outliers.is_none(), "{name}: eps=0 must stay dense");
            }
        }
    }
}
