//! Post-training quantization: primitives, bit-plane packing, backends.
//!
//! Format contract (shared with the Pallas kernels, see
//! `python/compile/kernels/ref.py`): group-wise asymmetric uniform
//! quantization along the input dimension K, codes packed into u32 bit
//! planes. Uniform bit-width *within* a layer, mixed *across* layers —
//! the paper's hardware-friendly scheme (one GEMM kernel per layer).
//!
//! Backends (each one paper baseline):
//! * [`rtn`] — round-to-nearest (the primitive itself).
//! * [`gptq`] — Hessian-compensated column quantization (GPTQ).
//! * [`awq`] — activation-aware per-channel scaling (AWQ).
//! * [`pbllm`] — partial binarization (PB-LLM-like).
//! * [`slim`] — salience-driven per-group mixed precision (SliM-LLM-like).

pub mod awq;
pub mod codebook;
pub mod gptq;
pub mod pack;
pub mod pbllm;
pub mod rtn;
pub mod schemes;
pub mod slim;

pub use pack::{dequantize, pack_planes, quantize_group, unpack_planes, PackedWeight, QuantStats};

use crate::model::{ModelConfig, ParamStore};
use crate::tensor::Tensor;

/// Which backend produces the simulated-quantized weights for a linear.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rtn,
    Gptq,
    Awq,
    PbLlm,
    SlimLlm,
    /// Scalar k-means codebook (AQLM/QUIP#-class comparison row).
    Codebook,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Rtn => "RTN",
            Backend::Gptq => "GPTQ",
            Backend::Awq => "AWQ",
            Backend::PbLlm => "PB-LLM",
            Backend::SlimLlm => "SliM-LLM",
            Backend::Codebook => "Codebook",
        }
    }

    pub fn from_name(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Backend::Rtn),
            "gptq" => Some(Backend::Gptq),
            "awq" => Some(Backend::Awq),
            "pb-llm" | "pbllm" => Some(Backend::PbLlm),
            "slim-llm" | "slim" => Some(Backend::SlimLlm),
            "codebook" | "aqlm" => Some(Backend::Codebook),
            _ => None,
        }
    }
}

/// Per-layer quantization decision: bit-width for every linear in layer ℓ.
/// Uniform within the layer (the paper's structured scheme).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerBits(pub Vec<u8>);

impl LayerBits {
    pub fn uniform(n_layers: usize, bits: u8) -> LayerBits {
        LayerBits(vec![bits; n_layers])
    }

    /// Average bits weighted by per-layer quantizable parameter count
    /// (paper Eq. 12 with FP16 reference handled by caller).
    pub fn avg_bits(&self, cfg: &ModelConfig) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (l, &b) in self.0.iter().enumerate() {
            let n = cfg.layer_linear_param_count(l) as f64;
            num += b as f64 * n;
            den += n;
        }
        num / den
    }

    /// Compression ratio vs FP16 (Eq. 12).
    pub fn compression_ratio(&self, cfg: &ModelConfig) -> f64 {
        self.avg_bits(cfg) / 16.0
    }
}

/// Quantize every linear of every layer with the given backend and
/// per-layer bits, returning a new (simulated-dequantized f32) ParamStore.
/// `calib` supplies per-linear calibration activations for GPTQ/AWQ.
///
/// The (layer, linear) grid fans out on [`Pool::current`]: every job is
/// independent (reads `params`/`calib`, writes its own tensor) and results
/// merge back in grid order, so output is identical at any thread count.
pub fn quantize_model(
    cfg: &ModelConfig,
    params: &ParamStore,
    bits: &LayerBits,
    backend: Backend,
    calib: Option<&crate::diagnostics::capture::CaptureSet>,
) -> anyhow::Result<ParamStore> {
    use crate::model::config::ALL_LINEARS;
    use crate::model::LinearKind;
    use crate::util::Pool;

    let mut jobs: Vec<(usize, LinearKind)> = Vec::new();
    for layer in 0..cfg.n_layers {
        if bits.0[layer] >= 16 {
            continue; // FP16 layer: untouched
        }
        for &kind in ALL_LINEARS.iter() {
            jobs.push((layer, kind));
        }
    }

    let quantized = Pool::current().par_map(jobs, |(layer, kind)| {
        let b = bits.0[layer];
        let name = cfg.linear_name(layer, kind);
        let w = params.get(&name)?;
        let (k, n) = (w.shape[0], w.shape[1]);
        let wq: Vec<f32> = match backend {
            Backend::Rtn => rtn::quantize_rtn(w.f32_slice(), k, n, cfg.group_size, b),
            Backend::Gptq => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                gptq::quantize_gptq(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())?
            }
            Backend::Awq => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                awq::quantize_awq(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())
            }
            Backend::PbLlm => pbllm::quantize_pbllm(w.f32_slice(), k, n, cfg.group_size, b),
            Backend::SlimLlm => {
                let x = calib.map(|c| c.calib_matrix(layer, kind));
                slim::quantize_slim(w.f32_slice(), k, n, cfg.group_size, b, x.as_deref())
            }
            Backend::Codebook => {
                codebook::quantize_codebook(w.f32_slice(), k, n, cfg.group_size, b)
            }
        };
        anyhow::Ok((name, Tensor::from_f32(wq, &[k, n])))
    });

    let mut out = params.clone();
    for job in quantized {
        let (name, t) = job?;
        out.set(&name, t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_names_roundtrip() {
        for b in [
            Backend::Rtn,
            Backend::Gptq,
            Backend::Awq,
            Backend::PbLlm,
            Backend::SlimLlm,
            Backend::Codebook,
        ] {
            assert_eq!(Backend::from_name(b.name()), Some(b));
        }
    }

    #[test]
    fn uniform_bits() {
        let lb = LayerBits::uniform(4, 2);
        assert_eq!(lb.0, vec![2, 2, 2, 2]);
    }
}
