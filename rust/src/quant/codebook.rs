//! Codebook (vector-free, scalar k-means) quantization backend — the
//! AQLM/QUIP#-class comparison row of Table 3.
//!
//! Per (group, output-channel) we fit a 2^b-entry scalar codebook with
//! Lloyd's algorithm instead of the uniform grid RTN uses. Codebooks adapt
//! to the weight distribution (heavier mass near zero ⇒ denser centroids
//! there), which buys accuracy at the same stored-bits budget in exchange
//! for a per-group table — the paper's "codebook-based compression
//! methods" integration point.

/// Simulated-quantized weights with a per-(group, column) k-means codebook.
pub fn quantize_codebook(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    assert!(k % group == 0);
    let levels = 1usize << bits;
    let groups = k / group;
    let mut out = vec![0f32; k * n];
    let mut vals = vec![0f32; group];
    let mut centroids = vec![0f32; levels];

    for gi in 0..groups {
        for col in 0..n {
            for r in 0..group {
                vals[r] = w[(gi * group + r) * n + col];
            }
            kmeans_1d(&vals, &mut centroids);
            for r in 0..group {
                let idx = (gi * group + r) * n + col;
                out[idx] = nearest(&centroids, vals[r]);
            }
        }
    }
    out
}

/// Lloyd's algorithm on scalars; init = uniform quantiles (stable, no RNG).
fn kmeans_1d(vals: &[f32], centroids: &mut [f32]) {
    let levels = centroids.len();
    let mut sorted = vals.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (i, c) in centroids.iter_mut().enumerate() {
        let q = (i as f32 + 0.5) / levels as f32;
        *c = sorted[((q * sorted.len() as f32) as usize).min(sorted.len() - 1)];
    }
    let mut sums = vec![0f64; levels];
    let mut counts = vec![0usize; levels];
    for _iter in 0..8 {
        sums.fill(0.0);
        counts.fill(0);
        for &v in vals {
            let j = nearest_idx(centroids, v);
            sums[j] += v as f64;
            counts[j] += 1;
        }
        let mut moved = 0f32;
        for j in 0..levels {
            if counts[j] > 0 {
                let next = (sums[j] / counts[j] as f64) as f32;
                moved = moved.max((next - centroids[j]).abs());
                centroids[j] = next;
            }
        }
        if moved < 1e-6 {
            break;
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

#[inline]
fn nearest_idx(centroids: &[f32], v: f32) -> usize {
    // Centroids are sorted: binary search then compare neighbours.
    let mut lo = 0usize;
    let mut hi = centroids.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if centroids[mid] < v {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo > 0 && (v - centroids[lo - 1]).abs() <= (centroids[lo] - v).abs() {
        lo - 1
    } else {
        lo
    }
}

#[inline]
fn nearest(centroids: &[f32], v: f32) -> f32 {
    centroids[nearest_idx(centroids, v)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::quant_dequant;
    use crate::util::Rng;

    fn mse(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) * (x - y)) as f64).sum::<f64>() / a.len() as f64
    }

    #[test]
    fn beats_uniform_grid_on_gaussian() {
        // k-means adapts to the bell shape → lower MSE than the uniform
        // grid at the same bit count.
        let mut rng = Rng::new(3);
        let (k, n, g) = (64usize, 24usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        for bits in [2u8, 3] {
            let cb = quantize_codebook(&w, k, n, g, bits);
            let rtn = quant_dequant(&w, k, n, g, bits);
            assert!(
                mse(&w, &cb) < mse(&w, &rtn),
                "bits={bits}: codebook {} vs rtn {}",
                mse(&w, &cb),
                mse(&w, &rtn)
            );
        }
    }

    #[test]
    fn output_uses_at_most_2pow_b_values_per_group_column() {
        let mut rng = Rng::new(5);
        let (k, n, g, bits) = (32usize, 4usize, 32usize, 2u8);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let q = quantize_codebook(&w, k, n, g, bits);
        for col in 0..n {
            let mut uniq: Vec<f32> = (0..k).map(|r| q[r * n + col]).collect();
            uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
            uniq.dedup();
            assert!(uniq.len() <= 1 << bits, "col {col}: {} uniques", uniq.len());
        }
    }

    #[test]
    fn nearest_idx_correct() {
        let c = [-1.0f32, 0.0, 2.0];
        assert_eq!(nearest_idx(&c, -5.0), 0);
        assert_eq!(nearest_idx(&c, -0.4), 1);
        assert_eq!(nearest_idx(&c, 1.2), 2);
        assert_eq!(nearest_idx(&c, 10.0), 2);
    }

    #[test]
    fn constant_input_exact() {
        let w = vec![0.7f32; 64];
        let q = quantize_codebook(&w, 32, 2, 32, 2);
        for v in q {
            assert!((v - 0.7).abs() < 1e-6);
        }
    }
}
