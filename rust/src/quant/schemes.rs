//! The four mixed-precision schemes of the paper's Fig. 3, implemented as
//! the scheme ablation (`lieq ablate-schemes`).
//!
//! (i)   element-wise FP16 protection of outlier weights;
//! (ii)  group-wise 2-bit with salience-split 1/3-bit groups;
//! (iii) block-wise 4-bit attention, 2-bit MLP;
//! (iv)  LieQ: uniform-within-layer, 4-bit for the top-m scored layers;
//! (v)   LieQ + column-outlier sidecar: (iv) with the top-ε salient
//!       input columns per linear carried as a sparse fp16 sidecar
//!       (the deployable mixed-packing representation — structured per
//!       column, unlike (i)'s irregular element mask).

use anyhow::Result;

use crate::model::config::ALL_LINEARS;
use crate::model::{ModelConfig, ParamStore};
use crate::tensor::Tensor;

use super::pack::quant_dequant;
use super::{slim, LayerBits};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// (i) 2-bit + top-1% weights kept FP16 (element-wise, irregular).
    ElementOutlierFp16,
    /// (ii) group-wise 2-bit with 1/3-bit salience split (SliM-style).
    GroupMixed13,
    /// (iii) attention linears 4-bit, MLP linears 2-bit, every layer.
    BlockAttn4Mlp2,
    /// (iv) LieQ: per-layer uniform bits from the effectiveness score.
    LieqTopM,
    /// (v) LieQ bits + top-ε column outliers in a sparse fp16 sidecar
    /// (`pack_weight_outlier` at [`SCHEME_OUTLIER_EPS`]).
    LieqTopMOutlier,
}

/// Column-outlier fraction used by [`Scheme::LieqTopMOutlier`] — matches
/// the `--outlier-eps 0.01` deployment default.
pub const SCHEME_OUTLIER_EPS: f64 = 0.01;

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::ElementOutlierFp16 => "element-fp16-protect",
            Scheme::GroupMixed13 => "group-2bit-1/3-split",
            Scheme::BlockAttn4Mlp2 => "block-attn4-mlp2",
            Scheme::LieqTopM => "lieq-top-m",
            Scheme::LieqTopMOutlier => "lieq-top-m+out1%",
        }
    }
}

/// Apply scheme (i)–(iii) directly; scheme (iv) goes through the LieQ
/// pipeline (diagnostics::allocate) and is listed here for completeness.
pub fn apply_scheme(
    cfg: &ModelConfig,
    params: &ParamStore,
    scheme: Scheme,
    lieq_bits: Option<&LayerBits>,
) -> Result<ParamStore> {
    let mut out = params.clone();
    for layer in 0..cfg.n_layers {
        for &kind in ALL_LINEARS.iter() {
            let name = cfg.linear_name(layer, kind);
            let w = params.get(&name)?;
            let (k, n) = (w.shape[0], w.shape[1]);
            let g = cfg.group_size;
            let wq: Vec<f32> = match scheme {
                Scheme::ElementOutlierFp16 => outlier_protect(w.f32_slice(), k, n, g, 2, 0.01),
                Scheme::GroupMixed13 => slim::quantize_slim(w.f32_slice(), k, n, g, 2, None),
                Scheme::BlockAttn4Mlp2 => {
                    let bits = match kind.calib_source() {
                        "attn_in" | "ctx" => 4,
                        _ => 2,
                    };
                    quant_dequant(w.f32_slice(), k, n, g, bits)
                }
                Scheme::LieqTopM => {
                    let bits = lieq_bits.map(|lb| lb.0[layer]).unwrap_or(2);
                    quant_dequant(w.f32_slice(), k, n, g, bits)
                }
                Scheme::LieqTopMOutlier => {
                    let bits = lieq_bits.map(|lb| lb.0[layer]).unwrap_or(2);
                    super::pack::pack_weight_outlier(
                        w.f32_slice(),
                        k,
                        n,
                        g,
                        bits,
                        SCHEME_OUTLIER_EPS,
                        None,
                    )
                    .dequantized()
                }
            };
            out.set(&name, Tensor::from_f32(wq, &[k, n]));
        }
    }
    Ok(out)
}

/// Effective average bits of a scheme (for the ablation table's memory
/// column). Element-wise protection pays 16 bits for the protected
/// fraction plus an index overhead (~log2(K·N) bits/outlier ≈ 16).
pub fn scheme_avg_bits(cfg: &ModelConfig, scheme: Scheme, lieq_bits: Option<&LayerBits>) -> f64 {
    match scheme {
        Scheme::ElementOutlierFp16 => 0.99 * 2.0 + 0.01 * (16.0 + 16.0),
        Scheme::GroupMixed13 => 2.0,
        Scheme::BlockAttn4Mlp2 => {
            // Weighted by actual attn/mlp parameter split.
            let mut attn = 0usize;
            let mut mlp = 0usize;
            for l in 0..cfg.n_layers {
                for &kind in ALL_LINEARS.iter() {
                    let p = cfg
                        .param_info(&cfg.linear_name(l, kind))
                        .map(|p| p.shape.iter().product::<usize>())
                        .unwrap_or(0);
                    match kind.calib_source() {
                        "attn_in" | "ctx" => attn += p,
                        _ => mlp += p,
                    }
                }
            }
            (attn as f64 * 4.0 + mlp as f64 * 2.0) / (attn + mlp) as f64
        }
        Scheme::LieqTopM => lieq_bits.map(|lb| lb.avg_bits(cfg)).unwrap_or(2.0),
        Scheme::LieqTopMOutlier => {
            lieq_bits.map(|lb| lb.avg_bits(cfg)).unwrap_or(2.0)
                + crate::diagnostics::outlier_overhead_bits(cfg, SCHEME_OUTLIER_EPS)
        }
    }
}

/// 2-bit RTN with the top `frac` magnitude weights restored to FP16.
fn outlier_protect(w: &[f32], k: usize, n: usize, group: usize, bits: u8, frac: f64) -> Vec<f32> {
    let mut q = quant_dequant(w, k, n, group, bits);
    let n_protect = ((k * n) as f64 * frac) as usize;
    let mut idx: Vec<usize> = (0..k * n).collect();
    idx.sort_by(|&a, &b| w[b].abs().partial_cmp(&w[a].abs()).unwrap());
    for &i in idx.iter().take(n_protect) {
        q[i] = w[i];
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn outlier_protection_reduces_error() {
        let mut rng = Rng::new(12);
        let (k, n) = (64, 32);
        let mut w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32() * 0.2).collect();
        for i in (0..k * n).step_by(97) {
            w[i] = rng.normal_f32() * 8.0; // outliers
        }
        let plain = quant_dequant(&w, k, n, 32, 2);
        let prot = outlier_protect(&w, k, n, 32, 2, 0.02);
        let mae = |q: &[f32]| {
            w.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f32>() / w.len() as f32
        };
        assert!(mae(&prot) < mae(&plain));
    }

    #[test]
    fn scheme_bits_ordering() {
        // Block scheme sits between 2 and 4 bits; element protection ≈2.3.
        let e = scheme_avg_bits_dummy(Scheme::ElementOutlierFp16);
        assert!(e > 2.0 && e < 2.5, "{e}");
    }

    fn scheme_avg_bits_dummy(s: Scheme) -> f64 {
        match s {
            Scheme::ElementOutlierFp16 => 0.99 * 2.0 + 0.01 * 32.0,
            _ => 0.0,
        }
    }
}
