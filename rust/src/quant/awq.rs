//! AWQ backend (Lin et al., 2024): activation-aware weight quantization.
//!
//! Salient input channels (large mean |activation|) are protected by
//! scaling them up before quantization and folding the inverse scale into
//! the activation side: `y = (x / s) · Q(diag(s) W)`. We grid-search the
//! exponent α in `s_k = E[|x_k|]^α` over the paper's 20-point grid
//! (α = i/20, i = 0..20) to minimize the output reconstruction error on
//! the calibration set, exactly as the AWQ reference implementation does.
//! The grid points are independent, so the search fans out on [`Pool`];
//! ties break toward the smallest α in grid order, making the winner
//! identical at any thread count.

use crate::util::Pool;

use super::pack::quant_dequant;

/// Number of α grid points searched (AWQ paper/reference default).
pub const GRID_POINTS: usize = 20;

/// The α candidates: `i / GRID_POINTS` for `i = 0..GRID_POINTS`
/// (α = 0 ⇒ plain RTN is always among the candidates).
pub fn alpha_grid() -> Vec<f64> {
    (0..GRID_POINTS).map(|i| i as f64 / GRID_POINTS as f64).collect()
}

/// Simulated-quantized weights with activation-aware scaling. Without
/// calibration data, degrades to RTN (α = 0).
pub fn quantize_awq(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    x_calib: Option<&[f32]>,
) -> Vec<f32> {
    let Some(x) = x_calib else {
        return quant_dequant(w, k, n, group, bits);
    };
    let samples = x.len() / k;
    // Mean |activation| per input channel, normalized to mean 1.
    let mut act = vec![0f64; k];
    for s in 0..samples {
        for col in 0..k {
            act[col] += x[s * k + col].abs() as f64;
        }
    }
    for a in &mut act {
        *a = (*a / samples as f64).max(1e-8);
    }
    let norm: f64 = act.iter().sum::<f64>() / k as f64;
    for a in &mut act {
        *a /= norm.max(1e-12);
    }

    // Pool-parallel α grid search: score every candidate (each worker
    // quantizes independently), then pick the first minimum in grid
    // order and re-quantize once — O(grid) memory stays at one error
    // scalar per point instead of one K×N matrix per point.
    let act_ref = &act;
    let grid = alpha_grid();
    let errs: Vec<f64> = Pool::current().par_map(grid.clone(), |alpha| {
        let s: Vec<f64> = act_ref.iter().map(|a| a.powf(alpha).max(1e-4)).collect();
        let q = quantize_with_scales(w, k, n, group, bits, &s);
        weighted_recon_error(w, &q, act_ref, k, n)
    });
    let best = errs
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0);
    let alpha = grid[best];
    let s: Vec<f64> = act.iter().map(|a| a.powf(alpha).max(1e-4)).collect();
    quantize_with_scales(w, k, n, group, bits, &s)
}

/// Q(diag(s)·W) / diag(s) — scale rows, quantize, unscale.
fn quantize_with_scales(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u8,
    s: &[f64],
) -> Vec<f32> {
    let mut ws = vec![0f32; k * n];
    for row in 0..k {
        let sr = s[row] as f32;
        for col in 0..n {
            ws[row * n + col] = w[row * n + col] * sr;
        }
    }
    let mut q = quant_dequant(&ws, k, n, group, bits);
    for row in 0..k {
        let inv = 1.0 / s[row] as f32;
        for col in 0..n {
            q[row * n + col] *= inv;
        }
    }
    q
}

/// Activation-magnitude-weighted reconstruction error
/// Σ_k act_k² ‖W_k - Ŵ_k‖² — proxy for ‖X(W - Ŵ)‖² that avoids a full GEMM
/// per grid point.
fn weighted_recon_error(w: &[f32], q: &[f32], act: &[f64], k: usize, n: usize) -> f64 {
    let mut err = 0.0;
    for row in 0..k {
        let a2 = act[row] * act[row];
        let mut rowerr = 0.0f64;
        for col in 0..n {
            let d = (w[row * n + col] - q[row * n + col]) as f64;
            rowerr += d * d;
        }
        err += a2 * rowerr;
    }
    err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn setup(seed: u64, k: usize, n: usize, samples: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        // Heavy-tailed activations: a few channels dominate (the AWQ
        // motivation — salient channels exist).
        let mut x = vec![0f32; samples * k];
        for s in 0..samples {
            for col in 0..k {
                let boost = if col % 16 == 0 { 8.0 } else { 1.0 };
                x[s * k + col] = rng.normal_f32() * boost;
            }
        }
        (w, x)
    }

    fn task_error(w: &[f32], q: &[f32], x: &[f32], k: usize, n: usize) -> f64 {
        let samples = x.len() / k;
        let mut err = 0.0;
        for s in 0..samples {
            for col in 0..n {
                let mut acc = 0.0f64;
                for row in 0..k {
                    acc += x[s * k + row] as f64 * (w[row * n + col] - q[row * n + col]) as f64;
                }
                err += acc * acc;
            }
        }
        err
    }

    #[test]
    fn beats_rtn_with_salient_channels() {
        let (k, n, samples) = (64, 32, 96);
        let mut wins = 0;
        for seed in 0..5 {
            let (w, x) = setup(seed, k, n, samples);
            let q_awq = quantize_awq(&w, k, n, 32, 2, Some(&x));
            let q_rtn = quant_dequant(&w, k, n, 32, 2);
            if task_error(&w, &q_awq, &x, k, n) < task_error(&w, &q_rtn, &x, k, n) {
                wins += 1;
            }
        }
        assert!(wins >= 4, "AWQ won only {wins}/5");
    }

    #[test]
    fn grid_has_twenty_points_including_rtn() {
        let g = alpha_grid();
        assert_eq!(g.len(), 20, "AWQ paper grid is 20 points");
        assert_eq!(g[0], 0.0, "α = 0 (plain RTN) must be a candidate");
        assert!(g.windows(2).all(|w| w[1] > w[0]));
        assert!(g.iter().all(|&a| (0.0..1.0).contains(&a)));
    }

    #[test]
    fn no_calib_equals_rtn() {
        let (w, _) = setup(3, 32, 16, 8);
        assert_eq!(quantize_awq(&w, 32, 16, 32, 3, None), quant_dequant(&w, 32, 16, 32, 3));
    }

    #[test]
    fn uniform_activations_recover_rtn_alpha0() {
        // With flat activations every α gives similar scales; α=0 (RTN) must
        // be among the candidates, so error can never exceed plain RTN's.
        let mut rng = Rng::new(4);
        let (k, n) = (32, 16);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..k * 64).map(|_| rng.normal_f32()).collect();
        let q_awq = quantize_awq(&w, k, n, 32, 2, Some(&x));
        let q_rtn = quant_dequant(&w, k, n, 32, 2);
        let act = vec![1.0f64; k];
        let e_awq = weighted_recon_error(&w, &q_awq, &act, k, n);
        let e_rtn = weighted_recon_error(&w, &q_rtn, &act, k, n);
        assert!(e_awq <= e_rtn * 1.5, "awq {e_awq} rtn {e_rtn}");
    }
}
