//! RTN (round-to-nearest) backend: plain group-wise quantize-dequantize.
//! Both the simplest baseline and the primitive every other backend calls.

use super::pack::quant_dequant;

/// Simulated-quantized weights via direct rounding.
pub fn quantize_rtn(w: &[f32], k: usize, n: usize, group: usize, bits: u8) -> Vec<f32> {
    quant_dequant(w, k, n, group, bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_shape_and_stays_close_at_4bit() {
        let mut rng = crate::util::Rng::new(3);
        let (k, n) = (64, 32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let q = quantize_rtn(&w, k, n, 32, 4);
        assert_eq!(q.len(), w.len());
        let mae: f32 =
            w.iter().zip(&q).map(|(a, b)| (a - b).abs()).sum::<f32>() / w.len() as f32;
        assert!(mae < 0.1, "mae={mae}");
    }
}
