//! INT8 activation quantization with calibration-based mode selection
//! (the W·A8 half of the SIMD/A8 kernel tier).
//!
//! Per linear, calibration activations yield mean/std/min/max; the
//! **symmetry score** `exp(-|mean| / (std + ε))` decides the mode:
//!
//! * score **>** [`SYMMETRY_THRESHOLD`] (0.6) — the distribution is
//!   centered: **symmetric** signed INT8, codes in `[-127, 127]`,
//!   zero-point 0, scale `max(|min|, |max|) / 127`.
//! * score **≤** threshold — skewed (post-GELU/ReLU-like): **asymmetric**
//!   unsigned INT8, codes in `[0, 255]` over the zero-inclusive range
//!   `[min(min, 0), max(max, 0)]`: scale `(hi - lo) / 255`, zero-point
//!   `round(-lo / scale)`.
//!
//! Either way the kernel consumes **centered** codes `q - zp` (i32, in
//! `[-255, 255]`), so the A8 GEMV is one integer dot product per
//! (group, column) plus one affine rescale:
//! `y[col] += s_x · (scale_w[g,col] · Σ c_x·c_w + min_w[g,col] · Σ c_x)`.
//!
//! Rounding is `f32::round` (half away from zero) throughout — the same
//! deterministic rule the weight grids use — so quantized activations
//! are identical on every ISA and at every thread count.
//!
//! Calibrated parameters attach to `PackedWeight::act` and persist as a
//! `.lieq` v3 side entry; weights without stored parameters fall back
//! to per-row **dynamic** quantization ([`ActQuant::dynamic`]) using
//! the same score/mode recipe on the live row.

/// Mode-selection threshold on the symmetry score (SNIPPETS §1 recipe).
pub const SYMMETRY_THRESHOLD: f32 = 0.6;

/// Guard against zero std in the symmetry score.
const EPS: f32 = 1e-6;

/// Floor for quantization scales (mirrors the weight-grid floor).
const SCALE_FLOOR: f32 = 1e-8;

/// Which INT8 grid the score picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActMode {
    /// Signed codes in `[-127, 127]`, zero-point 0.
    Symmetric,
    /// Unsigned codes in `[0, 255]` with a computed zero-point.
    Asymmetric,
}

impl ActMode {
    pub fn name(&self) -> &'static str {
        match self {
            ActMode::Symmetric => "symmetric",
            ActMode::Asymmetric => "asymmetric",
        }
    }

    /// Archive code (`.lieq` v3 act side entry).
    pub fn to_code(self) -> u8 {
        match self {
            ActMode::Symmetric => 0,
            ActMode::Asymmetric => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<ActMode> {
        match c {
            0 => Some(ActMode::Symmetric),
            1 => Some(ActMode::Asymmetric),
            _ => None,
        }
    }
}

/// Activation-quantization parameters for one linear's input. The
/// calibration moments ride along for provenance (and so a reloaded
/// archive can report why a mode was picked).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuant {
    pub mode: ActMode,
    pub scale: f32,
    /// Zero-point on the unsigned grid (0 for symmetric).
    pub zero_point: i32,
    pub mean: f32,
    pub std: f32,
    /// The score `exp(-|mean| / (std + ε))` that picked `mode`.
    pub symmetry: f32,
}

impl ActQuant {
    /// Build parameters from distribution moments (the mode-selection
    /// recipe itself; calibration and dynamic quantization both land
    /// here).
    pub fn from_moments(mean: f32, std: f32, min: f32, max: f32) -> ActQuant {
        let symmetry = (-(mean.abs()) / (std + EPS)).exp();
        if symmetry > SYMMETRY_THRESHOLD {
            let amax = min.abs().max(max.abs()).max(SCALE_FLOOR);
            ActQuant {
                mode: ActMode::Symmetric,
                scale: amax / 127.0,
                zero_point: 0,
                mean,
                std,
                symmetry,
            }
        } else {
            // Zero-inclusive range: real zero must be exactly
            // representable (a zero activation that dequantizes to
            // nonzero would inject bias), and it keeps the zero-point
            // on the unsigned grid.
            let lo = min.min(0.0);
            let hi = max.max(0.0);
            let scale = ((hi - lo) / 255.0).max(SCALE_FLOOR);
            let zp = (-lo / scale).round().clamp(0.0, 255.0) as i32;
            ActQuant { mode: ActMode::Asymmetric, scale, zero_point: zp, mean, std, symmetry }
        }
    }

    /// Dynamic (per-row) parameters: one deterministic sequential pass
    /// over `x` for moments, then [`ActQuant::from_moments`]. Used by
    /// the A8 kernel when the weight carries no calibrated parameters.
    pub fn dynamic(x: &[f32]) -> ActQuant {
        let mut c = ActCalib::new();
        c.observe(x);
        c.finish().unwrap_or(ActQuant {
            mode: ActMode::Symmetric,
            scale: SCALE_FLOOR,
            zero_point: 0,
            mean: 0.0,
            std: 0.0,
            symmetry: 1.0,
        })
    }

    /// Quantize `x` to **centered** codes `q - zp` (what the integer
    /// GEMV consumes): symmetric → `[-127, 127]`, asymmetric →
    /// `[-zp, 255 - zp]`. `out` must be `x.len()` long.
    pub fn quantize_centered(&self, x: &[f32], out: &mut [i32]) {
        debug_assert_eq!(x.len(), out.len());
        match self.mode {
            ActMode::Symmetric => {
                for (o, &v) in out.iter_mut().zip(x) {
                    *o = (v / self.scale).round().clamp(-127.0, 127.0) as i32;
                }
            }
            ActMode::Asymmetric => {
                let zp = self.zero_point;
                for (o, &v) in out.iter_mut().zip(x) {
                    let q = ((v / self.scale).round() as i32 + zp).clamp(0, 255);
                    *o = q - zp;
                }
            }
        }
    }

    /// De-quantize one centered code.
    pub fn dequant(&self, centered: i32) -> f32 {
        centered as f32 * self.scale
    }
}

/// Streaming moment accumulator for calibration batches. f64 sums keep
/// the derived mean/std deterministic and stable across batch sizes
/// (observation order is the caller's fixed capture order).
#[derive(Clone, Copy, Debug)]
pub struct ActCalib {
    n: u64,
    sum: f64,
    sumsq: f64,
    min: f32,
    max: f32,
}

impl Default for ActCalib {
    fn default() -> Self {
        Self::new()
    }
}

impl ActCalib {
    pub fn new() -> ActCalib {
        ActCalib { n: 0, sum: 0.0, sumsq: 0.0, min: f32::INFINITY, max: f32::NEG_INFINITY }
    }

    pub fn observe(&mut self, x: &[f32]) {
        for &v in x {
            self.n += 1;
            self.sum += v as f64;
            self.sumsq += (v as f64) * (v as f64);
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Resolve to parameters; `None` when nothing was observed.
    pub fn finish(&self) -> Option<ActQuant> {
        if self.n == 0 {
            return None;
        }
        let mean = self.sum / self.n as f64;
        let var = (self.sumsq / self.n as f64 - mean * mean).max(0.0);
        Some(ActQuant::from_moments(mean as f32, var.sqrt() as f32, self.min, self.max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn symmetric_branch_centered_data() {
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let a = ActQuant::dynamic(&x);
        assert_eq!(a.mode, ActMode::Symmetric, "zero-mean data must pick symmetric");
        assert_eq!(a.zero_point, 0);
        assert!(a.symmetry > SYMMETRY_THRESHOLD);
        // Roundtrip error bounded by half a step for in-range values.
        let mut q = vec![0i32; x.len()];
        a.quantize_centered(&x, &mut q);
        for (&v, &c) in x.iter().zip(&q) {
            assert!((v - a.dequant(c)).abs() <= a.scale * 0.5 + 1e-6, "v={v}");
        }
    }

    #[test]
    fn asymmetric_branch_skewed_data() {
        let mut rng = Rng::new(12);
        // ReLU-like: heavy mass at a positive offset, tiny spread.
        let x: Vec<f32> = (0..4096).map(|_| 5.0 + 0.3 * rng.normal_f32().abs()).collect();
        let a = ActQuant::dynamic(&x);
        assert_eq!(a.mode, ActMode::Asymmetric, "skewed data must pick asymmetric");
        assert!(a.symmetry <= SYMMETRY_THRESHOLD);
        assert!(a.zero_point >= 0 && a.zero_point <= 255);
        let mut q = vec![0i32; x.len()];
        a.quantize_centered(&x, &mut q);
        for (&v, &c) in x.iter().zip(&q) {
            // Half a step, plus up to another half where the rounded
            // zero-point shifts the grid against the range edge.
            assert!((v - a.dequant(c)).abs() <= a.scale + 1e-5, "v={v}");
            assert!((-a.zero_point..=255 - a.zero_point).contains(&c));
        }
    }

    /// The 0.6 threshold boundary: score exactly at the threshold goes
    /// asymmetric (the branch is strict `>`); nudging the mean toward 0
    /// flips it symmetric.
    #[test]
    fn threshold_boundary() {
        let std = 1.0f32;
        // score = exp(-|mean|/(std+ε)) == 0.6  ⇔  |mean| = -ln(0.6)·(std+ε)
        let boundary_mean = -(0.6f32.ln()) * (std + EPS);
        let at = ActQuant::from_moments(boundary_mean, std, -3.0, 3.0);
        assert!(
            (at.symmetry - SYMMETRY_THRESHOLD).abs() < 1e-5,
            "boundary score {}",
            at.symmetry
        );
        assert_eq!(at.mode, ActMode::Asymmetric, "score == threshold is not > threshold");
        let above = ActQuant::from_moments(boundary_mean * 0.95, std, -3.0, 3.0);
        assert_eq!(above.mode, ActMode::Symmetric);
        let below = ActQuant::from_moments(boundary_mean * 1.05, std, -3.0, 3.0);
        assert_eq!(below.mode, ActMode::Asymmetric);
    }

    #[test]
    fn calib_accumulates_across_batches() {
        let mut one = ActCalib::new();
        one.observe(&[1.0, -1.0, 2.0, -2.0]);
        let mut split = ActCalib::new();
        split.observe(&[1.0, -1.0]);
        split.observe(&[2.0, -2.0]);
        assert_eq!(one.count(), split.count());
        let (a, b) = (one.finish().unwrap(), split.finish().unwrap());
        assert_eq!(a, b, "batched observation must match one-shot");
        assert!(ActCalib::new().finish().is_none());
    }

    #[test]
    fn mode_codes_roundtrip() {
        for m in [ActMode::Symmetric, ActMode::Asymmetric] {
            assert_eq!(ActMode::from_code(m.to_code()), Some(m));
        }
        assert_eq!(ActMode::from_code(9), None);
    }

    #[test]
    fn all_zero_row_is_safe() {
        let a = ActQuant::dynamic(&[0.0; 64]);
        let mut q = vec![0i32; 64];
        a.quantize_centered(&[0.0; 64], &mut q);
        assert!(q.iter().all(|&c| c == 0));
        assert!(a.scale > 0.0);
    }
}
