//! `.lieq` tensor archive reader/writer.
//!
//! Byte-level twin of `python/compile/tensorio.py` — see that module's
//! docstring for the exact layout. Archives store init params (written by
//! the AOT path), trained checkpoints (written by the Rust trainer), and
//! packed quantized weights (written by the quantization pipeline).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{prod, DType, Tensor};

const MAGIC: &[u8; 8] = b"LIEQTNSR";

pub fn write_archive(path: impl AsRef<Path>, tensors: &[(String, Tensor)]) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&1u32.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[t.dtype as u8, t.shape.len() as u8])?;
        for &d in &t.shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for word in t.u32_slice() {
            w.write_all(&word.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn read_archive(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{:?}: bad magic {:?}", path.as_ref(), magic);
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("unsupported archive version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        let mut nb = vec![0u8; nlen];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let dtype = DType::from_code(hdr[0])?;
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n = prod(&shape);
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)?;
        out.push((name, Tensor::from_raw(dtype, shape, &bytes)?));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_dtypes() {
        let dir = std::env::temp_dir().join(format!("lieq_arch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.lieq");
        let tensors = vec![
            ("w".to_string(), Tensor::from_f32(vec![1.5, -2.0, 0.0, 9.0], &[2, 2])),
            ("ids".to_string(), Tensor::from_i32(vec![-1, 2, 3], &[3])),
            ("planes".to_string(), Tensor::from_u32(vec![0xffffffff, 0], &[2, 1])),
            ("scalar".to_string(), Tensor::scalar_f32(0.25)),
        ];
        write_archive(&path, &tensors).unwrap();
        let back = read_archive(&path).unwrap();
        assert_eq!(back.len(), 4);
        for ((n0, t0), (n1, t1)) in tensors.iter().zip(&back) {
            assert_eq!(n0, n1);
            assert_eq!(t0.shape, t1.shape);
            assert_eq!(t0.dtype, t1.dtype);
            assert_eq!(t0.u32_slice(), t1.u32_slice());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("lieq_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.lieq");
        std::fs::write(&path, b"NOTMAGIC....").unwrap();
        assert!(read_archive(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Cross-language check: reads the init archive produced by the Python
    /// AOT path when artifacts exist (skips silently otherwise).
    #[test]
    fn reads_python_written_archive() {
        let path = crate::artifacts_dir().join("q_nano/init.lieq");
        if !path.exists() {
            return;
        }
        let tensors = read_archive(&path).unwrap();
        assert!(tensors.iter().any(|(n, _)| n == "embed"));
        let (_, embed) = tensors.iter().find(|(n, _)| n == "embed").unwrap();
        assert_eq!(embed.shape, vec![512, 128]);
        // Init embeddings are N(0, 0.02): check std is in the right range.
        let vals = embed.as_f32();
        let std = (vals.iter().map(|v| (v * v) as f64).sum::<f64>() / vals.len() as f64).sqrt();
        assert!(std > 0.01 && std < 0.04, "std={std}");
    }
}
