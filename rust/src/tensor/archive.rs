//! `.lieq` tensor archive reader/writer.
//!
//! **Version 1** is the byte-level twin of `python/compile/tensorio.py`
//! — see that module's docstring for the exact layout. v1 archives store
//! init params (written by the AOT path), trained checkpoints (written
//! by the Rust trainer), and simulated-dequantized f32 checkpoints.
//!
//! **Version 2** extends the container with *packed-weight* entries so a
//! quantized deployment archive carries the real bit-plane payload, its
//! per-group quant grid, and (optionally) the derived interleaved lane
//! image — the acceleration layout the LUT/panel kernels stream. A cold
//! `lieq serve` from a v2 archive with persisted lanes performs **zero**
//! `planes_to_interleaved` conversions (`kernel_path_stats().lane_builds`
//! stays flat).
//!
//! v2 layout after the shared `MAGIC | version | count` header, per
//! entry (`u32`/`f32` little-endian throughout):
//!
//! ```text
//! u32 name_len | name bytes | u8 kind
//! kind 0 (tensor):  u8 dtype | u8 ndim | u32 shape[ndim] | u32 data[prod]
//! kind 1 (packed):  u8 bits | u8 flags | u32 k | u32 n | u32 group
//!                   u32 planes[bits * K/32 * N]
//!                   f32 scale[(K/g)*N] | f32 minv[(K/g)*N]
//!                   flags & 2 (v3 act record present):
//!                     u8 mode | f32 scale | i32 zero_point
//!                     f32 mean | f32 std | f32 symmetry
//!                   flags & 1 (lane image present):
//!                     u32 lane_len_bytes | u32 fnv1a_checksum
//!                     u8 lanes[lane_len_bytes]  (== (K/g)*N*lane_len today)
//! ```
//!
//! **Version 3** adds the optional *activation-quantization record*
//! (`flags & 2`) between the weight grid and the lane section: the
//! calibrated INT8 parameters ([`crate::quant::ActQuant`]) the W·A8
//! kernel path consumes. The writer only stamps version 3 when at least
//! one entry carries the record, so archives without activation
//! calibration remain bit-identical v2 files older readers accept.
//!
//! **Version 4** adds the optional *fp16 outlier sidecar* (`flags & 4`)
//! between the act record and the lane section — the sparse half of the
//! mixed packing ([`crate::quant::OutlierSide`]):
//!
//! ```text
//! flags & 4 (v4 outlier sidecar present):
//!   u32 payload_len | u32 fnv1a_checksum
//!   payload: u32 n_out | u32 cols[n_out] | u16 vals_f16[n_out * N]
//! ```
//!
//! The sidecar carries the same framing and degradation contract as the
//! lane section: self-describing length (so a reader can skip or consume
//! a section it cannot interpret without desyncing), checksum over the
//! payload, and every header-derived size overflow-checked and bounded
//! by the file length. A corrupt or truncated sidecar degrades the entry
//! to **dense-only** with a warning — strictly lower fidelity, never
//! garbage. The writer stamps version 4 only when some entry actually
//! carries outliers, so `--outlier-eps 0` archives remain byte-identical
//! v3/v2 files older readers accept.
//!
//! Compat rules: v1 archives stay readable forever (both by
//! [`read_archive`] and [`read_archive_entries`]); [`read_archive`] also
//! accepts a v2 archive containing only tensor entries. Lane-section
//! integrity failures degrade instead of failing the load, losing only
//! the cold-start optimization: a checksum mismatch (any entry) drops
//! that entry's lanes and keeps reading, and a lane section truncated
//! at the archive tail (the final entry) likewise falls back to
//! on-demand conversion. Truncation *before* the final entry's lane
//! section cannot be resynced (lane payloads carry no skip table), so
//! it — like truncation in any mandatory section — is a hard error.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::act::{ActMode, ActQuant};
use crate::quant::pack::{
    f16_bits_to_f32, f32_to_f16_bits, lane_len, OutlierSide, PackedWeight, QuantStats,
};

use super::{DType, Tensor};

const MAGIC: &[u8; 8] = b"LIEQTNSR";
const KIND_TENSOR: u8 = 0;
const KIND_PACKED: u8 = 1;
const FLAG_LANES: u8 = 1;
const FLAG_ACT: u8 = 2;
const FLAG_OUTLIERS: u8 = 4;

/// One named payload of a v2 archive: a plain tensor or a packed
/// quantized weight.
#[derive(Clone, Debug)]
pub enum ArchiveEntry {
    Tensor(Tensor),
    Packed(PackedWeight),
}

impl From<Tensor> for ArchiveEntry {
    fn from(t: Tensor) -> ArchiveEntry {
        ArchiveEntry::Tensor(t)
    }
}

impl From<PackedWeight> for ArchiveEntry {
    fn from(w: PackedWeight) -> ArchiveEntry {
        ArchiveEntry::Packed(w)
    }
}

/// 32-bit FNV-1a over the lane image — cheap, order-sensitive, and
/// mirrors what a one-pass reader can verify while streaming.
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

pub fn write_archive(path: impl AsRef<Path>, tensors: &[(String, Tensor)]) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&1u32.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        write_name(&mut w, name)?;
        write_tensor_body(&mut w, t)?;
    }
    w.flush()?;
    Ok(())
}

/// Write a v2/v3/v4 archive. `persist_lanes` additionally stores each
/// packed entry's interleaved lane image (building it now if it isn't
/// resident — quantize-time work, so serve-time cold loads skip it)
/// plus a checksum. The version stamps the lowest format the payload
/// needs: 4 only when some packed entry carries an outlier sidecar, 3
/// when one carries activation-quantization parameters, else a plain v2
/// archive older readers accept.
pub fn write_archive_v2(
    path: impl AsRef<Path>,
    entries: &[(String, ArchiveEntry)],
    persist_lanes: bool,
) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    let has_act = entries
        .iter()
        .any(|(_, e)| matches!(e, ArchiveEntry::Packed(pw) if pw.act.is_some()));
    let has_outliers = entries
        .iter()
        .any(|(_, e)| matches!(e, ArchiveEntry::Packed(pw) if pw.outlier_cols() > 0));
    let version: u32 = if has_outliers {
        4
    } else if has_act {
        3
    } else {
        2
    };
    w.write_all(MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;
    for (name, entry) in entries {
        write_name(&mut w, name)?;
        match entry {
            ArchiveEntry::Tensor(t) => {
                w.write_all(&[KIND_TENSOR])?;
                write_tensor_body(&mut w, t)?;
            }
            ArchiveEntry::Packed(pw) => {
                w.write_all(&[KIND_PACKED])?;
                let mut flags = if persist_lanes { FLAG_LANES } else { 0 };
                if pw.act.is_some() {
                    flags |= FLAG_ACT;
                }
                if pw.outlier_cols() > 0 {
                    flags |= FLAG_OUTLIERS;
                }
                w.write_all(&[pw.bits, flags])?;
                for dim in [pw.k, pw.n, pw.group_size] {
                    w.write_all(&(dim as u32).to_le_bytes())?;
                }
                for word in &pw.planes {
                    w.write_all(&word.to_le_bytes())?;
                }
                for v in pw.stats.scale.iter().chain(pw.stats.minv.iter()) {
                    w.write_all(&v.to_bits().to_le_bytes())?;
                }
                if let Some(a) = pw.act {
                    w.write_all(&[a.mode.to_code()])?;
                    w.write_all(&a.scale.to_bits().to_le_bytes())?;
                    w.write_all(&a.zero_point.to_le_bytes())?;
                    for v in [a.mean, a.std, a.symmetry] {
                        w.write_all(&v.to_bits().to_le_bytes())?;
                    }
                }
                if flags & FLAG_OUTLIERS != 0 {
                    // Sidecar section, framed like the lane section:
                    // explicit payload length + checksum, so a reader
                    // that cannot use the payload still consumes it
                    // without desyncing, and corruption degrades to
                    // dense-only instead of decoding garbage.
                    if let Some(side) = &pw.outliers {
                        let mut payload =
                            Vec::with_capacity(side.side_bytes(pw.n).saturating_add(4));
                        payload.extend_from_slice(&(side.cols.len() as u32).to_le_bytes());
                        for &c in &side.cols {
                            payload.extend_from_slice(&c.to_le_bytes());
                        }
                        for &v in &side.vals {
                            payload.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
                        }
                        w.write_all(&(payload.len() as u32).to_le_bytes())?;
                        w.write_all(&fnv1a32(&payload).to_le_bytes())?;
                        w.write_all(&payload)?;
                    }
                }
                if persist_lanes {
                    let lanes = pw.interleaved();
                    // Explicit section length (redundant with the layout
                    // formula today) so future readers can skip a lane
                    // section they cannot interpret without a version
                    // bump, and a formula mismatch degrades instead of
                    // desyncing the stream.
                    w.write_all(&(lanes.len() as u32).to_le_bytes())?;
                    w.write_all(&fnv1a32(lanes).to_le_bytes())?;
                    w.write_all(lanes)?;
                }
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a v1–v4 archive as typed entries (v1 yields only
/// `ArchiveEntry::Tensor`s). Packed entries with a valid persisted lane
/// section come back with the lane cache seeded; a corrupt or truncated
/// lane section degrades to on-demand conversion, and a corrupt v4
/// outlier sidecar degrades the entry to dense-only — neither fails the
/// load or decodes garbage. The v3 activation record, by contrast, is
/// tiny and mandatory once flagged: damage there is a hard error.
pub fn read_archive_entries(path: impl AsRef<Path>) -> Result<Vec<(String, ArchiveEntry)>> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let version = read_u32(&mut r)?;
    if !(1..=4).contains(&version) {
        bail!("unsupported archive version {version} (this build reads v1–v4)");
    }
    // Upper bound for any section length parsed from the (untrusted)
    // headers: nothing inside the file can be longer than the file.
    // Turns corrupted dims into a clean error instead of an OOM abort.
    let file_len = std::fs::metadata(path).map(|m| m.len() as usize).unwrap_or(usize::MAX);
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for i in 0..count {
        let name = read_name(&mut r, file_len)?;
        let kind = if version == 1 {
            KIND_TENSOR
        } else {
            let mut k = [0u8; 1];
            r.read_exact(&mut k)?;
            k[0]
        };
        let entry = match kind {
            KIND_TENSOR => ArchiveEntry::Tensor(read_tensor_body(&mut r, file_len)?),
            KIND_PACKED => {
                let last = i + 1 == count;
                ArchiveEntry::Packed(read_packed_body(&mut r, path, &name, last, file_len)?)
            }
            other => bail!("{path:?}: entry {name:?} has unknown kind {other}"),
        };
        out.push((name, entry));
    }
    Ok(out)
}

/// Read a v1 archive (or a v2 archive containing only tensor entries)
/// as named tensors — the checkpoint/init surface `ParamStore` loads.
pub fn read_archive(path: impl AsRef<Path>) -> Result<Vec<(String, Tensor)>> {
    let path = path.as_ref();
    read_archive_entries(path)?
        .into_iter()
        .map(|(name, e)| match e {
            ArchiveEntry::Tensor(t) => Ok((name, t)),
            ArchiveEntry::Packed(_) => bail!(
                "{path:?}: entry {name:?} is a packed weight — read it with \
                 read_archive_entries (packed .lieq v2 archive, not an f32 checkpoint)"
            ),
        })
        .collect()
}

fn write_name(w: &mut impl Write, name: &str) -> Result<()> {
    let nb = name.as_bytes();
    w.write_all(&(nb.len() as u32).to_le_bytes())?;
    w.write_all(nb)?;
    Ok(())
}

/// Read a length-prefixed name, refusing lengths longer than the file
/// itself (untrusted input must error, not allocate gigabytes).
fn read_name(r: &mut impl Read, file_len: usize) -> Result<String> {
    let nlen = read_u32(r)? as usize;
    if nlen > file_len {
        bail!("name length {nlen} exceeds archive size ({file_len} bytes)");
    }
    let mut nb = vec![0u8; nlen];
    r.read_exact(&mut nb)?;
    Ok(String::from_utf8(nb)?)
}

fn write_tensor_body(w: &mut impl Write, t: &Tensor) -> Result<()> {
    w.write_all(&[t.dtype as u8, t.shape.len() as u8])?;
    for &d in &t.shape {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    for word in t.u32_slice() {
        w.write_all(&word.to_le_bytes())?;
    }
    Ok(())
}

fn read_tensor_body(r: &mut impl Read, file_len: usize) -> Result<Tensor> {
    let mut hdr = [0u8; 2];
    r.read_exact(&mut hdr)?;
    let dtype = DType::from_code(hdr[0])?;
    let ndim = hdr[1] as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(read_u32(r)? as usize);
    }
    // Overflow-checked byte count, bounded by the file length (same
    // hardening as the packed branch: corrupt dims error, never OOM).
    let nbytes = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .and_then(|v| v.checked_mul(4))
        .filter(|&b| b <= file_len)
        .ok_or_else(|| {
            anyhow::anyhow!("tensor shape {shape:?} exceeds the archive size ({file_len} bytes)")
        })?;
    let mut bytes = vec![0u8; nbytes];
    r.read_exact(&mut bytes)?;
    Tensor::from_raw(dtype, shape, &bytes)
}

/// Read one packed-weight body (after the kind byte). `last` marks the
/// archive's final entry: a truncated lane section there degrades to
/// on-demand conversion; anywhere else the stream cannot be resynced, so
/// truncation is a hard error. `file_len` bounds every header-derived
/// section length (corrupt dims must error, not OOM).
fn read_packed_body(
    r: &mut impl Read,
    path: &Path,
    name: &str,
    last: bool,
    file_len: usize,
) -> Result<PackedWeight> {
    let mut hdr = [0u8; 2];
    r.read_exact(&mut hdr)?;
    let (bits, flags) = (hdr[0], hdr[1]);
    if bits == 0 || bits > 8 {
        bail!("{path:?}: packed entry {name:?} has invalid bits {bits}");
    }
    let k = read_u32(r)? as usize;
    let n = read_u32(r)? as usize;
    let group = read_u32(r)? as usize;
    if group == 0 || k == 0 || n == 0 || k % group != 0 || k % 32 != 0 {
        bail!("{path:?}: packed entry {name:?} has invalid dims k{k} n{n} g{group}");
    }
    // Header-derived sizes, overflow-checked and bounded by the file
    // length: the planes alone must fit in the remaining bytes.
    let plane_bytes = (bits as usize)
        .checked_mul(k / 32)
        .and_then(|v| v.checked_mul(n))
        .and_then(|v| v.checked_mul(4))
        .filter(|&b| b <= file_len)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{path:?}: packed entry {name:?} dims k{k} n{n} b{bits} exceed the \
                 archive size ({file_len} bytes)"
            )
        })?;
    // Bulk reads (one read_exact per section, not per value): the cold
    // load is exactly the path lane persistence exists to make fast.
    let mut pb = vec![0u8; plane_bytes];
    r.read_exact(&mut pb)?;
    let planes: Vec<u32> = pb
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let grid = (k / group)
        .checked_mul(n)
        .filter(|&v| v.checked_mul(8).is_some_and(|b| b <= file_len))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{path:?}: packed entry {name:?} grid dims k{k} n{n} g{group} exceed \
                 the archive size ({file_len} bytes)"
            )
        })?;
    let mut read_f32s = |len: usize| -> Result<Vec<f32>> {
        let nb = len.checked_mul(4).ok_or_else(|| {
            anyhow::anyhow!("{path:?}: packed entry {name:?} f32 section length overflows")
        })?;
        let mut gb = vec![0u8; nb];
        r.read_exact(&mut gb)?;
        Ok(gb
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    };
    let scale = read_f32s(grid)?;
    let minv = read_f32s(grid)?;
    let stats = QuantStats { scale, minv, groups: k / group, n };

    // v3 act record: small and mandatory once flagged, so damage here is
    // a hard error (unlike the optional lane acceleration section).
    let act = if flags & FLAG_ACT != 0 {
        let mut mode = [0u8; 1];
        r.read_exact(&mut mode)?;
        let mode = ActMode::from_code(mode[0]).ok_or_else(|| {
            anyhow::anyhow!(
                "{path:?}: packed entry {name:?} has unknown act mode code {}",
                mode[0]
            )
        })?;
        let scale = f32::from_bits(read_u32(r)?);
        let zero_point = read_u32(r)? as i32;
        let mean = f32::from_bits(read_u32(r)?);
        let std = f32::from_bits(read_u32(r)?);
        let symmetry = f32::from_bits(read_u32(r)?);
        if !scale.is_finite() || scale <= 0.0 || !(0..=255).contains(&zero_point) {
            bail!(
                "{path:?}: packed entry {name:?} has invalid act params \
                 (scale {scale}, zero_point {zero_point})"
            );
        }
        Some(ActQuant { mode, scale, zero_point, mean, std, symmetry })
    } else {
        None
    };
    // v4 outlier sidecar: optional-fidelity like lanes, so integrity
    // failures degrade the entry to dense-only instead of failing the
    // load (truncation before the tail still hard-errors — no resync).
    let side = if flags & FLAG_OUTLIERS != 0 {
        read_outlier_section(r, path, name, last, file_len, k, n)?
    } else {
        None
    };
    let attach = |pw: PackedWeight| {
        let pw = match act {
            Some(a) => pw.with_act(a),
            None => pw,
        };
        match &side {
            Some(s) => pw.with_outliers(s.clone()),
            None => pw,
        }
    };

    if flags & FLAG_LANES == 0 {
        return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
    }
    // Lane section: `u32 len | u32 checksum | bytes`. Any integrity
    // failure falls back to the lane-less weight (on-demand conversion)
    // rather than decoding garbage lane bytes in the kernels; the
    // explicit length lets the reader skip a section whose size doesn't
    // match this build's layout formula without desyncing the stream.
    // Overflow here can only mean a corrupt header; the MAX sentinel
    // fails the stored-length comparison below and degrades to the
    // lane-less fallback like any other mismatch.
    let expect_bytes = (k / group)
        .checked_mul(n)
        .and_then(|v| v.checked_mul(lane_len(bits, group)))
        .unwrap_or(usize::MAX);
    let mut lb = [0u8; 4];
    let mut cb = [0u8; 4];
    let header = r.read_exact(&mut lb).and_then(|()| r.read_exact(&mut cb));
    if let Err(e) = header {
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} lane section truncated ({e}) — \
                 falling back to on-demand lane conversion"
            );
            return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
        }
        bail!("{path:?}: packed entry {name:?} lane section: {e}");
    }
    let stored_len = u32::from_le_bytes(lb) as usize;
    if stored_len > file_len {
        // Corrupt length field. On the final entry nothing follows the
        // lane section, so this degrades like any other lane-section
        // damage; mid-archive the stream cannot be resynced.
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} lane section length {stored_len} \
                 exceeds the archive size ({file_len} bytes) — falling back to \
                 on-demand lane conversion"
            );
            return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
        }
        bail!(
            "{path:?}: packed entry {name:?} lane section length {stored_len} exceeds \
             the archive size ({file_len} bytes)"
        );
    }
    let mut lane_buf = vec![0u8; stored_len];
    if let Err(e) = r.read_exact(&mut lane_buf) {
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} lane section truncated ({e}) — \
                 falling back to on-demand lane conversion"
            );
            return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
        }
        bail!("{path:?}: packed entry {name:?} lane section: {e}");
    }
    let stored = u32::from_le_bytes(cb);
    let computed = fnv1a32(&lane_buf);
    if stored_len != expect_bytes {
        // Section consumed in full (stream stays synced for the next
        // entry); the image just doesn't match this build's layout.
        log::warn!(
            "{path:?}: packed entry {name:?} lane section is {stored_len} bytes, \
             expected {expect_bytes} — falling back to on-demand lane conversion"
        );
        return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
    }
    if computed != stored {
        log::warn!(
            "{path:?}: packed entry {name:?} lane checksum mismatch \
             (stored {stored:#010x}, computed {computed:#010x}) — falling \
             back to on-demand lane conversion"
        );
        return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
    }
    // Content validity on top of integrity: a checksum-consistent image
    // with out-of-range codes (writer bug, re-checksummed corruption)
    // must not reach the kernels' table indexing.
    if !crate::quant::pack::lanes_codes_in_range(&lane_buf, bits, group) {
        log::warn!(
            "{path:?}: packed entry {name:?} lane image has codes >= 2^{bits} — \
             falling back to on-demand lane conversion"
        );
        return Ok(attach(PackedWeight::new(bits, k, n, group, planes, stats)));
    }
    Ok(attach(PackedWeight::with_lanes(bits, k, n, group, planes, stats, lane_buf)?))
}

/// Read one v4 outlier-sidecar section (after the act record, before
/// the lane section): `u32 payload_len | u32 checksum | payload` with
/// `payload = u32 n_out | u32 cols[n_out] | u16 vals_f16[n_out * n]`.
///
/// Returns `Ok(None)` — dense-only degradation, with a warning — for
/// any integrity or structural failure after the section was consumed
/// in full (checksum mismatch, length/shape mismatch, invalid sidecar),
/// and for truncation on the archive's final entry. Truncation
/// mid-archive cannot be resynced and is a hard error, mirroring the
/// lane-section contract exactly.
fn read_outlier_section(
    r: &mut impl Read,
    path: &Path,
    name: &str,
    last: bool,
    file_len: usize,
    k: usize,
    n: usize,
) -> Result<Option<OutlierSide>> {
    let mut lb = [0u8; 4];
    let mut cb = [0u8; 4];
    let header = r.read_exact(&mut lb).and_then(|()| r.read_exact(&mut cb));
    if let Err(e) = header {
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} outlier sidecar truncated ({e}) — \
                 degrading to dense-only decode"
            );
            return Ok(None);
        }
        bail!("{path:?}: packed entry {name:?} outlier sidecar: {e}");
    }
    let stored_len = u32::from_le_bytes(lb) as usize;
    if stored_len > file_len {
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} outlier sidecar length {stored_len} \
                 exceeds the archive size ({file_len} bytes) — degrading to dense-only \
                 decode"
            );
            return Ok(None);
        }
        bail!(
            "{path:?}: packed entry {name:?} outlier sidecar length {stored_len} exceeds \
             the archive size ({file_len} bytes)"
        );
    }
    let mut payload = vec![0u8; stored_len];
    if let Err(e) = r.read_exact(&mut payload) {
        if last {
            log::warn!(
                "{path:?}: packed entry {name:?} outlier sidecar truncated ({e}) — \
                 degrading to dense-only decode"
            );
            return Ok(None);
        }
        bail!("{path:?}: packed entry {name:?} outlier sidecar: {e}");
    }
    // Section fully consumed from here on: the stream stays synced for
    // the lane section and later entries, so every remaining failure
    // degrades instead of erroring.
    let stored_sum = u32::from_le_bytes(cb);
    let computed = fnv1a32(&payload);
    if computed != stored_sum {
        log::warn!(
            "{path:?}: packed entry {name:?} outlier sidecar checksum mismatch \
             (stored {stored_sum:#010x}, computed {computed:#010x}) — degrading to \
             dense-only decode"
        );
        return Ok(None);
    }
    if payload.len() < 4 {
        log::warn!(
            "{path:?}: packed entry {name:?} outlier sidecar too short \
             ({stored_len} bytes) — degrading to dense-only decode"
        );
        return Ok(None);
    }
    let n_out = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    // Overflow-checked expected payload size; a mismatch (corrupt count,
    // foreign layout) degrades — the bytes are already consumed.
    let expect = n_out
        .checked_mul(4)
        .and_then(|cols_b| {
            n_out
                .checked_mul(n)
                .and_then(|v| v.checked_mul(2))
                .and_then(|vals_b| cols_b.checked_add(vals_b))
        })
        .and_then(|b| b.checked_add(4));
    if expect != Some(stored_len) {
        log::warn!(
            "{path:?}: packed entry {name:?} outlier sidecar is {stored_len} bytes, \
             expected {expect:?} for {n_out} columns — degrading to dense-only decode"
        );
        return Ok(None);
    }
    let mut off = 4usize;
    let mut cols = Vec::with_capacity(n_out);
    for _ in 0..n_out {
        cols.push(u32::from_le_bytes([
            payload[off],
            payload[off + 1],
            payload[off + 2],
            payload[off + 3],
        ]));
        off += 4;
    }
    let vals: Vec<f32> = payload[off..]
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect();
    let side = OutlierSide { cols, vals };
    if n_out == 0 || !side.validate(k, n) {
        log::warn!(
            "{path:?}: packed entry {name:?} outlier sidecar is structurally invalid \
             (unsorted, out-of-range, or non-finite) — degrading to dense-only decode"
        );
        return Ok(None);
    }
    Ok(Some(side))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_weight;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("lieq_arch_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_packed(bits: u8, seed: u64) -> PackedWeight {
        let mut rng = crate::util::Rng::new(seed);
        let (k, n, g) = (64usize, 24usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        pack_weight(&w, k, n, g, bits)
    }

    #[test]
    fn roundtrip_mixed_dtypes() {
        let dir = temp_dir("v1");
        let path = dir.join("t.lieq");
        let tensors = vec![
            ("w".to_string(), Tensor::from_f32(vec![1.5, -2.0, 0.0, 9.0], &[2, 2])),
            ("ids".to_string(), Tensor::from_i32(vec![-1, 2, 3], &[3])),
            ("planes".to_string(), Tensor::from_u32(vec![0xffffffff, 0], &[2, 1])),
            ("scalar".to_string(), Tensor::scalar_f32(0.25)),
        ];
        write_archive(&path, &tensors).unwrap();
        let back = read_archive(&path).unwrap();
        assert_eq!(back.len(), 4);
        for ((n0, t0), (n1, t1)) in tensors.iter().zip(&back) {
            assert_eq!(n0, n1);
            assert_eq!(t0.shape, t1.shape);
            assert_eq!(t0.dtype, t1.dtype);
            assert_eq!(t0.u32_slice(), t1.u32_slice());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = temp_dir("bad");
        let path = dir.join("bad.lieq");
        std::fs::write(&path, b"NOTMAGIC....").unwrap();
        assert!(read_archive(&path).is_err());
        assert!(read_archive_entries(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// v1 archives read identically through the typed-entry reader
    /// (compat: every pre-v2 checkpoint keeps working).
    #[test]
    fn v1_reads_through_entry_reader() {
        let dir = temp_dir("v1compat");
        let path = dir.join("ckpt.lieq");
        let tensors =
            vec![("embed".to_string(), Tensor::from_f32(vec![0.5, 1.5, -2.5, 3.0], &[2, 2]))];
        write_archive(&path, &tensors).unwrap();
        let entries = read_archive_entries(&path).unwrap();
        assert_eq!(entries.len(), 1);
        match &entries[0].1 {
            ArchiveEntry::Tensor(t) => assert_eq!(t.u32_slice(), tensors[0].1.u32_slice()),
            other => panic!("v1 entry must read as Tensor, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// v2 roundtrip: mixed tensor + packed entries, lanes persisted and
    /// seeded on read (zero later conversions), planes/grids exact.
    #[test]
    fn v2_roundtrip_packed_with_lanes() {
        let dir = temp_dir("v2");
        let path = dir.join("q.lieq");
        let pw2 = sample_packed(2, 1);
        let pw5 = sample_packed(5, 2);
        let lanes2 = pw2.interleaved().to_vec();
        let lanes5 = pw5.interleaved().to_vec();
        let entries = vec![
            ("embed".to_string(), ArchiveEntry::from(Tensor::from_f32(vec![1.0, 2.0], &[2]))),
            ("l0".to_string(), ArchiveEntry::from(pw2.clone())),
            ("l1".to_string(), ArchiveEntry::from(pw5.clone())),
        ];
        write_archive_v2(&path, &entries, true).unwrap();
        let back = read_archive_entries(&path).unwrap();
        assert_eq!(back.len(), 3);
        for (want, lanes, idx) in [(&pw2, &lanes2, 1usize), (&pw5, &lanes5, 2)] {
            let ArchiveEntry::Packed(got) = &back[idx].1 else {
                panic!("entry {idx} must be packed");
            };
            assert_eq!(
                (got.bits, got.k, got.n, got.group_size),
                (want.bits, want.k, want.n, want.group_size)
            );
            assert_eq!(got.planes, want.planes);
            assert_eq!(got.stats.scale, want.stats.scale);
            assert_eq!(got.stats.minv, want.stats.minv);
            assert!(got.lanes_built(), "persisted lanes must come back seeded");
            assert_eq!(got.interleaved(), lanes.as_slice());
        }
        // read_archive refuses the packed entries with a pointer to the
        // typed reader.
        let err = read_archive(&path).unwrap_err();
        assert!(format!("{err:#}").contains("read_archive_entries"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// v2 without persisted lanes: packed entries come back lane-less
    /// and convert on demand.
    #[test]
    fn v2_roundtrip_packed_without_lanes() {
        let dir = temp_dir("v2nolanes");
        let path = dir.join("q.lieq");
        let pw = sample_packed(4, 3);
        let entries = vec![("l0".to_string(), ArchiveEntry::from(pw.clone()))];
        write_archive_v2(&path, &entries, false).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(!got.lanes_built());
        assert_eq!(got.interleaved(), pw.interleaved(), "on-demand conversion must agree");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupted lane byte fails the checksum and degrades to
    /// on-demand conversion — never garbage lanes, never a failed load.
    #[test]
    fn v2_corrupt_lane_section_falls_back() {
        let dir = temp_dir("v2corrupt");
        let path = dir.join("q.lieq");
        let pw = sample_packed(3, 4);
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw.clone()))], true)
            .unwrap();
        // Flip the final byte — inside the lane image (it's the last
        // section of the last entry).
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(!got.lanes_built(), "corrupt lanes must be dropped");
        assert_eq!(got.planes, pw.planes, "planes are untouched by lane corruption");
        assert_eq!(got.interleaved(), pw.interleaved(), "fallback conversion must agree");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A checksum-*consistent* lane image with out-of-range codes (a
    /// writer bug, or corruption that re-checksums) is also dropped:
    /// content validity is checked on top of integrity, so garbage can
    /// never reach the kernels' dequant-table indexing.
    #[test]
    fn v2_out_of_range_lane_codes_fall_back() {
        let dir = temp_dir("v2range");
        let path = dir.join("q.lieq");
        let pw = sample_packed(2, 8); // 2-bit nibble lanes: 0xFF is invalid
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw.clone()))], true)
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let lane_bytes = (pw.k / pw.group_size) * pw.n * pw.lane_len();
        let lane_start = bytes.len() - lane_bytes;
        bytes[lane_start + lane_bytes - 1] = 0xFF; // code 15 in a 2-bit image
        let patched_sum = fnv1a32(&bytes[lane_start..]);
        bytes[lane_start - 4..lane_start].copy_from_slice(&patched_sum.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(!got.lanes_built(), "out-of-range lane codes must be dropped");
        assert_eq!(got.interleaved(), pw.interleaved(), "fallback conversion must agree");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// v3 act-record roundtrip, both modes; act-less archives keep
    /// stamping version 2 (older readers accept them unchanged).
    #[test]
    fn v3_act_record_roundtrip() {
        let dir = temp_dir("v3act");
        let path = dir.join("q.lieq");
        let sym = ActQuant::from_moments(0.01, 1.0, -3.0, 3.0);
        let asym = ActQuant::from_moments(5.0, 0.3, 4.0, 6.0);
        assert_ne!(sym.mode, asym.mode, "fixture must exercise both grids");
        let entries = vec![
            ("l0".to_string(), ArchiveEntry::from(sample_packed(3, 9).with_act(sym))),
            ("l1".to_string(), ArchiveEntry::from(sample_packed(5, 10).with_act(asym))),
        ];
        write_archive_v2(&path, &entries, true).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 3);
        let back = read_archive_entries(&path).unwrap();
        for (idx, want) in [(0usize, sym), (1, asym)] {
            let ArchiveEntry::Packed(got) = &back[idx].1 else {
                panic!("entry {idx} must be packed");
            };
            assert_eq!(got.act, Some(want), "entry {idx}");
            assert!(got.lanes_built(), "act record must not disturb the lane section");
        }

        let p2 = dir.join("q2.lieq");
        write_archive_v2(&p2, &[("l0".to_string(), ArchiveEntry::from(sample_packed(3, 9)))], true)
            .unwrap();
        let bytes = std::fs::read(&p2).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 2);
        let back = read_archive_entries(&p2).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(got.act.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupt act-mode code is a hard error (the act record is tiny
    /// and mandatory once flagged — no degrade path like lanes).
    #[test]
    fn v3_bad_act_mode_errors() {
        let dir = temp_dir("v3badact");
        let path = dir.join("q.lieq");
        let pw = sample_packed(2, 11).with_act(ActQuant::from_moments(0.0, 1.0, -2.0, 2.0));
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw))], false).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Act record starts 21 bytes from the end in a lane-less single-
        // entry archive; its first byte is the mode code.
        let mode_at = bytes.len() - 21;
        bytes[mode_at] = 9;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_archive_entries(&path).unwrap_err();
        assert!(format!("{err:#}").contains("act mode"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A lane section truncated mid-image (tail entry) also degrades to
    /// on-demand conversion instead of failing the load.
    #[test]
    fn v2_truncated_lane_section_falls_back() {
        let dir = temp_dir("v2trunc");
        let path = dir.join("q.lieq");
        let pw = sample_packed(2, 5);
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw.clone()))], true)
            .unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(!got.lanes_built());
        assert_eq!(got.interleaved(), pw.interleaved());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Truncation *before* the lane section (inside planes) is a hard
    /// error — fallback only covers the optional acceleration payload.
    #[test]
    fn v2_truncated_planes_still_error() {
        let dir = temp_dir("v2truncplanes");
        let path = dir.join("q.lieq");
        let pw = sample_packed(2, 6);
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw))], false).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..40]).unwrap();
        assert!(read_archive_entries(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    fn sample_packed_outliers(bits: u8, seed: u64, eps: f64) -> PackedWeight {
        let mut rng = crate::util::Rng::new(seed);
        let (k, n, g) = (64usize, 24usize, 32usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        crate::quant::pack::pack_weight_outlier(&w, k, n, g, bits, eps, None)
    }

    /// v4 roundtrip: the outlier sidecar comes back bit-exact (vals are
    /// f16-rounded at extraction, so the u16 storage is lossless), the
    /// lane section is undisturbed, and the version stamps 4.
    #[test]
    fn v4_outlier_sidecar_roundtrip() {
        let dir = temp_dir("v4");
        let path = dir.join("q.lieq");
        let pw = sample_packed_outliers(2, 21, 0.05); // ceil(0.05*64) = 4 cols
        let side = pw.outliers.clone().unwrap();
        assert_eq!(side.cols.len(), 4);
        let entries = vec![
            ("l0".to_string(), ArchiveEntry::from(pw.clone())),
            ("dense".to_string(), ArchiveEntry::from(sample_packed(3, 22))),
        ];
        write_archive_v2(&path, &entries, true).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 4);
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        let got_side = got.outliers.as_ref().expect("sidecar must survive the roundtrip");
        assert_eq!(got_side.cols, side.cols);
        let vals_exact = got_side
            .vals
            .iter()
            .zip(&side.vals)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(vals_exact, "f16 storage must be lossless for f16-rounded vals");
        assert!(got.lanes_built(), "sidecar must not disturb the lane section");
        let dq_exact = got
            .dequantized()
            .iter()
            .zip(&pw.dequantized())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(dq_exact, "mixed decode must match pre-write bitwise");
        let ArchiveEntry::Packed(dense) = &back[1].1 else { panic!("must be packed") };
        assert!(dense.outliers.is_none(), "dense entries carry no sidecar");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `--outlier-eps 0` archives are byte-identical to what the v3/v2
    /// writer produced: same version stamp, same flags, same payload.
    #[test]
    fn v4_eps_zero_is_byte_identical_to_v3() {
        let dir = temp_dir("v4eps0");
        let (pa, pb) = (dir.join("a.lieq"), dir.join("b.lieq"));
        let dense = sample_packed(3, 30);
        let eps0 = sample_packed_outliers(3, 30, 0.0);
        assert!(eps0.outliers.is_none(), "eps 0 must extract nothing");
        write_archive_v2(&pa, &[("l0".to_string(), ArchiveEntry::from(dense))], true).unwrap();
        write_archive_v2(&pb, &[("l0".to_string(), ArchiveEntry::from(eps0))], true).unwrap();
        let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        assert_eq!(ba, bb, "eps=0 archive must be byte-identical to the dense writer");
        assert_eq!(u32::from_le_bytes(ba[8..12].try_into().unwrap()), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupted sidecar byte fails the checksum and degrades the
    /// entry to dense-only — load succeeds, planes untouched.
    #[test]
    fn v4_corrupt_outlier_sidecar_degrades_to_dense() {
        let dir = temp_dir("v4corrupt");
        let path = dir.join("q.lieq");
        let pw = sample_packed_outliers(2, 23, 0.05);
        // No lanes: the sidecar payload is the file's final section.
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw.clone()))], false)
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let lastb = bytes.len() - 1;
        bytes[lastb] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(got.outliers.is_none(), "corrupt sidecar must be dropped");
        assert_eq!(got.planes, pw.planes, "planes untouched by sidecar corruption");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A sidecar truncated at the archive tail degrades to dense-only
    /// instead of failing the load.
    #[test]
    fn v4_truncated_outlier_sidecar_degrades_at_tail() {
        let dir = temp_dir("v4trunc");
        let path = dir.join("q.lieq");
        let pw = sample_packed_outliers(2, 24, 0.05);
        write_archive_v2(&path, &[("l0".to_string(), ArchiveEntry::from(pw))], false).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let back = read_archive_entries(&path).unwrap();
        let ArchiveEntry::Packed(got) = &back[0].1 else { panic!("must be packed") };
        assert!(got.outliers.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A checksum mismatch mid-archive (not the final entry) degrades
    /// that entry to dense-only and keeps the stream synced: the
    /// following entry still reads intact.
    #[test]
    fn v4_mid_archive_sidecar_corruption_keeps_stream_synced() {
        let dir = temp_dir("v4mid");
        let path = dir.join("q.lieq");
        let pw0 = sample_packed_outliers(2, 25, 0.05);
        let pw1 = sample_packed_outliers(3, 26, 0.05);
        let side1 = pw1.outliers.clone().unwrap();
        let entries = vec![
            ("l0".to_string(), ArchiveEntry::from(pw0.clone())),
            ("l1".to_string(), ArchiveEntry::from(pw1)),
        ];
        write_archive_v2(&path, &entries, false).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Entry 0's sidecar payload ends right before entry 1's name
        // length; its last byte sits at a computable offset:
        // header 16 | name 4+2 | kind 1 | bits/flags 2 | dims 12
        // | planes | grid | sidecar 8 + payload.
        let planes = pw0.planes.len() * 4;
        let grid = pw0.stats.scale.len() * 8;
        let payload = 4 + pw0.outlier_bytes();
        let sidecar_end = 16 + 6 + 1 + 2 + 12 + planes + grid + 8 + payload;
        bytes[sidecar_end - 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let back = read_archive_entries(&path).unwrap();
        assert_eq!(back.len(), 2);
        let ArchiveEntry::Packed(got0) = &back[0].1 else { panic!("must be packed") };
        assert!(got0.outliers.is_none(), "corrupt mid-archive sidecar must degrade");
        let ArchiveEntry::Packed(got1) = &back[1].1 else { panic!("must be packed") };
        assert_eq!(
            got1.outliers.as_ref().map(|s| s.cols.clone()),
            Some(side1.cols),
            "entry after a degraded sidecar must read intact"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Cross-language check: reads the init archive produced by the Python
    /// AOT path when artifacts exist (skips silently otherwise).
    #[test]
    fn reads_python_written_archive() {
        let path = crate::artifacts_dir().join("q_nano/init.lieq");
        if !path.exists() {
            return;
        }
        let tensors = read_archive(&path).unwrap();
        assert!(tensors.iter().any(|(n, _)| n == "embed"));
        let (_, embed) = tensors.iter().find(|(n, _)| n == "embed").unwrap();
        assert_eq!(embed.shape, vec![512, 128]);
        // Init embeddings are N(0, 0.02): check std is in the right range.
        let vals = embed.as_f32();
        let std = (vals.iter().map(|v| (v * v) as f64).sum::<f64>() / vals.len() as f64).sqrt();
        assert!(std > 0.01 && std < 0.04, "std={std}");
    }
}
