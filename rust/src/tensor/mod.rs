//! N-dimensional tensors and the `.lieq` archive format.

pub mod archive;

pub use archive::{
    read_archive, read_archive_entries, write_archive, write_archive_v2, ArchiveEntry,
};

use anyhow::{bail, Result};

/// Element type of a [`Tensor`]; mirrors the Python `tensorio` codes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U32 = 2,
}

impl DType {
    pub fn from_code(code: u8) -> Result<DType> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            _ => bail!("unknown dtype code {code}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    pub fn from_name(name: &str) -> Result<DType> {
        Ok(match name {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            _ => bail!("unknown dtype name {name:?}"),
        })
    }
}

/// Dense row-major tensor. All element types are 4 bytes wide, so data is
/// stored as `u32` words and reinterpreted on access — this keeps the
/// archive reader, PJRT literal conversion, and packing code uniform.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub(crate) words: Vec<u32>,
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor { dtype: DType::F32, shape: shape.to_vec(), words: vec![0; prod(shape)] }
    }

    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), prod(shape), "data/shape mismatch");
        Tensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            words: data.into_iter().map(f32::to_bits).collect(),
        }
    }

    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), prod(shape));
        Tensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            words: data.into_iter().map(|v| v as u32).collect(),
        }
    }

    pub fn from_u32(data: Vec<u32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), prod(shape));
        Tensor { dtype: DType::U32, shape: shape.to_vec(), words: data }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor { dtype: DType::F32, shape: vec![], words: vec![v.to_bits()] }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn as_f32(&self) -> Vec<f32> {
        debug_assert_eq!(self.dtype, DType::F32);
        self.words.iter().map(|&w| f32::from_bits(w)).collect()
    }

    /// Zero-copy f32 view (reinterpret; valid because all dtypes are 32-bit
    /// and we only call this on F32 tensors).
    pub fn f32_slice(&self) -> &[f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        // SAFETY: `words` is a live, initialized Vec<u32>; u32 and f32 have
        // identical size/alignment and every bit pattern is a valid f32, so
        // reinterpreting over the same length is sound ('self' stays borrowed).
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const f32, self.words.len()) }
    }

    pub fn f32_slice_mut(&mut self) -> &mut [f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        // SAFETY: as in `f32_slice`; additionally the `&mut self` borrow
        // guarantees exclusive access, so no aliasing view can exist for
        // the lifetime of the returned slice.
        unsafe {
            std::slice::from_raw_parts_mut(self.words.as_mut_ptr() as *mut f32, self.words.len())
        }
    }

    pub fn u32_slice(&self) -> &[u32] {
        &self.words
    }

    pub fn as_i32(&self) -> Vec<i32> {
        self.words.iter().map(|&w| w as i32).collect()
    }

    pub fn raw_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 4);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    pub fn from_raw(dtype: DType, shape: Vec<usize>, bytes: &[u8]) -> Result<Tensor> {
        let n = prod(&shape);
        if bytes.len() != n * 4 {
            bail!("raw data length {} != {} * 4", bytes.len(), n);
        }
        let words = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { dtype, shape, words })
    }

    /// Reshape without copying (element count must match).
    pub fn reshaped(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(prod(shape), self.words.len(), "reshape element mismatch");
        self.shape = shape.to_vec();
        self
    }
}

pub fn prod(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(if shape.is_empty() { 1 } else { 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::from_f32(vec![1.0, -2.5, 3.25, 0.0], &[2, 2]);
        assert_eq!(t.as_f32(), vec![1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.f32_slice()[1], -2.5);
    }

    #[test]
    fn scalar_shape_is_empty_but_has_one_element() {
        let t = Tensor::scalar_f32(7.0);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.len(), 1);
        assert_eq!(prod(&t.shape), 1);
    }

    #[test]
    fn raw_bytes_roundtrip() {
        let t = Tensor::from_u32(vec![0xdeadbeef, 42], &[2]);
        let b = t.raw_bytes();
        let t2 = Tensor::from_raw(DType::U32, vec![2], &b).unwrap();
        assert_eq!(t2.u32_slice(), t.u32_slice());
    }

    #[test]
    fn i32_negative_roundtrip() {
        let t = Tensor::from_i32(vec![-5, 7], &[2]);
        assert_eq!(t.as_i32(), vec![-5, 7]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(vec![1.0, 2.0], &[3]);
    }
}
