//! Seven synthetic zero-shot multiple-choice suites (Table 3 substitutes
//! for PIQA / ARC-e / ARC-c / BoolQ / HellaSwag / WinoGrande / MMLU).
//!
//! Every suite asks the model to assign the lowest continuation NLL to the
//! world-consistent option — the same protocol lm-eval-harness uses. The
//! distractors violate the shared corpus grammar in task-specific ways, so
//! accuracy degrades exactly when quantization noise destroys the layer
//! structure that encodes those regularities.

use anyhow::Result;

use crate::corpus::world::{World, ADJECTIVES, CLASSES, PLACES, VERBS_PAST};
use crate::tokenizer::Bpe;
use crate::util::Rng;

use super::ppl::NllBatcher;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskSuite {
    /// class-attribute plausibility (PIQA-like)
    Plausible,
    /// easy fact completion (ARC-easy-like)
    FactEasy,
    /// harder 4-way fact completion (ARC-challenge-like)
    FactHard,
    /// yes/no fact verification (BoolQ-like)
    BoolFact,
    /// narrative continuation (HellaSwag-like)
    Continuation,
    /// referent resolution (WinoGrande-like)
    Referent,
    /// mixed-domain knowledge (MMLU-like)
    Knowledge,
}

pub const ALL_TASKS: [TaskSuite; 7] = [
    TaskSuite::Plausible,
    TaskSuite::FactEasy,
    TaskSuite::FactHard,
    TaskSuite::BoolFact,
    TaskSuite::Continuation,
    TaskSuite::Referent,
    TaskSuite::Knowledge,
];

impl TaskSuite {
    pub fn name(&self) -> &'static str {
        match self {
            TaskSuite::Plausible => "PIQA*",
            TaskSuite::FactEasy => "ARC-e*",
            TaskSuite::FactHard => "ARC-c*",
            TaskSuite::BoolFact => "BoolQ*",
            TaskSuite::Continuation => "HellaSwag*",
            TaskSuite::Referent => "Winogrande*",
            TaskSuite::Knowledge => "MMLU*",
        }
    }

    pub fn n_options(&self) -> usize {
        match self {
            TaskSuite::BoolFact | TaskSuite::Referent | TaskSuite::Plausible => 2,
            _ => 4,
        }
    }
}

/// One multiple-choice item: shared context, options, gold index.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub context: String,
    pub options: Vec<String>,
    pub gold: usize,
}

/// Generate `n` items of a suite from the world (deterministic in seed).
pub fn generate(world: &World, suite: TaskSuite, n: usize, seed: u64) -> Vec<TaskItem> {
    let mut rng = Rng::new(seed ^ (suite as u64 + 1).wrapping_mul(0x9E37_79B9));
    (0..n).map(|_| generate_item(world, suite, &mut rng)).collect()
}

fn wrong_choice<'a>(rng: &mut Rng, pool: &'a [&'a str], right: &str) -> &'a str {
    loop {
        let cand = rng.choose(pool);
        if *cand != right {
            return cand;
        }
    }
}

fn generate_item(world: &World, suite: TaskSuite, rng: &mut Rng) -> TaskItem {
    let fi = rng.below(world.facts.len());
    let f = world.fact(fi).clone();
    let subj = world.entity(f.subject).to_string();
    let class = CLASSES[f.class];
    let place = PLACES[f.place];
    let verb = VERBS_PAST[f.verb];
    let agent = world.entity(f.agent).to_string();
    let adj = ADJECTIVES[f.adjective];
    let year = f.year;

    match suite {
        TaskSuite::Plausible => {
            // Grammatical plausibility: correct "<class> in <place>" vs
            // scrambled word order.
            let ctx = format!("{subj} is a {adj}");
            let good = format!(" {class} in {place}.");
            let bad = format!(" in {class} {place} a.");
            shuffled(rng, ctx, vec![good, bad])
        }
        TaskSuite::FactEasy => {
            let ctx = format!("{subj} is a");
            let good = format!(" {class} in {place}.");
            let mut opts = vec![good];
            for _ in 0..3 {
                let wc = wrong_choice(rng, CLASSES, class);
                let wp = wrong_choice(rng, PLACES, place);
                opts.push(format!(" {wc} in {wp}."));
            }
            shuffled(rng, ctx, opts)
        }
        TaskSuite::FactHard => {
            // Same class, wrong place/agent — closer distractors.
            let ctx = format!("{subj}, a {class} of");
            let good = format!(" {place}, was {verb} by {agent}.");
            let mut opts = vec![good];
            for _ in 0..3 {
                let wp = wrong_choice(rng, PLACES, place);
                let wa = world.entity(rng.below(world.entities.len())).to_string();
                opts.push(format!(" {wp}, was {verb} by {wa}."));
            }
            shuffled(rng, ctx, opts)
        }
        TaskSuite::BoolFact => {
            let truthy = rng.below(2) == 0;
            let shown_place = if truthy { place } else { wrong_choice(rng, PLACES, place) };
            let ctx = format!("Human: is {subj} a {class} in {shown_place}? Assistant:");
            let opts = vec![" yes.".to_string(), " no.".to_string()];
            TaskItem { context: ctx, options: opts, gold: if truthy { 0 } else { 1 } }
        }
        TaskSuite::Continuation => {
            let ctx = format!("In {year}, {agent} {verb} the {class} {subj}");
            let good = format!(" near {place}.");
            let mut opts = vec![good];
            for _ in 0..3 {
                let wv = wrong_choice(rng, VERBS_PAST, verb);
                opts.push(format!(" near {wv} the.", wv = wv));
            }
            shuffled(rng, ctx, opts)
        }
        TaskSuite::Referent => {
            // Which entity does the pronoun-like slot refer to?
            let ctx = format!("{agent} {verb} {subj}. The {class} is named");
            let good = format!(" {subj}.");
            let bad = format!(" {agent}.");
            shuffled(rng, ctx, vec![good, bad])
        }
        TaskSuite::Knowledge => {
            // Cross-register: dolly-style question about a wiki fact.
            let ctx = format!("Instruction: who {verb} {subj}? Response: it was {verb} by");
            let good = format!(" {agent} in {year}.");
            let mut opts = vec![good];
            for _ in 0..3 {
                let wa = world.entity(rng.below(world.entities.len())).to_string();
                let wy = 1400 + rng.below(600) as u32;
                opts.push(format!(" {wa} in {wy}."));
            }
            shuffled(rng, ctx, opts)
        }
    }
}

fn shuffled(rng: &mut Rng, context: String, mut options: Vec<String>) -> TaskItem {
    // options[0] is gold; shuffle and track it.
    let mut idx: Vec<usize> = (0..options.len()).collect();
    rng.shuffle(&mut idx);
    let gold = idx.iter().position(|&i| i == 0).unwrap();
    let opts = idx.iter().map(|&i| std::mem::take(&mut options[i])).collect();
    TaskItem { context, options: opts, gold }
}

/// Teacher-forced scoring: accuracy = fraction of items whose gold option
/// has the lowest summed NLL over its continuation tokens.
pub fn task_accuracy(
    batcher: &NllBatcher,
    bpe: &Bpe,
    items: &[TaskItem],
) -> Result<f64> {
    let mask = vec![1.0f32; batcher.cfg.n_layers];
    let mut correct = 0usize;
    for item in items {
        let ctx_ids = bpe.encode(&item.context);
        // Build all option sequences, batch-score them together.
        let mut seqs = Vec::with_capacity(item.options.len());
        let mut opt_lens = Vec::with_capacity(item.options.len());
        for opt in &item.options {
            let full = bpe.encode(&format!("{}{}", item.context, opt));
            opt_lens.push(full.len().saturating_sub(ctx_ids.len()));
            seqs.push(full);
        }
        let rows = batcher.nll_rows(&seqs, &mask)?;
        let mut best = (f64::INFINITY, 0usize);
        for (oi, row) in rows.iter().enumerate() {
            // NLL positions for the option tokens: last opt_lens tokens.
            let n = row.len();
            let k = opt_lens[oi].min(n).max(1);
            let score: f64 = row[n - k..].iter().map(|&v| v as f64).sum::<f64>() / k as f64;
            if score < best.0 {
                best = (score, oi);
            }
        }
        if best.1 == item.gold {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(3, 96)
    }

    #[test]
    fn generation_deterministic() {
        let w = world();
        for suite in ALL_TASKS {
            let a = generate(&w, suite, 10, 7);
            let b = generate(&w, suite, 10, 7);
            assert_eq!(a.len(), 10);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.gold, y.gold);
            }
        }
    }

    #[test]
    fn option_counts_and_gold_in_range() {
        let w = world();
        for suite in ALL_TASKS {
            for item in generate(&w, suite, 30, 11) {
                assert_eq!(item.options.len(), suite.n_options(), "{suite:?}");
                assert!(item.gold < item.options.len());
                assert!(!item.context.is_empty());
                for o in &item.options {
                    assert!(!o.is_empty());
                }
            }
        }
    }

    #[test]
    fn gold_positions_shuffled() {
        let w = world();
        let items = generate(&w, TaskSuite::FactEasy, 40, 13);
        let first_gold = items[0].gold;
        assert!(items.iter().any(|i| i.gold != first_gold), "gold never moves");
    }

    #[test]
    fn gold_option_is_world_consistent() {
        let w = world();
        for item in generate(&w, TaskSuite::BoolFact, 20, 17) {
            assert!(item.context.contains("Human:"));
            assert!(item.options[item.gold] == " yes." || item.options[item.gold] == " no.");
        }
    }
}
