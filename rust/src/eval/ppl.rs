//! Perplexity evaluation over the `fwd_nll` artifacts.
//!
//! Passages are right-padded to the artifact sequence length; causal
//! attention makes trailing padding inert for the positions we score, and
//! the per-token NLL matrix lets us mask exactly the real tokens. The
//! skip-mask input doubles as the ΔPPL instrument (diagnostics::ppl_drop).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::{ModelConfig, ParamStore};
use crate::runtime::exec::{engine, Executable};
use crate::tensor::Tensor;

/// Compiled fwd_nll executables + a shared parameter store, reused across
/// calls. Executables come from the engine's compile cache (repeat
/// construction on one thread reloads nothing) and parameters are held
/// behind an `Arc`, so N serving workers share one weight copy and a
/// quantized-variant swap is an `Arc` store, not a model clone.
pub struct NllBatcher {
    pub cfg: ModelConfig,
    params: Arc<ParamStore>,
    short: Executable, // b8_t128
    long: Executable,  // b2_t512
    short_bt: (usize, usize),
    long_bt: (usize, usize),
}

impl NllBatcher {
    pub fn new(cfg: &ModelConfig, params: &ParamStore) -> Result<NllBatcher> {
        Self::new_shared(cfg, Arc::new(params.clone()))
    }

    /// Like [`NllBatcher::new`] but takes shared ownership of the weights
    /// (no copy — the serving runtime hands every worker the same `Arc`).
    pub fn new_shared(cfg: &ModelConfig, params: Arc<ParamStore>) -> Result<NllBatcher> {
        let short = engine().load(cfg.artifact_path("fwd_nll_b8_t128")?)?;
        let long = engine().load(cfg.artifact_path("fwd_nll_b2_t512")?)?;
        let a_short = cfg.artifact("fwd_nll_b8_t128")?;
        let a_long = cfg.artifact("fwd_nll_b2_t512")?;
        Ok(NllBatcher {
            cfg: cfg.clone(),
            params,
            short,
            long,
            short_bt: (a_short.batch, a_short.seq),
            long_bt: (a_long.batch, a_long.seq),
        })
    }

    /// Replace weights (e.g. quantized variant) without recompiling.
    pub fn set_params(&mut self, params: &ParamStore) {
        self.params = Arc::new(params.clone());
    }

    /// Zero-copy variant of [`NllBatcher::set_params`].
    pub fn set_params_shared(&mut self, params: Arc<ParamStore>) {
        self.params = params;
    }

    /// Per-token NLL rows for a batch of passages (all ≤ T for the chosen
    /// artifact). Returns one Vec<f32> of length len-1 per passage.
    pub fn nll_rows(&self, passages: &[Vec<u32>], skip_mask: &[f32]) -> Result<Vec<Vec<f32>>> {
        if skip_mask.len() != self.cfg.n_layers {
            bail!("skip mask length {} != layers {}", skip_mask.len(), self.cfg.n_layers);
        }
        let max_len = passages.iter().map(|p| p.len()).max().unwrap_or(0);
        let (exe, (b, t)) = if max_len <= self.short_bt.1 {
            (&self.short, self.short_bt)
        } else if max_len <= self.long_bt.1 {
            (&self.long, self.long_bt)
        } else {
            bail!("passage length {max_len} exceeds long artifact seq {}", self.long_bt.1)
        };

        let mask_t = Tensor::from_f32(skip_mask.to_vec(), &[self.cfg.n_layers]);
        let mut out = Vec::with_capacity(passages.len());
        for chunk in passages.chunks(b) {
            let mut tokens = vec![0i32; b * t];
            for (row, p) in chunk.iter().enumerate() {
                for (i, &tok) in p.iter().take(t).enumerate() {
                    tokens[row * t + i] = tok as i32;
                }
            }
            let tok_t = Tensor::from_i32(tokens, &[b, t]);
            let mut args: Vec<&Tensor> = vec![&tok_t, &mask_t];
            args.extend(self.params.positional());
            let outs = exe.run(&args)?;
            let nll = &outs[0];
            anyhow::ensure!(nll.shape == vec![b, t - 1], "nll shape {:?}", nll.shape);
            let data = nll.f32_slice();
            for (row, p) in chunk.iter().enumerate() {
                let n_pred = p.len().min(t) - 1;
                out.push(data[row * (t - 1)..row * (t - 1) + n_pred].to_vec());
            }
        }
        Ok(out)
    }
}

/// Mean per-token NLL over passages (PPL = exp of this).
pub fn nll_over_passages(
    batcher: &NllBatcher,
    passages: &[Vec<u32>],
    skip_mask: &[f32],
) -> Result<f64> {
    let rows = batcher.nll_rows(passages, skip_mask)?;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for row in rows {
        for v in row {
            total += v as f64;
            count += 1;
        }
    }
    if count == 0 {
        bail!("no tokens scored");
    }
    Ok(total / count as f64)
}

/// Corpus perplexity with all layers active.
pub fn perplexity(
    cfg: &ModelConfig,
    params: &ParamStore,
    passages: &[Vec<u32>],
) -> Result<f64> {
    let batcher = NllBatcher::new(cfg, params)?;
    let mask = vec![1.0f32; cfg.n_layers];
    Ok(nll_over_passages(&batcher, passages, &mask)?.exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<(ModelConfig, ParamStore)> {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return None;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        Some((cfg, params))
    }

    #[test]
    fn init_ppl_near_uniform() {
        let Some((cfg, params)) = setup() else { return };
        let passages: Vec<Vec<u32>> = (0..4)
            .map(|i| (0..64u32).map(|t| (t * 7 + i) % cfg.vocab as u32).collect())
            .collect();
        let ppl = perplexity(&cfg, &params, &passages).unwrap();
        // Untrained model ≈ uniform over 512 tokens.
        assert!(ppl > 300.0 && ppl < 900.0, "ppl={ppl}");
    }

    #[test]
    fn variable_lengths_are_masked() {
        let Some((cfg, params)) = setup() else { return };
        let batcher = NllBatcher::new(&cfg, &params).unwrap();
        let mask = vec![1.0f32; cfg.n_layers];
        let passages = vec![
            (0..40u32).collect::<Vec<_>>(),
            (0..100u32).map(|t| t % 512).collect::<Vec<_>>(),
        ];
        let rows = batcher.nll_rows(&passages, &mask).unwrap();
        assert_eq!(rows[0].len(), 39);
        assert_eq!(rows[1].len(), 99);
    }

    #[test]
    fn long_bucket_routes_to_t512() {
        let Some((cfg, params)) = setup() else { return };
        let batcher = NllBatcher::new(&cfg, &params).unwrap();
        let mask = vec![1.0f32; cfg.n_layers];
        let passages = vec![(0..300u32).map(|t| t % 512).collect::<Vec<_>>()];
        let rows = batcher.nll_rows(&passages, &mask).unwrap();
        assert_eq!(rows[0].len(), 299);
    }
}
