//! Evaluation harnesses: perplexity (Tables 1–2) and the seven synthetic
//! zero-shot reasoning suites (Table 3, Fig. 5).

pub mod ppl;
pub mod tasks;

pub use ppl::{perplexity, NllBatcher};
pub use tasks::{task_accuracy, TaskSuite, ALL_TASKS};
