//! Figures 1, 2, 4, 5 and the Spearman diagnostic table.

use anyhow::Result;

use crate::coordinator::pipeline::LieqPipeline;
use crate::corpus::{Bucket, Corpus, Domain, ALL_DOMAINS};
use crate::diagnostics::compactness::compact_delta;
use crate::diagnostics::energy::{energy_delta, DEFAULT_K};
use crate::diagnostics::ppl_drop::ppl_drop;
use crate::diagnostics::score::{aggregate, ScoreWeights};
use crate::eval::ppl::NllBatcher;
use crate::kernels::{dq_gemm, gemm_f32};
use crate::linalg::spearman;
use crate::quant::pack::pack_weight;
use crate::quant::Backend;
use crate::util::bench::{black_box, print_table, BenchRunner};
use crate::util::cli::Args;
use crate::util::fmt_metric;
use crate::util::Rng;

use super::helpers::*;

/// Fig. 1: per-layer metric taxonomy across model sizes — the scatter data
/// (normalized ΔPPL̂, Δr̂, ΔÊ per layer per model), dumped as CSV.
pub fn fig1(args: &Args) -> Result<()> {
    let models = args.list("models");
    let models: Vec<String> = if models.is_empty() {
        vec!["q_nano".into(), "q_micro".into(), "q_small".into()]
    } else {
        models
    };
    let opt = base_pipeline_options(args);
    let mut csv = Vec::new();
    let mut rows = Vec::new();
    for model in &models {
        let ctx = model_ctx(model, args)?;
        let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
        let diag = pipe.diagnose(&ctx.params, &opt)?;
        let scores = aggregate(&diag, ScoreWeights::default());
        for l in 0..ctx.cfg.n_layers {
            csv.push(format!(
                "{model},{l},{:.6},{:.6},{:.6},{:.6}",
                scores.ppl_hat[l], scores.compact_hat[l], scores.energy_hat[l], scores.s[l]
            ));
            rows.push(vec![
                model.clone(),
                l.to_string(),
                format!("{:.3}", scores.ppl_hat[l]),
                format!("{:.3}", scores.compact_hat[l]),
                format!("{:.3}", scores.energy_hat[l]),
                format!("{:.3}", scores.s[l]),
            ]);
        }
        // Dispersion summary (paper: small models cluster, larger spread).
        let std = |v: &[f64]| {
            let m = v.iter().sum::<f64>() / v.len() as f64;
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
        };
        log::info!("[{model}] score std {:.3}", std(&scores.s));
    }
    print_table(
        "Fig. 1: layer-wise information taxonomy",
        &["model", "layer", "dPPL^", "dR^", "dE^", "score"],
        &rows,
    );
    write_csv("fig1_taxonomy.csv", "model,layer,ppl_hat,compact_hat,energy_hat,score", &csv)?;
    Ok(())
}

/// Fig. 2: ΔPPL vs depth across the four diagnostic corpora.
pub fn fig2(args: &Args) -> Result<()> {
    let models = args.list("models");
    let models: Vec<String> = if models.is_empty() {
        vec!["q_nano".into(), "q_micro".into(), "q_small".into()]
    } else {
        models
    };
    let n = if args.flag("fast") { 6 } else { args.usize_or("passages", 12) };
    let domains = [Domain::Wiki, Domain::C4, Domain::Dolly, Domain::Hh];
    let mut csv = Vec::new();
    let mut rows = Vec::new();
    for model in &models {
        let ctx = model_ctx(model, args)?;
        for domain in domains {
            let corpus = Corpus::new(domain, 3);
            let passages = corpus.sample_bucket(&ctx.bpe, Bucket::Short, n);
            let pd = ppl_drop(&ctx.cfg, &ctx.params, &passages)?;
            for (l, d) in pd.delta.iter().enumerate() {
                csv.push(format!("{model},{},{l},{:.6},{:.6}", domain.name(), d, pd.base_ppl));
            }
            let curve: Vec<String> = pd.delta.iter().map(|d| format!("{d:.1}")).collect();
            rows.push(vec![model.clone(), domain.name().into(), curve.join(" ")]);
            log::info!("[{model}/{}] base {:.1} dPPL {:?}", domain.name(), pd.base_ppl, curve);
        }
    }
    print_table(
        "Fig. 2: dPPL per layer across corpora",
        &["model", "corpus", "dPPL by layer"],
        &rows,
    );
    write_csv("fig2_ppl_drop.csv", "model,corpus,layer,delta_ppl,base_ppl", &csv)?;
    Ok(())
}

/// Fig. 4: fused dequant-GEMM latency vs sequence length at gate_proj
/// shapes, packed 2/3/4-bit vs f32 (CPU deployment kernels).
///
/// Shapes are the PAPER's gate_proj dimensions (LLaMA-3.2-3B: 3072x8192,
/// LLaMA-3.1-8B: 4096x14336) — the kernel needs no trained weights, and
/// only at out-of-cache sizes is the memory-bound low-bit win measurable
/// (same physics as the paper's HBM argument on the 4090). Our ladder's
/// shapes are included for completeness.
pub fn fig4(args: &Args) -> Result<()> {
    let shapes: Vec<(&str, usize, usize)> = if args.flag("fast") {
        vec![("small(d256)", 256, 704), ("llama3B(d3072)", 3072, 8192)]
    } else {
        vec![
            ("small(d256)", 256, 704),
            ("base(d320)", 320, 896),
            ("llama3B(d3072)", 3072, 8192),
            ("llama8B(d4096)", 4096, 14336),
        ]
    };
    let seqs: Vec<usize> = if args.flag("fast") {
        vec![1, 16, 128]
    } else {
        vec![1, 4, 16, 64, 256, 1024, 2048]
    };
    let mut rng = Rng::new(42);
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut runner = BenchRunner::new(2, if args.flag("fast") { 5 } else { 15 });

    for (tag, k, n) in shapes {
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let packed: Vec<_> = [2u8, 3, 4].iter().map(|&b| pack_weight(&w, k, n, 64, b)).collect();
        for &m in &seqs {
            // Guard the single-core budget: skip GEMMs beyond ~12 GFLOP/call
            // (the decode/low-batch regime is where Fig. 4's claim lives).
            if 2 * m * k * n > 12_000_000_000 {
                continue;
            }
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let mut out = vec![0f32; m * n];
            let f32_stats =
                runner.bench(&format!("{tag} f32 m={m}"), || {
                    gemm_f32(&x, m, &w, k, n, &mut out);
                    black_box(&out);
                });
            let mut row =
                vec![tag.to_string(), m.to_string(), format!("{:.1}", f32_stats.median_us())];
            let mut csv_row = format!("{tag},{m},{:.2}", f32_stats.median_us());
            for pw in &packed {
                let stats = runner.bench(&format!("{tag} b{} m={m}", pw.bits), || {
                    dq_gemm(&x, m, pw, &mut out);
                    black_box(&out);
                });
                row.push(format!("{:.1}", stats.median_us()));
                csv_row.push_str(&format!(",{:.2}", stats.median_us()));
            }
            rows.push(row);
            csv.push(csv_row);
        }
    }
    print_table(
        "Fig. 4: gate_proj latency (us, median) — f32 vs packed 2/3/4-bit",
        &["shape", "seq", "f32", "2-bit", "3-bit", "4-bit"],
        &rows,
    );
    write_csv("fig4_latency.csv", "shape,seq,f32_us,b2_us,b3_us,b4_us", &csv)?;
    Ok(())
}

/// Fig. 5: average zero-shot accuracy as the number of 4-bit layers grows.
pub fn fig5(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let items = if args.flag("fast") { 8 } else { args.usize_or("items", 20) };
    let opt = base_pipeline_options(args);
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let diag = pipe.diagnose(&ctx.params, &opt)?;
    let scores = aggregate(&diag, ScoreWeights::default());

    let (fp_avg, _) = avg_task_accuracy(&ctx, &ctx.params, items)?;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    // Outlier-on companion column: same per-layer bits, but each linear
    // additionally carries the top-1% salient input columns as a sparse
    // fp16 sidecar (Scheme::LieqTopMOutlier — RTN-based simulation of
    // the mixed packing, so the delta isolates the sidecar's effect).
    let out_overhead =
        crate::diagnostics::outlier_overhead_bits(&ctx.cfg, crate::quant::schemes::SCHEME_OUTLIER_EPS);
    for m in 0..=ctx.cfg.n_layers {
        let bits = crate::diagnostics::allocate_top_m(&scores.s, m, 4, 2);
        let q = pipe.quantize_with(&ctx.params, &bits, Backend::Gptq)?;
        let (avg, _) = avg_task_accuracy(&ctx, &q, items)?;
        let q_out = crate::quant::schemes::apply_scheme(
            &ctx.cfg,
            &ctx.params,
            crate::quant::schemes::Scheme::LieqTopMOutlier,
            Some(&bits),
        )?;
        let (avg_out, _) = avg_task_accuracy(&ctx, &q_out, items)?;
        let avg_bits = bits.avg_bits(&ctx.cfg);
        let diff = (avg - fp_avg) * 100.0;
        log::info!(
            "m={m} avg_bits {avg_bits:.2} acc {:.1}% (diff {diff:+.1}; \
             +out1% {:.1}% at {:.2} bits)",
            avg * 100.0,
            avg_out * 100.0,
            avg_bits + out_overhead
        );
        rows.push(vec![
            m.to_string(),
            format!("{avg_bits:.2}"),
            format!("{:.1}", avg * 100.0),
            format!("{diff:+.1}"),
            format!("{:.1}", avg_out * 100.0),
        ]);
        csv.push(format!(
            "{m},{avg_bits:.3},{:.4},{diff:.4},{:.4}",
            avg * 100.0,
            avg_out * 100.0
        ));
    }
    rows.push(vec![
        "FP16".into(),
        "16.00".into(),
        format!("{:.1}", fp_avg * 100.0),
        "+0.0".into(),
        format!("{:.1}", fp_avg * 100.0),
    ]);
    print_table(
        &format!("Fig. 5: accuracy vs #4-bit layers on {model}"),
        &["m (4-bit layers)", "avg bits", "avg acc %", "diff vs FP16", "acc +out1% %"],
        &rows,
    );
    write_csv("fig5_bit_sweep.csv", "m,avg_bits,avg_acc,diff_vs_fp16,avg_acc_out1pct", &csv)?;
    Ok(())
}

/// Spearman correlations ρ(ΔPPL, Δr) and ρ(ΔPPL, ΔE_k) per corpus/bucket
/// (the paper's Diagnostic Settings protocol).
pub fn spearman_table(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let n = if args.flag("fast") { 6 } else { args.usize_or("passages", 12) };
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let cap = pipe.capture(&ctx.params)?;
    let dr = compact_delta(&ctx.cfg, &ctx.params, &cap, 3)?;
    let de = energy_delta(&ctx.cfg, &ctx.params, &cap, DEFAULT_K, 3)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &domain in ALL_DOMAINS.iter().take(4) {
        for bucket in [Bucket::Short, Bucket::Long] {
            let corpus = Corpus::new(domain, 3);
            let passages = corpus.sample_bucket(&ctx.bpe, bucket, n);
            let pd = ppl_drop(&ctx.cfg, &ctx.params, &passages)?;
            let dr_abs: Vec<f64> = dr.iter().map(|v| v.abs()).collect();
            let rho_r = spearman(&pd.delta, &dr_abs);
            let rho_e = spearman(&pd.delta, &de);
            rows.push(vec![
                domain.name().to_string(),
                bucket.name().to_string(),
                format!("{rho_r:+.3}"),
                format!("{rho_e:+.3}"),
                fmt_metric(pd.base_ppl),
            ]);
            csv.push(format!(
                "{},{},{rho_r},{rho_e},{}",
                domain.name(),
                bucket.name(),
                pd.base_ppl
            ));
        }
    }
    print_table(
        &format!("Spearman correlations on {model}"),
        &["corpus", "bucket", "rho(dPPL,|dR|)", "rho(dPPL,dE)", "base ppl"],
        &rows,
    );
    write_csv("spearman.csv", "corpus,bucket,rho_r,rho_e,base_ppl", &csv)?;
    Ok(())
}

/// Headline e2e: train → diagnose → allocate → quantize → recovery report
/// (the paper's "95.9% of FP16 at 2.05 bits" claim, on our testbed).
pub fn e2e(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let items = if args.flag("fast") { 10 } else { args.usize_or("items", 25) };
    let opt = base_pipeline_options(args);
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);

    let result = pipe.run(&ctx.params, &opt)?;
    let q = pipe.quantize_with(&ctx.params, &result.bits, opt.backend)?;
    let (fp_acc, _) = avg_task_accuracy(&ctx, &ctx.params, items)?;
    let (q_acc, per) = avg_task_accuracy(&ctx, &q, items)?;
    let recovery = q_acc / fp_acc * 100.0;

    println!("\n=== LieQ end-to-end on {model} ===");
    let rounded: Vec<f64> = result.scores.s.iter().map(|v| (v * 100.0).round() / 100.0).collect();
    println!("scores: {rounded:?}");
    println!("bits:   {:?} (avg {:.2})", result.bits.0, result.avg_bits);
    println!(
        "PPL:    FP16 {} -> LieQ {}",
        fmt_metric(result.fp16_ppl),
        fmt_metric(result.quant_ppl)
    );
    println!(
        "tasks:  FP16 {:.1}% -> LieQ {:.1}%  => recovery {recovery:.1}%",
        fp_acc * 100.0,
        q_acc * 100.0
    );
    for (name, acc) in per {
        println!("  {name:<12} {:.1}%", acc * 100.0);
    }
    println!(
        "diagnose {:.1}s, quantize {:.1}s",
        result.secs_diagnose, result.secs_quantize
    );
    write_csv(
        "e2e.csv",
        "model,avg_bits,fp16_ppl,lieq_ppl,fp16_acc,lieq_acc,recovery",
        &[format!(
            "{model},{:.3},{:.4},{:.4},{:.4},{:.4},{recovery:.2}",
            result.avg_bits, result.fp16_ppl, result.quant_ppl, fp_acc, q_acc
        )],
    )?;
    Ok(())
}
