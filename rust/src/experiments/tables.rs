//! Tables 1–3: perplexity and zero-shot comparisons across the method grid.

use anyhow::Result;

use crate::corpus::Domain;
use crate::eval::ppl::NllBatcher;
use crate::quant::{Backend, LayerBits};
use crate::util::bench::print_table;
use crate::util::cli::Args;
use crate::util::fmt_metric;

use super::helpers::*;

/// Tables 1 (family Q) and 2 (family L): zero-shot PPL on wiki-like and
/// c4-like corpora, FP16 vs {GPTQ, AWQ, RTN, PB-LLM, SliM-LLM, LieQ} at
/// 2- and 3-bit rows.
pub fn ppl_table(args: &Args, models: &[&str], table_name: &str) -> Result<()> {
    let n_eval = n_passages(args);
    let opt = base_pipeline_options(args);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut csv: Vec<String> = Vec::new();

    // Header: Precision | Method | <model> wiki... | <model> c4...
    let mut header: Vec<String> = vec!["Bits".into(), "Method".into()];
    for m in models {
        header.push(format!("{m}/wiki"));
    }
    for m in models {
        header.push(format!("{m}/c4"));
    }

    // Column-major evaluation: per model, compute all methods.
    let mut table: std::collections::BTreeMap<String, Vec<String>> = Default::default();
    let mut row_order: Vec<String> = vec!["FP16|-".to_string()];
    for bits in [2u8, 3] {
        for b in TABLE_BACKENDS {
            row_order.push(format!("{bits}|{}", b.name()));
        }
        row_order.push(format!("{bits}|LieQ"));
    }

    for model in models {
        let ctx = model_ctx(model, args)?;
        let wiki = eval_passages(&ctx, Domain::Wiki, n_eval);
        let c4 = eval_passages(&ctx, Domain::C4, n_eval);
        let mut batcher = NllBatcher::new(&ctx.cfg, &ctx.params)?;

        // FP16 row.
        let fp_wiki = ppl_with(&mut batcher, &ctx.params, &wiki)?;
        let fp_c4 = ppl_with(&mut batcher, &ctx.params, &c4)?;
        table.entry("FP16|-".into()).or_default().push(format!("{fp_wiki:.6}|{fp_c4:.6}"));
        log::info!("[{model}] FP16 wiki {fp_wiki:.2} c4 {fp_c4:.2}");

        for bits in [2u8, 3] {
            for backend in TABLE_BACKENDS {
                let q = quantize_uniform(&ctx, backend, bits)?;
                let pw = ppl_with(&mut batcher, &q, &wiki)?;
                let pc = ppl_with(&mut batcher, &q, &c4)?;
                table
                    .entry(format!("{bits}|{}", backend.name()))
                    .or_default()
                    .push(format!("{pw:.6}|{pc:.6}"));
                log::info!("[{model}] {} {bits}bit wiki {pw:.1} c4 {pc:.1}", backend.name());
            }
            // LieQ row (lo=bits, top-m layers at 4-bit).
            let (lbits, avg) = lieq_bits_for_row(&ctx, &opt, bits)?;
            let pipe = crate::coordinator::pipeline::LieqPipeline::new(&ctx.cfg, &ctx.bpe);
            let q = pipe.quantize_with(&ctx.params, &lbits, opt.backend)?;
            let pw = ppl_with(&mut batcher, &q, &wiki)?;
            let pc = ppl_with(&mut batcher, &q, &c4)?;
            table
                .entry(format!("{bits}|LieQ"))
                .or_default()
                .push(format!("{pw:.6}|{pc:.6}"));
            log::info!("[{model}] LieQ {avg:.2}bit wiki {pw:.1} c4 {pc:.1}");
        }
    }

    // Assemble printable rows.
    for key in &row_order {
        let (bits, method) = key.split_once('|').unwrap();
        let mut row = vec![bits.to_string(), method.to_string()];
        let cells = table.get(key).cloned().unwrap_or_default();
        let wiki_cells: Vec<String> =
            cells.iter().map(|c| c.split('|').next().unwrap().to_string()).collect();
        let c4_cells: Vec<String> =
            cells.iter().map(|c| c.split('|').nth(1).unwrap().to_string()).collect();
        for w in &wiki_cells {
            row.push(fmt_metric(w.parse().unwrap_or(f64::NAN)));
        }
        for c in &c4_cells {
            row.push(fmt_metric(c.parse().unwrap_or(f64::NAN)));
        }
        csv.push(row.join(","));
        rows.push(row);
    }

    print_table(
        table_name,
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        &rows,
    );
    write_csv(
        &format!("{}.csv", table_name.replace(' ', "_").to_lowercase()),
        &header.join(","),
        &csv,
    )?;
    Ok(())
}

pub fn table1(args: &Args) -> Result<()> {
    let models = args.list("models");
    let models: Vec<&str> = if !models.is_empty() {
        models.iter().map(|s| s.as_str()).collect::<Vec<_>>()
    } else if args.flag("fast") {
        vec!["q_nano", "q_micro"]
    } else {
        vec!["q_nano", "q_micro", "q_small", "q_base"]
    };
    ppl_table(args, &models, "Table 1: Qwen3-family zero-shot PPL (wiki/c4)")
}

pub fn table2(args: &Args) -> Result<()> {
    let models = args.list("models");
    let models: Vec<&str> = if !models.is_empty() {
        models.iter().map(|s| s.as_str()).collect::<Vec<_>>()
    } else if args.flag("fast") {
        vec!["l_nano"]
    } else {
        vec!["l_nano", "l_micro", "l_small"]
    };
    ppl_table(args, &models, "Table 2: LLaMA3-family zero-shot PPL (wiki/c4)")
}

/// Table 3: zero-shot reasoning accuracy across the seven synthetic suites.
pub fn table3(args: &Args) -> Result<()> {
    let models = args.list("models");
    let models: Vec<String> = if !models.is_empty() {
        models
    } else if args.flag("fast") {
        vec!["q_nano".into()]
    } else {
        vec!["q_small".into(), "l_small".into()]
    };
    let items = if args.flag("fast") { 12 } else { args.usize_or("items", 30) };
    let opt = base_pipeline_options(args);

    let mut header = vec!["Model".to_string(), "Bits".into(), "Method".into()];
    header.extend(crate::eval::tasks::ALL_TASKS.iter().map(|t| t.name().to_string()));
    header.push("Avg".into());
    let mut rows = Vec::new();
    let mut csv = Vec::new();

    for model in &models {
        let ctx = model_ctx(model, args)?;
        let mut add_row = |bits: String,
                           method: &str,
                           params: &crate::model::ParamStore|
         -> Result<()> {
            let (avg, per) = avg_task_accuracy(&ctx, params, items)?;
            let mut row = vec![model.clone(), bits, method.to_string()];
            for (_, acc) in &per {
                row.push(format!("{:.1}", acc * 100.0));
            }
            row.push(format!("{:.1}", avg * 100.0));
            log::info!("[{model}] {method} avg {:.1}%", avg * 100.0);
            csv.push(row.join(","));
            rows.push(row);
            Ok(())
        };

        add_row("FP16".into(), "-", &ctx.params)?;
        for bits in [2u8, 3] {
            for backend in [Backend::Gptq, Backend::Awq] {
                let q = quantize_uniform(&ctx, backend, bits)?;
                add_row(format!("{bits}"), backend.name(), &q)?;
            }
            let (lbits, avg_bits) = lieq_bits_for_row(&ctx, &opt, bits)?;
            let pipe = crate::coordinator::pipeline::LieqPipeline::new(&ctx.cfg, &ctx.bpe);
            let q = pipe.quantize_with(&ctx.params, &lbits, opt.backend)?;
            add_row(format!("{avg_bits:.2}"), "LieQ", &q)?;
        }
    }

    print_table(
        "Table 3: zero-shot reasoning accuracy (%)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        &rows,
    );
    write_csv("table3.csv", &header.join(","), &csv)?;
    Ok(())
}

/// The Fig. 3 scheme ablation (structured mixed-precision variants).
pub fn ablate_schemes(args: &Args) -> Result<()> {
    use crate::quant::schemes::{apply_scheme, scheme_avg_bits, Scheme};
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let n_eval = n_passages(args);
    let wiki = eval_passages(&ctx, Domain::Wiki, n_eval);
    let mut batcher = NllBatcher::new(&ctx.cfg, &ctx.params)?;
    let fp = ppl_with(&mut batcher, &ctx.params, &wiki)?;

    let opt = base_pipeline_options(args);
    let (lieq_bits, _) = lieq_bits_for_row(&ctx, &opt, 2)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    rows.push(vec!["fp16".to_string(), "16.00".into(), fmt_metric(fp)]);
    csv.push(format!("fp16,16.0,{fp}"));
    for scheme in [
        Scheme::ElementOutlierFp16,
        Scheme::GroupMixed13,
        Scheme::BlockAttn4Mlp2,
        Scheme::LieqTopM,
        Scheme::LieqTopMOutlier,
    ] {
        let q = apply_scheme(&ctx.cfg, &ctx.params, scheme, Some(&lieq_bits))?;
        let ppl = ppl_with(&mut batcher, &q, &wiki)?;
        let bits = scheme_avg_bits(&ctx.cfg, scheme, Some(&lieq_bits));
        log::info!("scheme {} -> ppl {}", scheme.name(), fmt_metric(ppl));
        rows.push(vec![scheme.name().to_string(), format!("{bits:.2}"), fmt_metric(ppl)]);
        csv.push(format!("{},{bits:.3},{ppl}", scheme.name()));
    }
    print_table(
        &format!("Fig. 3 scheme ablation on {model} (wiki PPL)"),
        &["scheme", "avg bits", "ppl"],
        &rows,
    );
    write_csv("ablate_schemes.csv", "scheme,avg_bits,ppl", &csv)?;
    let _ = LayerBits::uniform(1, 2); // keep import used in all cfgs
    Ok(())
}
