//! Shared experiment plumbing: model setup with cached training, held-out
//! evaluation sets, method sweeps, and result persistence.

use anyhow::Result;

use crate::coordinator::pipeline::{LieqPipeline, PipelineOptions};
use crate::corpus::{self, Bucket, Corpus, Domain};
use crate::eval::ppl::{nll_over_passages, NllBatcher};
use crate::eval::tasks::{generate, task_accuracy, ALL_TASKS};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::{Backend, LayerBits};
use crate::tokenizer::Bpe;
use crate::train::{trained_params, TrainOptions};
use crate::util::cli::Args;

/// Corpus/world seed shared with training and diagnostics (same universe).
pub const WORLD_SEED: u64 = 3;
/// Passage index offset for evaluation: held-out *text* from the same
/// world, disjoint from calibration indices (0..) and the training stream
/// (1_114_112..).
pub const EVAL_OFFSET: usize = 50_000;

pub struct ModelCtx {
    pub cfg: ModelConfig,
    pub bpe: Bpe,
    pub params: ParamStore,
}

/// Load config + tokenizer + cached trained checkpoint (training it on
/// first use).
pub fn model_ctx(name: &str, args: &Args) -> Result<ModelCtx> {
    let root = crate::artifacts_dir();
    let cfg = ModelConfig::load(&root, name)?;
    let bpe = corpus::shared_tokenizer(&root, cfg.vocab, 3);
    let steps = args.usize_or("steps", crate::cmds::default_steps(name));
    let opt = TrainOptions { steps, ..Default::default() };
    let (params, report) = trained_params(&cfg, &bpe, &opt)?;
    if let Some(r) = report {
        log::info!(
            "[{name}] trained {} steps, final loss {:.3} ({:.0} tok/s)",
            r.steps,
            r.final_loss,
            r.tokens_per_sec
        );
    }
    Ok(ModelCtx { cfg, bpe, params })
}

/// Held-out passages for PPL evaluation (same world, unseen text).
pub fn eval_passages(ctx: &ModelCtx, domain: Domain, n: usize) -> Vec<Vec<u32>> {
    Corpus::new(domain, WORLD_SEED).sample_bucket_from(&ctx.bpe, Bucket::Short, n, EVAL_OFFSET)
}

/// PPL of a (possibly quantized) ParamStore on pre-sampled passages,
/// reusing a compiled batcher.
pub fn ppl_with(
    batcher: &mut NllBatcher,
    params: &ParamStore,
    passages: &[Vec<u32>],
) -> Result<f64> {
    batcher.set_params(params);
    let mask = vec![1.0f32; batcher.cfg.n_layers];
    Ok(nll_over_passages(batcher, passages, &mask)?.exp())
}

/// The method grid of Tables 1–3. `OmniQuant` and codebook methods
/// (AQLM/QUIP#) are gradient/codebook-based and out of scope — reported
/// as `-` rows, mirroring the paper's own missing entries.
pub const TABLE_BACKENDS: [Backend; 5] =
    [Backend::Gptq, Backend::Awq, Backend::Rtn, Backend::PbLlm, Backend::SlimLlm];

/// Produce the LieQ allocation for a target "bit row" of the tables:
/// row `2` → lo=2/hi=4 with top-m=1 (the paper's 2.05-bit extreme config);
/// row `3` → lo=3/hi=4 with top-m=1.
pub fn lieq_bits_for_row(
    ctx: &ModelCtx,
    opt_base: &PipelineOptions,
    row_bits: u8,
) -> Result<(LayerBits, f64)> {
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let mut opt = opt_base.clone();
    opt.lo_bits = row_bits;
    opt.hi_bits = 4;
    let diag = pipe.diagnose(&ctx.params, &opt)?;
    let scores = crate::diagnostics::score::aggregate(&diag, opt.weights);
    let bits = crate::diagnostics::allocate_top_m(&scores.s, opt.top_m, opt.hi_bits, opt.lo_bits);
    let avg = bits.avg_bits(&ctx.cfg);
    Ok((bits, avg))
}

/// Quantize with a backend at uniform bits (baseline rows).
pub fn quantize_uniform(ctx: &ModelCtx, backend: Backend, bits: u8) -> Result<ParamStore> {
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let lb = LayerBits::uniform(ctx.cfg.n_layers, bits);
    pipe.quantize_with(&ctx.params, &lb, backend)
}

/// Average zero-shot accuracy over all seven suites.
pub fn avg_task_accuracy(
    ctx: &ModelCtx,
    params: &ParamStore,
    items_per_suite: usize,
) -> Result<(f64, Vec<(String, f64)>)> {
    let batcher = NllBatcher::new(&ctx.cfg, params)?;
    let world = Corpus::new(Domain::Wiki, 3).world;
    let mut per = Vec::new();
    let mut total = 0.0;
    for suite in ALL_TASKS {
        let items = generate(&world, suite, items_per_suite, 2024);
        let acc = task_accuracy(&batcher, &ctx.bpe, &items)?;
        per.push((suite.name().to_string(), acc));
        total += acc;
    }
    Ok((total / ALL_TASKS.len() as f64, per))
}

/// Results directory (CSV/JSON dumps for every experiment).
pub fn results_dir() -> std::path::PathBuf {
    let dir = crate::artifacts_dir().parent().unwrap_or(std::path::Path::new(".")).join("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<()> {
    let safe: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect();
    let path = results_dir().join(safe);
    let mut s = String::from(header);
    s.push('\n');
    for r in rows {
        s.push_str(r);
        s.push('\n');
    }
    std::fs::write(&path, s)?;
    log::info!("wrote {}", path.display());
    Ok(())
}

/// Standard passage count, honoring --passages / --fast.
pub fn n_passages(args: &Args) -> usize {
    if args.flag("fast") {
        6
    } else {
        args.usize_or("passages", 16)
    }
}

/// Pipeline options shared by table/figure drivers.
pub fn base_pipeline_options(args: &Args) -> PipelineOptions {
    let mut opt = PipelineOptions::default();
    opt.diag_passages = if args.flag("fast") { 6 } else { args.usize_or("diag-passages", 12) };
    opt.top_m = args.usize_or("top-m", 1);
    if let Some(b) = args.get("backend").and_then(Backend::from_name) {
        opt.backend = b;
    }
    opt
}
