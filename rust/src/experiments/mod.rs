//! Paper experiment drivers — one function per table/figure (DESIGN.md §4).

pub mod ablations;
pub mod figures;
pub mod helpers;
pub mod tables;

use crate::util::cli::Args;
use anyhow::Result;

pub fn table1(args: &Args) -> Result<()> { tables::table1(args) }
pub fn table2(args: &Args) -> Result<()> { tables::table2(args) }
pub fn table3(args: &Args) -> Result<()> { tables::table3(args) }
pub fn ablate_schemes(args: &Args) -> Result<()> { tables::ablate_schemes(args) }
pub fn fig1(args: &Args) -> Result<()> { figures::fig1(args) }
pub fn fig2(args: &Args) -> Result<()> { figures::fig2(args) }
pub fn fig4(args: &Args) -> Result<()> { figures::fig4(args) }
pub fn fig5(args: &Args) -> Result<()> { figures::fig5(args) }
pub fn spearman(args: &Args) -> Result<()> { figures::spearman_table(args) }
pub fn e2e(args: &Args) -> Result<()> { figures::e2e(args) }
pub fn ablate_alloc(args: &Args) -> Result<()> { ablations::ablate_alloc(args) }
pub fn ablate_weights(args: &Args) -> Result<()> { ablations::ablate_weights(args) }
pub fn pareto(args: &Args) -> Result<()> { ablations::pareto(args) }
