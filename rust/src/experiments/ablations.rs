//! Design-choice ablations beyond the paper's figures (DESIGN.md §4 note):
//!
//! * **allocation policy** — does the LieQ score actually pick the right
//!   layers? Compare: top-m by s_ℓ (LieQ), bottom-m (adversarial), random
//!   m, first-m (prefix heuristic), greedy-by-quant-error. Same budget,
//!   same backend; only the *choice of protected layers* differs.
//! * **score weights** — α/β/γ sensitivity: each single-metric score vs
//!   the balanced default.

use anyhow::Result;

use crate::coordinator::pipeline::LieqPipeline;
use crate::corpus::Domain;
use crate::diagnostics::allocate_top_m;
use crate::diagnostics::score::{aggregate, ScoreWeights};
use crate::eval::ppl::NllBatcher;
use crate::quant::{Backend, LayerBits};
use crate::util::bench::print_table;
use crate::util::cli::Args;
use crate::util::{fmt_metric, Rng};

use super::helpers::*;

pub fn ablate_alloc(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let n_eval = n_passages(args);
    let m = args.usize_or("top-m", 1);
    let opt = base_pipeline_options(args);
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);

    let diag = pipe.diagnose(&ctx.params, &opt)?;
    let scores = aggregate(&diag, ScoreWeights::default());
    let l = ctx.cfg.n_layers;

    // Candidate policies -> bit allocations at identical budget (m hi-bit
    // layers).
    let mut rng = Rng::new(2024);
    let inverse: Vec<f64> = scores.s.iter().map(|s| -s).collect();
    let random: Vec<f64> = (0..l).map(|_| rng.f64()).collect();
    let prefix: Vec<f64> = (0..l).map(|i| (l - i) as f64).collect();
    let policies: Vec<(&str, LayerBits)> = vec![
        ("lieq (top-m by s)", allocate_top_m(&scores.s, m, 4, 2)),
        ("inverse (bottom-m)", allocate_top_m(&inverse, m, 4, 2)),
        ("random-m", allocate_top_m(&random, m, 4, 2)),
        ("first-m layers", allocate_top_m(&prefix, m, 4, 2)),
        ("uniform 2-bit", LayerBits::uniform(l, 2)),
    ];

    let wiki = eval_passages(&ctx, Domain::Wiki, n_eval);
    let mut batcher = NllBatcher::new(&ctx.cfg, &ctx.params)?;
    let fp16 = ppl_with(&mut batcher, &ctx.params, &wiki)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    rows.push(vec!["fp16".into(), "16.00".into(), fmt_metric(fp16), "-".into()]);
    for (name, bits) in policies {
        let q = pipe.quantize_with(&ctx.params, &bits, Backend::Gptq)?;
        let ppl = ppl_with(&mut batcher, &q, &wiki)?;
        log::info!("alloc {name}: bits {:?} ppl {}", bits.0, fmt_metric(ppl));
        rows.push(vec![
            name.to_string(),
            format!("{:.2}", bits.avg_bits(&ctx.cfg)),
            fmt_metric(ppl),
            format!("{:?}", bits.0),
        ]);
        csv.push(format!("{name},{:.3},{ppl}", bits.avg_bits(&ctx.cfg)));
    }
    print_table(
        &format!("Allocation-policy ablation on {model} (GPTQ backend, m={m})"),
        &["policy", "avg bits", "wiki ppl", "bits/layer"],
        &rows,
    );
    write_csv("ablate_alloc.csv", "policy,avg_bits,ppl", &csv)?;
    Ok(())
}

pub fn ablate_weights(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let n_eval = n_passages(args);
    let opt = base_pipeline_options(args);
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let diag = pipe.diagnose(&ctx.params, &opt)?;

    let wiki = eval_passages(&ctx, Domain::Wiki, n_eval);
    let mut batcher = NllBatcher::new(&ctx.cfg, &ctx.params)?;
    let fp16 = ppl_with(&mut batcher, &ctx.params, &wiki)?;

    let grid: Vec<(&str, ScoreWeights)> = vec![
        ("balanced 1/3", ScoreWeights::default()),
        ("ppl only", ScoreWeights { alpha: 1.0, beta: 0.0, gamma: 0.0 }),
        ("compactness only", ScoreWeights { alpha: 0.0, beta: 1.0, gamma: 0.0 }),
        ("energy only", ScoreWeights { alpha: 0.0, beta: 0.0, gamma: 1.0 }),
        ("ppl+geometry", ScoreWeights { alpha: 0.5, beta: 0.25, gamma: 0.25 }),
    ];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    rows.push(vec!["fp16".into(), "-".into(), fmt_metric(fp16)]);
    for (name, w) in grid {
        let scores = aggregate(&diag, w);
        let bits = allocate_top_m(&scores.s, opt.top_m, 4, 2);
        let q = pipe.quantize_with(&ctx.params, &bits, Backend::Gptq)?;
        let ppl = ppl_with(&mut batcher, &q, &wiki)?;
        let protected: Vec<usize> =
            bits.0.iter().enumerate().filter(|(_, &b)| b == 4).map(|(i, _)| i).collect();
        rows.push(vec![name.to_string(), format!("{protected:?}"), fmt_metric(ppl)]);
        csv.push(format!("{name},{protected:?},{ppl}"));
    }
    print_table(
        &format!("Score-weight ablation on {model} (α/β/γ of Eq. 10)"),
        &["weights", "protected layers", "wiki ppl"],
        &rows,
    );
    write_csv("ablate_weights.csv", "weights,protected,ppl", &csv)?;
    Ok(())
}

/// Pareto front: PPL vs average bits — LieQ's m-sweep against uniform
/// RTN/GPTQ points (the paper's "new Pareto front for sub-7B LLM
/// quantization" claim, measured).
pub fn pareto(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_small").to_string();
    let ctx = model_ctx(&model, args)?;
    let n_eval = n_passages(args);
    let opt = base_pipeline_options(args);
    let pipe = LieqPipeline::new(&ctx.cfg, &ctx.bpe);
    let diag = pipe.diagnose(&ctx.params, &opt)?;
    let scores = aggregate(&diag, ScoreWeights::default());

    let wiki = eval_passages(&ctx, Domain::Wiki, n_eval);
    let mut batcher = NllBatcher::new(&ctx.cfg, &ctx.params)?;
    let fp16 = ppl_with(&mut batcher, &ctx.params, &wiki)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    // LieQ curve: m = 0..L (2/4-bit mix).
    for m in 0..=ctx.cfg.n_layers {
        let bits = allocate_top_m(&scores.s, m, 4, 2);
        let q = pipe.quantize_with(&ctx.params, &bits, Backend::Gptq)?;
        let ppl = ppl_with(&mut batcher, &q, &wiki)?;
        let avg = bits.avg_bits(&ctx.cfg);
        rows.push(vec![format!("LieQ m={m}"), format!("{avg:.2}"), fmt_metric(ppl)]);
        csv.push(format!("lieq_m{m},{avg:.3},{ppl}"));
        log::info!("pareto lieq m={m} bits {avg:.2} ppl {ppl:.2}");
    }
    // Uniform baselines.
    for (backend, bits) in [
        (Backend::Rtn, 2u8),
        (Backend::Rtn, 3),
        (Backend::Rtn, 4),
        (Backend::Gptq, 2),
        (Backend::Gptq, 3),
    ] {
        let q = quantize_uniform(&ctx, backend, bits)?;
        let ppl = ppl_with(&mut batcher, &q, &wiki)?;
        rows.push(vec![
            format!("{} uniform {bits}b", backend.name()),
            format!("{bits}.00"),
            fmt_metric(ppl),
        ]);
        csv.push(format!("{}_{bits}b,{bits},{ppl}", backend.name()));
    }
    rows.push(vec!["FP16".into(), "16.00".into(), fmt_metric(fp16)]);
    print_table(
        &format!("Pareto front on {model}: wiki PPL vs avg bits"),
        &["config", "avg bits", "ppl"],
        &rows,
    );
    write_csv("pareto.csv", "config,avg_bits,ppl", &csv)?;
    Ok(())
}
