//! Activation capture: run the `capture` artifact over calibration
//! passages and hold the per-layer tensors the geometric diagnostics and
//! calibration-based backends need.
//!
//! Outputs of the artifact (stacked over layers):
//!   attn_in  [L, B, T, d]      post-attn-norm hidden state h^(ℓ)
//!   ctx      [L, B, T, nq*hd]  o_proj input
//!   mlp_in   [L, B, T, d]      gate/up input
//!   mlp_act  [L, B, T, dff]    down_proj input

use anyhow::Result;

use crate::model::{LinearKind, ModelConfig, ParamStore};
use crate::runtime::exec::engine;
use crate::tensor::Tensor;

/// Captured activations for one batch of calibration passages.
#[derive(Clone, Debug)]
pub struct CaptureSet {
    pub n_layers: usize,
    pub rows: usize, // B*T flattened
    pub d_model: usize,
    pub d_ctx: usize,
    pub d_ff: usize,
    attn_in: Tensor,
    ctx: Tensor,
    mlp_in: Tensor,
    mlp_act: Tensor,
}

impl CaptureSet {
    /// Run the capture artifact on `tokens` (must match artifact B, T).
    pub fn collect(cfg: &ModelConfig, params: &ParamStore, tokens: &Tensor) -> Result<CaptureSet> {
        let exe = engine().load(cfg.artifact_path("capture_b4_t128")?)?;
        let mut args: Vec<&Tensor> = vec![tokens];
        let pos = params.positional();
        args.extend(pos.iter().copied());
        let outs = exe.run(&args)?;
        anyhow::ensure!(outs.len() == 5, "capture returned {} outputs", outs.len());
        let mut it = outs.into_iter();
        let attn_in = it.next().unwrap();
        let ctx = it.next().unwrap();
        let mlp_in = it.next().unwrap();
        let mlp_act = it.next().unwrap();
        let (l, b, t, d) =
            (attn_in.shape[0], attn_in.shape[1], attn_in.shape[2], attn_in.shape[3]);
        Ok(CaptureSet {
            n_layers: l,
            rows: b * t,
            d_model: d,
            d_ctx: ctx.shape[3],
            d_ff: mlp_act.shape[3],
            attn_in,
            ctx,
            mlp_in,
            mlp_act,
        })
    }

    /// Synthetic capture set for unit tests: wrap pre-built activation
    /// tensors without running the capture artifact.
    #[cfg(test)]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        n_layers: usize,
        rows: usize,
        d_model: usize,
        d_ctx: usize,
        d_ff: usize,
        attn_in: Tensor,
        ctx: Tensor,
        mlp_in: Tensor,
        mlp_act: Tensor,
    ) -> CaptureSet {
        CaptureSet { n_layers, rows, d_model, d_ctx, d_ff, attn_in, ctx, mlp_in, mlp_act }
    }

    fn source(&self, name: &str) -> (&Tensor, usize) {
        match name {
            "attn_in" => (&self.attn_in, self.d_model),
            "ctx" => (&self.ctx, self.d_ctx),
            "mlp_in" => (&self.mlp_in, self.d_model),
            "mlp_act" => (&self.mlp_act, self.d_ff),
            _ => panic!("unknown capture source {name}"),
        }
    }

    /// Hidden-state matrix h^(ℓ) as rows x d (for the compactness SVD).
    pub fn hidden(&self, layer: usize) -> Vec<f32> {
        self.layer_rows("attn_in", layer)
    }

    /// Calibration input matrix (rows x K) for a given linear.
    pub fn calib_matrix(&self, layer: usize, kind: LinearKind) -> Vec<f32> {
        self.layer_rows(kind.calib_source(), layer)
    }

    fn layer_rows(&self, source: &str, layer: usize) -> Vec<f32> {
        let (t, width) = self.source(source);
        let per_layer = self.rows * width;
        let all = t.f32_slice();
        all[layer * per_layer..(layer + 1) * per_layer].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration: capture on q_nano init params (skips without artifacts).
    #[test]
    fn capture_shapes() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let art = cfg.artifact("capture_b4_t128").unwrap();
        let tokens = Tensor::from_i32(
            (0..art.batch * art.seq).map(|i| (i % cfg.vocab) as i32).collect(),
            &[art.batch, art.seq],
        );
        let cap = CaptureSet::collect(&cfg, &params, &tokens).unwrap();
        assert_eq!(cap.n_layers, cfg.n_layers);
        assert_eq!(cap.rows, art.batch * art.seq);
        assert_eq!(cap.hidden(0).len(), cap.rows * cfg.d_model);
        assert_eq!(
            cap.calib_matrix(1, LinearKind::DownProj).len(),
            cap.rows * cfg.d_ff
        );
        // Different layers produce different activations.
        assert_ne!(cap.hidden(0), cap.hidden(cfg.n_layers - 1));
    }
}
