//! Perplexity-drop diagnostic (paper Eq. 1–2).
//!
//! One `fwd_nll` artifact serves all passes: the skip-mask input turns
//! layer ℓ into identity-plus-residual. ΔPPL_ℓ = PPL_{\ℓ} − PPL_base over
//! a calibration set; (L+1) forwards per bucket, exactly the paper's
//! O(L·n) protocol.

use anyhow::Result;

use crate::eval::ppl::{nll_over_passages, NllBatcher};
use crate::model::{ModelConfig, ParamStore};

/// ΔPPL per layer plus the baseline PPL.
pub struct PplDrop {
    pub base_ppl: f64,
    pub delta: Vec<f64>,
}

/// Compute ΔPPL_ℓ for all ℓ on tokenized passages.
pub fn ppl_drop(
    cfg: &ModelConfig,
    params: &ParamStore,
    passages: &[Vec<u32>],
) -> Result<PplDrop> {
    let batcher = NllBatcher::new(cfg, params)?;
    let l = cfg.n_layers;

    let base_mask = vec![1.0f32; l];
    let base_nll = nll_over_passages(&batcher, passages, &base_mask)?;
    let base_ppl = base_nll.exp();

    let mut delta = Vec::with_capacity(l);
    for layer in 0..l {
        let mut mask = vec![1.0f32; l];
        mask[layer] = 0.0;
        let nll = nll_over_passages(&batcher, passages, &mask)?;
        let ppl = nll.exp();
        delta.push(ppl - base_ppl);
        log::debug!("[{}] drop layer {layer}: ppl {ppl:.2} (base {base_ppl:.2})", cfg.name);
    }
    Ok(PplDrop { base_ppl, delta })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{Bucket, Corpus, Domain};

    /// Integration (needs artifacts): every layer's removal changes PPL and
    /// deltas are finite.
    #[test]
    fn ppl_drop_finite_and_nonzero() {
        let root = crate::artifacts_dir();
        if !root.join("q_nano/manifest.json").exists() {
            return;
        }
        let cfg = ModelConfig::load(&root, "q_nano").unwrap();
        let params = ParamStore::load(&cfg, cfg.dir.join("init.lieq")).unwrap();
        let bpe = crate::corpus::shared_tokenizer(&root, cfg.vocab, 3);
        let corpus = Corpus::new(Domain::Wiki, 3);
        let passages = corpus.sample_bucket(&bpe, Bucket::Short, 8);
        let pd = ppl_drop(&cfg, &params, &passages).unwrap();
        assert_eq!(pd.delta.len(), cfg.n_layers);
        assert!(pd.base_ppl.is_finite() && pd.base_ppl > 1.0);
        for (l, d) in pd.delta.iter().enumerate() {
            assert!(d.is_finite(), "layer {l} delta not finite");
        }
        // At init the model is near-uniform so drops are small but the
        // computation must distinguish layers.
        let distinct = pd
            .delta
            .windows(2)
            .any(|w| (w[0] - w[1]).abs() > 1e-9);
        assert!(distinct);
    }
}
