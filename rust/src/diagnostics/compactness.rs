//! Representational compactness (paper Eq. 3–5).
//!
//! For each attention projection P ∈ {Q, K, V} of layer ℓ:
//!   Z = h^(ℓ) W_P   (rows x d_head·H — we take the first head's slice per
//!                    the paper's d_head-dimensional analysis)
//!   Compact(Z) = exp(−Σ p_k log p_k),  p_k = σ_k / Σσ_j
//!   Δr = (Compact(Z̃) − Compact(Z)) / Compact(Z̃)
//! with W̃_P a matched-variance random matrix (the untrained baseline).

use crate::linalg::{singular_values, Mat};
use crate::model::{LinearKind, ModelConfig, ParamStore};
use crate::util::{Pool, Rng};

use super::capture::CaptureSet;

/// exp(Shannon entropy) of the normalized singular spectrum (Eq. 4).
pub fn compactness(sigma: &[f64]) -> f64 {
    let total: f64 = sigma.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &s in sigma {
        let p = s / total;
        if p > 1e-300 {
            h -= p * p.ln();
        }
    }
    h.exp()
}

/// Δr_ℓ for every layer, averaged over Q/K/V projections (Eq. 5).
/// `head_cols` limits Z to the first d_head columns (one head's subspace),
/// keeping the SVD T x d_head as in the paper.
///
/// Layers fan out on [`Pool::current`]; each layer draws its random
/// baseline from a per-layer [`Rng`] stream derived from `seed`, so the
/// result is deterministic at any thread count.
pub fn compact_delta(
    cfg: &ModelConfig,
    params: &ParamStore,
    cap: &CaptureSet,
    seed: u64,
) -> anyhow::Result<Vec<f64>> {
    let kinds = [LinearKind::QProj, LinearKind::KProj, LinearKind::VProj];
    let rows = Pool::current().par_map((0..cfg.n_layers).collect::<Vec<usize>>(), |layer| {
        let mut rng = layer_rng(seed ^ 0xC04AC7, layer);
        let h = cap.hidden(layer);
        let hm = Mat::from_f32(&h, cap.rows, cfg.d_model);
        let mut acc = 0.0;
        for kind in kinds {
            let w = params.get(&cfg.linear_name(layer, kind))?;
            let (k, n) = (w.shape[0], w.shape[1]);
            let head = cfg.d_head.min(n);
            let trained = project(&hm, w.f32_slice(), k, n, head);
            let wr = random_like(&mut rng, w.f32_slice(), k, n);
            let random = project(&hm, &wr, k, n, head);

            let c_trained = compactness(&singular_values(&trained));
            let c_random = compactness(&singular_values(&random));
            if c_random > 1e-12 {
                acc += (c_random - c_trained) / c_random;
            }
        }
        anyhow::Ok(acc / kinds.len() as f64)
    });
    rows.into_iter().collect()
}

/// Independent per-layer RNG stream (SplitMix-style spacing) so layer
/// diagnostics parallelize without sharing a sequential generator.
pub(crate) fn layer_rng(seed: u64, layer: usize) -> Rng {
    Rng::new(seed.wrapping_add((layer as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)))
}

/// Z = h W[:, :head] (rows x head).
pub(crate) fn project(h: &Mat, w: &[f32], k: usize, n: usize, head: usize) -> Mat {
    debug_assert_eq!(h.cols, k);
    let mut z = Mat::zeros(h.rows, head);
    for r in 0..h.rows {
        let hrow = h.row(r);
        let zrow = &mut z.data[r * head..(r + 1) * head];
        for (kk, &hv) in hrow.iter().enumerate().take(k) {
            if hv == 0.0 {
                continue;
            }
            let wrow = &w[kk * n..kk * n + head];
            for c in 0..head {
                zrow[c] += hv * wrow[c] as f64;
            }
        }
    }
    z
}

/// Matched-moment random weight matrix: same empirical std as `w`, zero
/// mean — "the same initialization distribution but untrained" (Eq. 3).
pub(crate) fn random_like(rng: &mut Rng, w: &[f32], k: usize, n: usize) -> Vec<f32> {
    let mean: f64 = w.iter().map(|&v| v as f64).sum::<f64>() / w.len() as f64;
    let var: f64 =
        w.iter().map(|&v| (v as f64 - mean) * (v as f64 - mean)).sum::<f64>() / w.len() as f64;
    let std = var.sqrt().max(1e-12) as f32;
    let mut out = vec![0f32; k * n];
    rng.fill_normal(&mut out, std);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compactness_uniform_spectrum_is_count() {
        // Uniform σ over m values → entropy ln m → compactness = m.
        let sigma = vec![2.0; 8];
        assert!((compactness(&sigma) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn compactness_concentrated_spectrum_is_low() {
        let mut sigma = vec![1e-9; 16];
        sigma[0] = 100.0;
        assert!(compactness(&sigma) < 1.1);
    }

    #[test]
    fn compactness_monotone_under_concentration() {
        // Progressively concentrating energy lowers compactness.
        let flat = vec![1.0; 10];
        let mild: Vec<f64> = (0..10).map(|i| 1.0 / (1.0 + i as f64 * 0.3)).collect();
        let sharp: Vec<f64> = (0..10).map(|i| (0.3f64).powi(i as i32)).collect();
        let (a, b, c) = (compactness(&flat), compactness(&mild), compactness(&sharp));
        assert!(a > b && b > c, "{a} {b} {c}");
    }

    #[test]
    fn structured_projection_more_compact_than_random() {
        // A trained-like W that projects onto a low-rank subspace must show
        // positive Δr against a random W̃ on correlated inputs.
        let mut rng = Rng::new(21);
        let (rows, k, head) = (96, 32, 16);
        // Correlated inputs: rank-4 latent structure + noise.
        let mut h = Mat::zeros(rows, k);
        let latent: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..k).map(|_| rng.normal()).collect())
            .collect();
        for r in 0..rows {
            let coef: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            for c in 0..k {
                let mut v = 0.02 * rng.normal();
                for (l, lv) in latent.iter().enumerate() {
                    v += coef[l] * lv[c];
                }
                h[(r, c)] = v;
            }
        }
        // Trained W: aligned with the first latent direction (concentrates
        // variance into few directions).
        let mut w_tr = vec![0f32; k * head];
        for kk in 0..k {
            for c in 0..head {
                w_tr[kk * head + c] =
                    (latent[c % 4][kk] * 0.5) as f32 + 0.01 * rng.normal_f32();
            }
        }
        let w_rand = random_like(&mut rng, &w_tr, k, head);

        let z_tr = project(&h, &w_tr, k, head, head);
        let z_rnd = project(&h, &w_rand, k, head, head);
        let c_tr = compactness(&singular_values(&z_tr));
        let c_rnd = compactness(&singular_values(&z_rnd));
        assert!(
            c_tr < c_rnd,
            "trained projection should concentrate: {c_tr} vs random {c_rnd}"
        );
    }

    #[test]
    fn random_like_matches_moments() {
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 0.05).collect();
        let r = random_like(&mut rng, &w, 64, 64);
        let std = |v: &[f32]| {
            let m: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
            (v.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / v.len() as f64).sqrt()
        };
        let (s1, s2) = (std(&w), std(&r));
        assert!((s1 - s2).abs() / s1 < 0.1, "{s1} vs {s2}");
    }
}
