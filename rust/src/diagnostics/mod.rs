//! Layer-wise information effectiveness diagnostics — the paper's core
//! contribution (Eq. 1–12).
//!
//! * [`ppl_drop`] — functional diagnostic ΔPPL_ℓ via skip-mask forwards.
//! * [`capture`] — per-layer activation capture (feeds the geometric
//!   diagnostics and the GPTQ/AWQ calibration Hessians).
//! * [`compactness`] — representational compactness Δr_ℓ (SVD entropy of
//!   trained vs. random projections).
//! * [`energy`] — top-k energy gain ΔE_{k,ℓ}.
//! * [`score`] — normalization + convex aggregation into s_ℓ.
//! * [`allocate`] — bit-width allocation (top-m, budget-constrained).

pub mod allocate;
pub mod capture;
pub mod compactness;
pub mod energy;
pub mod ppl_drop;
pub mod score;

pub use allocate::{
    allocate_budget, allocate_budget_outlier, allocate_top_m, outlier_overhead_bits,
};
pub use capture::CaptureSet;
pub use compactness::compactness;
pub use energy::top_k_energy;
pub use score::{LayerScores, ScoreWeights};

/// Full per-layer diagnostic triplet for one (model, corpus, bucket).
#[derive(Clone, Debug)]
pub struct LayerDiagnostics {
    /// ΔPPL_ℓ (Eq. 2), length L.
    pub ppl_drop: Vec<f64>,
    /// Δr_ℓ (Eq. 5), averaged over Q/K/V projections, length L.
    pub compact_delta: Vec<f64>,
    /// ΔE_{k,ℓ} (Eq. 7), averaged over Q/K/V, length L.
    pub energy_delta: Vec<f64>,
    pub base_ppl: f64,
}

impl LayerDiagnostics {
    pub fn n_layers(&self) -> usize {
        self.ppl_drop.len()
    }
}
