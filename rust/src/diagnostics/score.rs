//! Score aggregation (paper Eq. 8–10): max-normalize each diagnostic
//! across layers, then convex-combine into the layer effectiveness s_ℓ.

use super::LayerDiagnostics;

#[derive(Clone, Copy, Debug)]
pub struct ScoreWeights {
    pub alpha: f64, // ΔPPL weight
    pub beta: f64,  // Δr weight
    pub gamma: f64, // ΔE weight
}

impl Default for ScoreWeights {
    /// Paper default: α = β = γ = 1/3.
    fn default() -> Self {
        ScoreWeights { alpha: 1.0 / 3.0, beta: 1.0 / 3.0, gamma: 1.0 / 3.0 }
    }
}

impl ScoreWeights {
    pub fn normalized(mut self) -> Self {
        let sum = self.alpha + self.beta + self.gamma;
        assert!(sum > 0.0);
        self.alpha /= sum;
        self.beta /= sum;
        self.gamma /= sum;
        self
    }
}

/// Per-layer effectiveness scores with their normalized components.
#[derive(Clone, Debug)]
pub struct LayerScores {
    pub s: Vec<f64>,
    pub ppl_hat: Vec<f64>,
    pub compact_hat: Vec<f64>,
    pub energy_hat: Vec<f64>,
}

/// Max-normalize (Eq. 8–9); |·| on Δr per the paper, plain max for others.
/// All-zero vectors normalize to zero (degenerate-but-defined).
fn max_norm(xs: &[f64], use_abs: bool) -> Vec<f64> {
    let vals: Vec<f64> = if use_abs { xs.iter().map(|v| v.abs()).collect() } else { xs.to_vec() };
    let mx = vals.iter().cloned().fold(f64::MIN, f64::max);
    if mx <= 0.0 || !mx.is_finite() {
        return vec![0.0; xs.len()];
    }
    vals.iter().map(|v| (v / mx).max(0.0)).collect()
}

/// Aggregate the diagnostics into s_ℓ (Eq. 10).
pub fn aggregate(diag: &LayerDiagnostics, w: ScoreWeights) -> LayerScores {
    let w = w.normalized();
    let ppl_hat = max_norm(&diag.ppl_drop, false);
    let compact_hat = max_norm(&diag.compact_delta, true);
    let energy_hat = max_norm(&diag.energy_delta, false);
    let s = (0..diag.n_layers())
        .map(|l| w.alpha * ppl_hat[l] + w.beta * compact_hat[l] + w.gamma * energy_hat[l])
        .collect();
    LayerScores { s, ppl_hat, compact_hat, energy_hat }
}

/// Average diagnostics over several (corpus, bucket) runs — the paper
/// aggregates per-bucket triplets before scoring.
pub fn average_diagnostics(runs: &[LayerDiagnostics]) -> LayerDiagnostics {
    assert!(!runs.is_empty());
    let l = runs[0].n_layers();
    let mut out = LayerDiagnostics {
        ppl_drop: vec![0.0; l],
        compact_delta: vec![0.0; l],
        energy_delta: vec![0.0; l],
        base_ppl: 0.0,
    };
    for r in runs {
        for i in 0..l {
            out.ppl_drop[i] += r.ppl_drop[i] / runs.len() as f64;
            out.compact_delta[i] += r.compact_delta[i] / runs.len() as f64;
            out.energy_delta[i] += r.energy_delta[i] / runs.len() as f64;
        }
        out.base_ppl += r.base_ppl / runs.len() as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag() -> LayerDiagnostics {
        LayerDiagnostics {
            ppl_drop: vec![4.0, 1.0, 0.5, 2.0],
            compact_delta: vec![0.1, -0.4, 0.2, 0.05],
            energy_delta: vec![0.05, 0.2, 0.1, 0.02],
            base_ppl: 20.0,
        }
    }

    #[test]
    fn scores_in_unit_interval() {
        let s = aggregate(&diag(), ScoreWeights::default());
        for v in &s.s {
            assert!(*v >= 0.0 && *v <= 1.0, "{v}");
        }
        // Max-normalized components hit 1 somewhere.
        assert!(s.ppl_hat.iter().cloned().fold(0.0, f64::max) > 0.999);
    }

    #[test]
    fn abs_applied_to_compactness() {
        let s = aggregate(&diag(), ScoreWeights::default());
        // layer 1 has the largest |Δr| (−0.4) → compact_hat = 1.
        assert!((s.compact_hat[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pure_ppl_weighting_ranks_by_ppl() {
        let s = aggregate(&diag(), ScoreWeights { alpha: 1.0, beta: 0.0, gamma: 0.0 });
        let max_idx = s
            .s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_idx, 0); // ppl_drop[0] = 4.0 dominates
    }

    #[test]
    fn weights_renormalize() {
        let w = ScoreWeights { alpha: 2.0, beta: 1.0, gamma: 1.0 }.normalized();
        assert!((w.alpha + w.beta + w.gamma - 1.0).abs() < 1e-12);
        assert!((w.alpha - 0.5).abs() < 1e-12);
    }

    #[test]
    fn averaging_runs() {
        let a = diag();
        let mut b = diag();
        b.ppl_drop = vec![0.0, 3.0, 0.5, 2.0];
        let avg = average_diagnostics(&[a, b]);
        assert!((avg.ppl_drop[0] - 2.0).abs() < 1e-12);
        assert!((avg.ppl_drop[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_all_zero() {
        let d = LayerDiagnostics {
            ppl_drop: vec![0.0; 3],
            compact_delta: vec![0.0; 3],
            energy_delta: vec![0.0; 3],
            base_ppl: 1.0,
        };
        let s = aggregate(&d, ScoreWeights::default());
        assert!(s.s.iter().all(|&v| v == 0.0));
    }
}
