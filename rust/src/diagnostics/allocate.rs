//! Bit-width allocation (paper Eq. 11–12).
//!
//! * [`allocate_top_m`] — the paper's scheme: top-m layers by s_ℓ get
//!   `hi` bits, the rest `lo` bits (Eq. 11). The paper's extreme config
//!   is m = 1, hi = 4, lo = 2 (≈2.05 avg bits).
//! * [`allocate_budget`] — closed-form m from a target average bit-width
//!   (inverse of Eq. 12, weighted by per-layer parameter counts), plus a
//!   greedy baseline allocator for the ablation.
//! * [`allocate_budget_outlier`] — the mixed-packing variant: the fp16
//!   outlier sidecar's per-weight overhead is charged against the same
//!   budget, and the dense allocator re-spends whatever the sidecar
//!   leaves on hi-bit layer upgrades.

use crate::model::config::ALL_LINEARS;
use crate::model::ModelConfig;
use crate::quant::LayerBits;

/// Eq. 11: S_hi = TopK_m(s), b_ℓ = hi for ℓ ∈ S_hi else lo.
pub fn allocate_top_m(scores: &[f64], m: usize, hi: u8, lo: u8) -> LayerBits {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut bits = vec![lo; scores.len()];
    for &i in idx.iter().take(m) {
        bits[i] = hi;
    }
    LayerBits(bits)
}

/// Largest m whose parameter-weighted average bits (Eq. 12) stays within
/// `target_avg_bits`, assigning hi bits to the highest-scoring layers
/// first. Returns (bits, m).
pub fn allocate_budget(
    cfg: &ModelConfig,
    scores: &[f64],
    target_avg_bits: f64,
    hi: u8,
    lo: u8,
) -> (LayerBits, usize) {
    let l = scores.len();
    let mut best = (LayerBits::uniform(l, lo), 0usize);
    for m in 1..=l {
        let cand = allocate_top_m(scores, m, hi, lo);
        if cand.avg_bits(cfg) <= target_avg_bits + 1e-9 {
            best = (cand, m);
        } else {
            break;
        }
    }
    best
}

/// Parameter-weighted average overhead (bits per weight) of the fp16
/// outlier sidecar at threshold `eps`: each extracted column of a K x N
/// linear costs one u32 index plus N fp16 values (32 + 16·N bits), and
/// extraction takes `ceil(eps·K)` columns per linear (the same count
/// rule as `quant::saliency::outlier_count`).
pub fn outlier_overhead_bits(cfg: &ModelConfig, eps: f64) -> f64 {
    if eps <= 0.0 {
        return 0.0;
    }
    let mut side_bits = 0.0f64;
    let mut weights = 0.0f64;
    for layer in 0..cfg.n_layers {
        for &kind in ALL_LINEARS.iter() {
            let Ok(info) = cfg.param_info(&cfg.linear_name(layer, kind)) else { continue };
            if info.shape.len() != 2 {
                continue;
            }
            let (k, n) = (info.shape[0], info.shape[1]);
            let nc = ((eps * k as f64).ceil() as usize).min(k);
            side_bits += nc as f64 * (32.0 + 16.0 * n as f64);
            weights += (k * n) as f64;
        }
    }
    if weights > 0.0 {
        side_bits / weights
    } else {
        0.0
    }
}

/// [`allocate_budget`] with the outlier sidecar charged against the same
/// target: the dense grid only gets `target - overhead(eps)` bits per
/// weight, and the allocator re-spends every remaining bit on hi-bit
/// upgrades. Returns (bits, m, sidecar overhead in bits/weight) — the
/// allocation table reports all three, so the re-spend is visible.
/// `eps = 0` degenerates to [`allocate_budget`] exactly.
pub fn allocate_budget_outlier(
    cfg: &ModelConfig,
    scores: &[f64],
    target_avg_bits: f64,
    hi: u8,
    lo: u8,
    eps: f64,
) -> (LayerBits, usize, f64) {
    let overhead = outlier_overhead_bits(cfg, eps);
    let (bits, m) = allocate_budget(cfg, scores, target_avg_bits - overhead, hi, lo);
    (bits, m, overhead)
}

/// Greedy-by-error baseline (the "myopic" allocator the related work uses):
/// repeatedly upgrade the layer with the largest marginal error reduction
/// per parameter until the budget is exhausted. `layer_error[l]` is any
/// per-layer sensitivity proxy (we feed it quantization MSE).
pub fn allocate_greedy(
    cfg: &ModelConfig,
    layer_error: &[f64],
    target_avg_bits: f64,
    hi: u8,
    lo: u8,
) -> LayerBits {
    let l = layer_error.len();
    let mut bits = LayerBits::uniform(l, lo);
    loop {
        // Candidate upgrades sorted by error / param count (marginal gain).
        let mut cand: Vec<(f64, usize)> = (0..l)
            .filter(|&i| bits.0[i] == lo)
            .map(|i| (layer_error[i] / cfg.layer_linear_param_count(i).max(1) as f64, i))
            .collect();
        cand.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let Some(&(_, pick)) = cand.first() else { break };
        let mut trial = bits.clone();
        trial.0[pick] = hi;
        if trial.avg_bits(cfg) > target_avg_bits + 1e-9 {
            break;
        }
        bits = trial;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_m_selects_highest() {
        let scores = [0.1, 0.9, 0.3, 0.7];
        let b = allocate_top_m(&scores, 2, 4, 2);
        assert_eq!(b.0, vec![2, 4, 2, 4]);
    }

    #[test]
    fn m_zero_uniform_lo() {
        let b = allocate_top_m(&[0.5, 0.6], 0, 4, 2);
        assert_eq!(b.0, vec![2, 2]);
    }

    #[test]
    fn m_all_uniform_hi() {
        let b = allocate_top_m(&[0.5, 0.6, 0.1], 3, 4, 2);
        assert_eq!(b.0, vec![4, 4, 4]);
    }

    #[test]
    fn ties_stable() {
        let b = allocate_top_m(&[0.5, 0.5, 0.5], 1, 4, 2);
        assert_eq!(b.0.iter().filter(|&&x| x == 4).count(), 1);
    }

    // Budget tests that need a ModelConfig run in tests/integration.rs
    // (they require the artifact manifest); the pure top-m math is covered
    // here.
}
