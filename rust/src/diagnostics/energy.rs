//! Top-k energy concentration (paper Eq. 6–7).
//!
//! E_k(Z) = Σ_{i≤k} σ_i² / Σ_j σ_j² — fraction of variance in the leading
//! k principal directions; ΔE = E_k(Z) − E_k(Z̃) (trained minus random).

use crate::linalg::{singular_values, Mat};
use crate::model::{LinearKind, ModelConfig, ParamStore};
use crate::util::Pool;

use super::capture::CaptureSet;
use super::compactness::{layer_rng, project, random_like};

pub const DEFAULT_K: usize = 8;

/// E_k of a singular spectrum (Eq. 6).
pub fn top_k_energy(sigma: &[f64], k: usize) -> f64 {
    let total: f64 = sigma.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let top: f64 = sigma.iter().take(k).map(|s| s * s).sum();
    top / total
}

/// ΔE_{k,ℓ} for every layer, averaged over Q/K/V projections (Eq. 7).
///
/// Layers fan out on [`Pool::current`] with per-layer RNG streams (see
/// `compactness::layer_rng`), deterministic at any thread count.
pub fn energy_delta(
    cfg: &ModelConfig,
    params: &ParamStore,
    cap: &CaptureSet,
    k_energy: usize,
    seed: u64,
) -> anyhow::Result<Vec<f64>> {
    let kinds = [LinearKind::QProj, LinearKind::KProj, LinearKind::VProj];
    let rows = Pool::current().par_map((0..cfg.n_layers).collect::<Vec<usize>>(), |layer| {
        let mut rng = layer_rng(seed ^ 0xE4E6, layer);
        let h = cap.hidden(layer);
        let hm = Mat::from_f32(&h, cap.rows, cfg.d_model);
        let mut acc = 0.0;
        for kind in kinds {
            let w = params.get(&cfg.linear_name(layer, kind))?;
            let (kk, n) = (w.shape[0], w.shape[1]);
            let head = cfg.d_head.min(n);
            let z_tr = project(&hm, w.f32_slice(), kk, n, head);
            let wr = random_like(&mut rng, w.f32_slice(), kk, n);
            let z_rnd = project(&hm, &wr, kk, n, head);
            let e_tr = top_k_energy(&singular_values(&z_tr), k_energy);
            let e_rnd = top_k_energy(&singular_values(&z_rnd), k_energy);
            acc += e_tr - e_rnd;
        }
        anyhow::Ok(acc / kinds.len() as f64)
    });
    rows.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_k_is_one() {
        let sigma = vec![3.0, 2.0, 1.0];
        assert!((top_k_energy(&sigma, 3) - 1.0).abs() < 1e-12);
        assert!((top_k_energy(&sigma, 10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_fraction() {
        let sigma = vec![2.0, 1.0, 1.0]; // squares: 4, 1, 1
        assert!((top_k_energy(&sigma, 1) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_k() {
        let sigma: Vec<f64> = (1..=10).rev().map(|i| i as f64).collect();
        let mut prev = 0.0;
        for k in 1..=10 {
            let e = top_k_energy(&sigma, k);
            assert!(e >= prev);
            prev = e;
        }
    }

    #[test]
    fn low_rank_concentrates() {
        // Spectrum with sharp low-rank structure has higher E_k than flat.
        let flat = vec![1.0; 32];
        let sharp: Vec<f64> = (0..32).map(|i| if i < 4 { 10.0 } else { 0.1 }).collect();
        assert!(top_k_energy(&sharp, 8) > top_k_energy(&flat, 8));
    }

    #[test]
    fn zero_spectrum_is_zero() {
        assert_eq!(top_k_energy(&[0.0, 0.0], 1), 0.0);
    }
}
