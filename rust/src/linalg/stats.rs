//! Rank statistics: Spearman ρ (the paper reports ρ(ΔPPL, Δr) and
//! ρ(ΔPPL, ΔE_k) per corpus/bucket) and Pearson r.

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Fractional ranks with ties averaged (midranks).
pub fn ranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| x[a].partial_cmp(&x[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation (Pearson of midranks; handles ties).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_monotone() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [10.0, 100.0, 1000.0, 10000.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn invariant_to_monotone_transform() {
        let x: [f64; 5] = [0.3, 1.7, 0.1, 5.0, 2.2];
        let y: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ties_get_midranks() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn pearson_of_linear() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uncorrelated_near_zero() {
        let mut rng = crate::util::Rng::new(3);
        let x: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..2000).map(|_| rng.normal()).collect();
        assert!(spearman(&x, &y).abs() < 0.08);
    }

    #[test]
    fn constant_input_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }
}
