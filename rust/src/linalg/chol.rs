//! Cholesky factorization and SPD solves — the numerical core of the GPTQ
//! backend (H⁻¹ via Cholesky, as in Frantar et al. 2022).

use anyhow::{bail, Result};

use super::Mat;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`. `A` must be
/// symmetric positive-definite; callers (GPTQ) add λI damping first.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows;
    if a.cols != n {
        bail!("cholesky: not square");
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: not positive definite at pivot {i} (sum={sum:.3e})");
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve `Lᵀ x = y` for lower-triangular `L` (backward substitution).
pub fn solve_upper(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Full SPD inverse via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`.
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&l, &y);
        for i in 0..n {
            inv[(i, j)] = x[i];
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

/// Upper Cholesky of the inverse: `U` with `UᵀU = A⁻¹`, i.e. the
/// `cholesky(H⁻¹, upper=True)` GPTQ uses for its error propagation row.
pub fn cholesky_inverse_upper(a: &Mat) -> Result<Mat> {
    let inv = cholesky_inverse(a)?;
    let l = cholesky(&inv)?;
    Ok(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n + 2);
        for v in &mut b.data {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(0.5);
        a
    }

    #[test]
    fn factor_reconstructs() {
        forall(
            "L*Lt == A",
            20,
            11,
            |rng| { let n = 2 + rng.below(10); random_spd(rng, n) },
            |a| {
                let l = cholesky(a).map_err(|e| e.to_string())?;
                let re = l.matmul(&l.transpose());
                let err = re.max_abs_diff(a);
                if err < 1e-8 * (1.0 + a.frob_norm()) {
                    Ok(())
                } else {
                    Err(format!("reconstruction err {err}"))
                }
            },
        );
    }

    #[test]
    fn inverse_property() {
        forall(
            "A * inv(A) == I",
            20,
            13,
            |rng| { let n = 2 + rng.below(8); random_spd(rng, n) },
            |a| {
                let inv = cholesky_inverse(a).map_err(|e| e.to_string())?;
                let prod = a.matmul(&inv);
                let err = prod.max_abs_diff(&Mat::eye(a.rows));
                if err < 1e-6 {
                    Ok(())
                } else {
                    Err(format!("inverse err {err}"))
                }
            },
        );
    }

    #[test]
    fn solve_matches_inverse() {
        let mut rng = Rng::new(5);
        let a = random_spd(&mut rng, 6);
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x = solve_upper(&l, &solve_lower(&l, &b));
        // A x == b
        let mut r = vec![0.0; 6];
        for i in 0..6 {
            for j in 0..6 {
                r[i] += a[(i, j)] * x[j];
            }
        }
        for i in 0..6 {
            assert!((r[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn inverse_upper_squares_to_inverse() {
        let mut rng = Rng::new(17);
        let a = random_spd(&mut rng, 5);
        let u = cholesky_inverse_upper(&a).unwrap();
        let inv = cholesky_inverse(&a).unwrap();
        let re = u.transpose().matmul(&u);
        assert!(re.max_abs_diff(&inv) < 1e-8);
    }
}
