//! Cholesky factorization and SPD solves — the numerical core of the GPTQ
//! backend (H⁻¹ via Cholesky, as in Frantar et al. 2022).
//!
//! Two factorizations share one FP contract:
//!
//! * [`cholesky`] — the naive left-looking reference loop.
//! * [`cholesky_blocked`] — right-looking panels with the panel solve
//!   and trailing update fanned out on [`Pool`]. Every element's
//!   subtraction chain runs in the same ascending-k order as the naive
//!   loop (updates are applied one `-=` at a time, never pre-summed), so
//!   the blocked factor is **bit-identical to [`cholesky`] at any
//!   thread count** — the same discipline the blocked GPTQ recursion
//!   established for its trailing updates.
//!
//! [`cholesky_inverse`] rides the blocked factor and fans its N
//! unit-vector solves out on the pool (each column is an independent
//! forward/backward substitution), which is where the O(K³) GPTQ setup
//! cost actually lives.

use anyhow::{bail, Result};

use crate::util::Pool;

use super::Mat;

/// Column width of one right-looking panel: wide enough that the pooled
/// trailing update dominates the sequential diagonal-block factor,
/// narrow enough that the copied panel strip stays cache-resident.
const CHOL_PANEL: usize = 64;

/// Rows per pooled work chunk in the panel solve / trailing update. The
/// trailing closure reconstructs its absolute row from the chunk index
/// with this same constant — keep them coupled.
const CHOL_ROW_CHUNK: usize = 8;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`. `A` must be
/// symmetric positive-definite; callers (GPTQ) add λI damping first.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows;
    if a.cols != n {
        bail!("cholesky: not square");
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: not positive definite at pivot {i} (sum={sum:.3e})");
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Blocked right-looking Cholesky, bit-identical to [`cholesky`] (see
/// module docs). Per panel `[p0, p1)`:
///
/// 1. factor the diagonal block sequentially (cheap, O(n·nb²) total);
/// 2. solve the sub-diagonal panel rows against the block — each row is
///    independent, fanned out on `pool`;
/// 3. apply the trailing update `A[i][c] -= Σ_k L[i][k]·L[c][k]` for the
///    panel's k range — rows fan out, the inner `-=` chain stays in
///    ascending (c, k) order per element.
///
/// Workers read the factored panel through private copies (`diag`,
/// `strip`) so parallel row chunks never alias the columns they write.
pub fn cholesky_blocked(a: &Mat, pool: &Pool) -> Result<Mat> {
    let n = a.rows;
    if a.cols != n {
        bail!("cholesky: not square");
    }
    let mut w = a.clone();
    let mut p0 = 0usize;
    while p0 < n {
        let p1 = (p0 + CHOL_PANEL).min(n);
        let nb = p1 - p0;

        // 1. Diagonal block (rows/cols p0..p1), sequential.
        for j in p0..p1 {
            let mut sum = w[(j, j)];
            for k in p0..j {
                sum -= w[(j, k)] * w[(j, k)];
            }
            if sum <= 0.0 {
                bail!("cholesky: not positive definite at pivot {j} (sum={sum:.3e})");
            }
            w[(j, j)] = sum.sqrt();
            for i in j + 1..p1 {
                let mut sum = w[(i, j)];
                for k in p0..j {
                    sum -= w[(i, k)] * w[(j, k)];
                }
                w[(i, j)] = sum / w[(j, j)];
            }
        }
        if p1 == n {
            break;
        }

        // Private copy of the factored diagonal block for the workers.
        let mut diag = vec![0.0f64; nb * nb];
        for j in 0..nb {
            for k in 0..=j {
                diag[j * nb + k] = w[(p0 + j, p0 + k)];
            }
        }

        // 2. Panel solve for rows p1..n: row i depends only on its own
        // earlier panel columns and the diagonal block.
        {
            let diag = &diag;
            pool.par_chunks_mut(&mut w.data[p1 * n..n * n], CHOL_ROW_CHUNK * n, |_, chunk| {
                for wrow in chunk.chunks_mut(n) {
                    for j in 0..nb {
                        let mut sum = wrow[p0 + j];
                        for k in 0..j {
                            sum -= wrow[p0 + k] * diag[j * nb + k];
                        }
                        wrow[p0 + j] = sum / diag[j * nb + j];
                    }
                }
            });
        }

        // Private copy of the solved panel strip (rows p1..n, cols
        // p0..p1): trailing workers read other rows' panel columns here
        // while writing their own trailing columns.
        let rows_below = n - p1;
        let mut strip = vec![0.0f64; rows_below * nb];
        for i in 0..rows_below {
            for k in 0..nb {
                strip[i * nb + k] = w[(p1 + i, p0 + k)];
            }
        }

        // 3. Trailing update, rows fanned out; ascending (c, k) per row.
        {
            let strip = &strip;
            pool.par_chunks_mut(&mut w.data[p1 * n..n * n], CHOL_ROW_CHUNK * n, |ci, chunk| {
                for (ri, wrow) in chunk.chunks_mut(n).enumerate() {
                    let i = ci * CHOL_ROW_CHUNK + ri; // row index relative to p1
                    let li = &strip[i * nb..(i + 1) * nb];
                    for c in 0..=i {
                        let lc = &strip[c * nb..(c + 1) * nb];
                        let slot = &mut wrow[p1 + c];
                        for k in 0..nb {
                            *slot -= li[k] * lc[k];
                        }
                    }
                }
            });
        }
        p0 = p1;
    }

    // Clear the strictly-upper remnants of A so the result matches the
    // naive factor's clean lower-triangular output.
    for i in 0..n {
        for j in i + 1..n {
            w[(i, j)] = 0.0;
        }
    }
    Ok(w)
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve `Lᵀ x = y` for lower-triangular `L` (backward substitution).
pub fn solve_upper(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Full SPD inverse via Cholesky: `A⁻¹ = L⁻ᵀ L⁻¹`. The factor is the
/// pooled blocked one (bit-identical to the naive loop) and the N
/// unit-vector solve pairs fan out per column — each column is an
/// independent substitution, so the inverse is also bit-identical at
/// any thread count.
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows;
    let pool = Pool::current();
    let l = cholesky_blocked(a, &pool)?;
    let mut inv = Mat::zeros(n, n);
    let l_ref = &l;
    let cols = pool.par_map((0..n).collect::<Vec<usize>>(), |j| {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let y = solve_lower(l_ref, &e);
        solve_upper(l_ref, &y)
    });
    for (j, x) in cols.iter().enumerate() {
        for i in 0..n {
            inv[(i, j)] = x[i];
        }
    }
    Ok(inv)
}

/// Upper Cholesky of the inverse: `U` with `UᵀU = A⁻¹`, i.e. the
/// `cholesky(H⁻¹, upper=True)` GPTQ uses for its error propagation row.
/// Both factorizations go through the blocked pooled path.
pub fn cholesky_inverse_upper(a: &Mat) -> Result<Mat> {
    let inv = cholesky_inverse(a)?;
    let l = cholesky_blocked(&inv, &Pool::current())?;
    Ok(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n + 2);
        for v in &mut b.data {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(0.5);
        a
    }

    #[test]
    fn factor_reconstructs() {
        forall(
            "L*Lt == A",
            20,
            11,
            |rng| { let n = 2 + rng.below(10); random_spd(rng, n) },
            |a| {
                let l = cholesky(a).map_err(|e| e.to_string())?;
                let re = l.matmul(&l.transpose());
                let err = re.max_abs_diff(a);
                if err < 1e-8 * (1.0 + a.frob_norm()) {
                    Ok(())
                } else {
                    Err(format!("reconstruction err {err}"))
                }
            },
        );
    }

    #[test]
    fn inverse_property() {
        forall(
            "A * inv(A) == I",
            20,
            13,
            |rng| { let n = 2 + rng.below(8); random_spd(rng, n) },
            |a| {
                let inv = cholesky_inverse(a).map_err(|e| e.to_string())?;
                let prod = a.matmul(&inv);
                let err = prod.max_abs_diff(&Mat::eye(a.rows));
                if err < 1e-6 {
                    Ok(())
                } else {
                    Err(format!("inverse err {err}"))
                }
            },
        );
    }

    #[test]
    fn solve_matches_inverse() {
        let mut rng = Rng::new(5);
        let a = random_spd(&mut rng, 6);
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let l = cholesky(&a).unwrap();
        let x = solve_upper(&l, &solve_lower(&l, &b));
        // A x == b
        let mut r = vec![0.0; 6];
        for i in 0..6 {
            for j in 0..6 {
                r[i] += a[(i, j)] * x[j];
            }
        }
        for i in 0..6 {
            assert!((r[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
        assert!(cholesky_blocked(&a, &Pool::new(2)).is_err());
    }

    #[test]
    fn blocked_bit_identical_to_sequential_at_any_thread_count() {
        let mut rng = Rng::new(41);
        // Sizes below, at, and well past the panel width (multi-panel).
        for n in [5usize, 63, 64, 150, 201] {
            let a = random_spd(&mut rng, n);
            let base = cholesky(&a).unwrap();
            for workers in [1usize, 4, 8] {
                let l = cholesky_blocked(&a, &Pool::new(workers)).unwrap();
                let identical = base
                    .data
                    .iter()
                    .zip(&l.data)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(identical, "blocked chol diverged: n={n}, {workers} workers");
            }
        }
    }

    #[test]
    fn pooled_inverse_matches_sequential_solves() {
        // The pooled inverse must equal naive-factor + sequential
        // per-column solves bit-for-bit (blocked factor == naive factor,
        // and each column solve is untouched by the fan-out). Thread
        // sweeps live in rust/tests/parallel.rs, which owns the global
        // pool knob.
        let mut rng = Rng::new(43);
        let a = random_spd(&mut rng, 90);
        let l = cholesky(&a).unwrap();
        let mut expect = Mat::zeros(90, 90);
        let mut e = vec![0.0; 90];
        for j in 0..90 {
            e[j] = 1.0;
            let x = solve_upper(&l, &solve_lower(&l, &e));
            for i in 0..90 {
                expect[(i, j)] = x[i];
            }
            e[j] = 0.0;
        }
        let inv = cholesky_inverse(&a).unwrap();
        let identical = expect
            .data
            .iter()
            .zip(&inv.data)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(identical, "pooled inverse diverged from sequential solves");
    }

    #[test]
    fn inverse_upper_squares_to_inverse() {
        let mut rng = Rng::new(17);
        let a = random_spd(&mut rng, 5);
        let u = cholesky_inverse_upper(&a).unwrap();
        let inv = cholesky_inverse(&a).unwrap();
        let re = u.transpose().matmul(&u);
        assert!(re.max_abs_diff(&inv) < 1e-8);
    }
}
