//! Row-major dense f64 matrix. f64 throughout: the spectral diagnostics
//! take entropies of tiny singular values and GPTQ inverts
//! ill-conditioned Hessians — f32 accumulates visible error there.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn from_f32(data: &[f32], rows: usize, cols: usize) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&v| v as f64).collect() }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Dense GEMM: `self (r x k) * other (k x c)`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "gemm shape mismatch");
        let (r, k, c) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(r, c);
        // ikj loop order: streams `other` rows, writes each output row once.
        for i in 0..r {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * c..(i + 1) * c];
            for (kk, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * c..(kk + 1) * c];
                for j in 0..c {
                    out_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    /// Gram matrix `selfᵀ * self` (used for Hessians H = XᵀX).
    pub fn gram(&self) -> Mat {
        let (r, c) = (self.rows, self.cols);
        let mut out = Mat::zeros(c, c);
        for i in 0..r {
            let row = self.row(i);
            for a in 0..c {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[a * c..(a + 1) * c];
                for (b, &rb) in row.iter().enumerate() {
                    out_row[b] += ra * rb;
                }
            }
        }
        out
    }

    /// [`Mat::gram`] with output rows fanned out on `pool` (GPTQ Hessians
    /// are the hot caller). Every output element accumulates over the
    /// sample rows in the same order as the sequential version and is
    /// written by exactly one worker, so the result is bit-identical at
    /// any worker count.
    pub fn gram_pooled(&self, pool: &crate::util::Pool) -> Mat {
        let (r, c) = (self.rows, self.cols);
        let mut out = Mat::zeros(c, c);
        pool.par_chunks_mut(&mut out.data, c, |a, out_row| {
            for i in 0..r {
                let row = self.row(i);
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for (b, &rb) in row.iter().enumerate() {
                    out_row[b] += ra * rb;
                }
            }
        });
        out
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += v;
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn gram_matches_explicit() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&g2) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_pooled_bit_identical_to_gram() {
        let mut rng = crate::util::Rng::new(21);
        let (r, c) = (37, 29);
        let mut a = Mat::zeros(r, c);
        for v in &mut a.data {
            *v = rng.normal() * 1e2;
        }
        let base = a.gram();
        for workers in [1usize, 2, 4, 8] {
            let pooled = a.gram_pooled(&crate::util::Pool::new(workers));
            let identical = base
                .data
                .iter()
                .zip(&pooled.data)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(identical, "gram_pooled diverged at {workers} workers");
        }
    }
}
