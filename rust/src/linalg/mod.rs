//! Dense linear algebra substrate.
//!
//! Everything the diagnostics (SVD spectra) and the GPTQ backend
//! (Hessian Cholesky) need, implemented from scratch: the offline
//! registry has no LAPACK binding.

pub mod chol;
pub mod mat;
pub mod stats;
pub mod svd;

pub use chol::{
    cholesky, cholesky_blocked, cholesky_inverse, cholesky_inverse_upper, solve_lower,
    solve_upper,
};
pub use mat::Mat;
pub use stats::{pearson, spearman};
pub use svd::{singular_values, svd_jacobi};
