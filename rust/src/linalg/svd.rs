//! One-sided Jacobi SVD.
//!
//! The representational-compactness diagnostic (paper Eq. 3–5) needs the
//! full singular spectrum of projected representations Z = h W_Pᵀ (shape
//! T x d_head, e.g. 128 x 32). One-sided Jacobi is simple, numerically
//! robust, and plenty fast at these sizes: we rotate column pairs of A
//! until all pairs are orthogonal; column norms are then the singular
//! values.

use super::Mat;

/// Singular values of `a` (descending). For rows < cols the matrix is
/// transposed first (singular values are invariant).
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let (_, s, _) = svd_jacobi(a);
    s
}

/// One-sided Jacobi SVD: returns (U, σ, V) with `a = U diag(σ) Vᵀ`,
/// σ descending. U is m x r, V is n x r with r = min(m, n).
pub fn svd_jacobi(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    if a.rows < a.cols {
        let (v, s, u) = svd_jacobi(&a.transpose());
        return (u, s, v);
    }
    let m = a.rows;
    let n = a.cols;
    let mut u = a.clone(); // working copy; columns become U * diag(σ)
    let mut v = Mat::eye(n);

    let max_sweeps = 60;
    let tol = 1e-12;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                // Gram entries for the (p, q) column pair.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation that zeroes the Gram off-diagonal.
                let zeta = (aqq - app) / (2.0 * apq);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-11 {
            break;
        }
    }

    // Column norms -> singular values; normalize U columns.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u_out = Mat::zeros(m, n);
    let mut v_out = Mat::zeros(n, n);
    let mut sigma = Vec::with_capacity(n);
    for (rank, &(norm, j)) in sv.iter().enumerate() {
        sigma.push(norm);
        let inv = if norm > 1e-300 { 1.0 / norm } else { 0.0 };
        for i in 0..m {
            u_out[(i, rank)] = u[(i, j)] * inv;
        }
        for i in 0..n {
            v_out[(i, rank)] = v[(i, j)];
        }
    }
    (u_out, sigma, v_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::Rng;

    fn random_mat(rng: &mut Rng, m: usize, n: usize) -> Mat {
        let mut a = Mat::zeros(m, n);
        for v in &mut a.data {
            *v = rng.normal();
        }
        a
    }

    fn reconstruct(u: &Mat, s: &[f64], v: &Mat) -> Mat {
        let mut us = u.clone();
        for i in 0..us.rows {
            for j in 0..s.len() {
                us[(i, j)] *= s[j];
            }
        }
        us.matmul(&v.transpose())
    }

    #[test]
    fn reconstruction_property() {
        forall(
            "U S Vt == A",
            15,
            23,
            |rng| {
                let m = 3 + rng.below(20);
                let n = 2 + rng.below(10);
                random_mat(rng, m, n)
            },
            |a| {
                let (u, s, v) = svd_jacobi(a);
                let err = reconstruct(&u, &s, &v).max_abs_diff(a);
                if err < 1e-8 * (1.0 + a.frob_norm()) {
                    Ok(())
                } else {
                    Err(format!("reconstruction err {err}"))
                }
            },
        );
    }

    #[test]
    fn singular_values_descending_nonneg() {
        forall(
            "sigma sorted desc, >= 0",
            15,
            29,
            |rng| { let m = 4 + rng.below(16); let n = 2 + rng.below(8); random_mat(rng, m, n) },
            |a| {
                let s = singular_values(a);
                for w in s.windows(2) {
                    if w[0] < w[1] - 1e-12 {
                        return Err(format!("not sorted: {w:?}"));
                    }
                }
                if s.iter().any(|&x| x < 0.0) {
                    return Err("negative sigma".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0], vec![0.0, 0.0]]);
        let s = singular_values(&a);
        assert!((s[0] - 4.0).abs() < 1e-10 && (s[1] - 3.0).abs() < 1e-10, "{s:?}");
    }

    #[test]
    fn rank_one_matrix() {
        // a = u vᵀ has exactly one nonzero singular value = |u||v|.
        let u = [1.0, 2.0, 3.0];
        let v = [4.0, 5.0];
        let a = Mat::from_rows(
            &u.iter().map(|&ui| v.iter().map(|&vj| ui * vj).collect()).collect::<Vec<_>>(),
        );
        let s = singular_values(&a);
        let expect = (14.0f64).sqrt() * (41.0f64).sqrt();
        assert!((s[0] - expect).abs() < 1e-9, "{s:?}");
        assert!(s[1].abs() < 1e-9, "{s:?}");
    }

    #[test]
    fn wide_matrix_transposes() {
        let mut rng = Rng::new(31);
        let a = random_mat(&mut rng, 3, 9);
        let s1 = singular_values(&a);
        let s2 = singular_values(&a.transpose());
        for (x, y) in s1.iter().zip(&s2) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn frobenius_identity() {
        // ||A||_F^2 == sum sigma_i^2.
        let mut rng = Rng::new(37);
        let a = random_mat(&mut rng, 12, 7);
        let s = singular_values(&a);
        let sum_sq: f64 = s.iter().map(|x| x * x).sum();
        assert!((sum_sq - a.frob_norm().powi(2)).abs() < 1e-8);
    }
}
