//! CLI command implementations (thin orchestration over the library).

use anyhow::Result;

use crate::coordinator::pipeline::{LieqPipeline, PipelineOptions};
use crate::coordinator::server::{
    AdmissionPolicy, Response, SessionOptions, SubmitError, SubmitOptions, WorkerRuntime,
};
use crate::corpus::{self, Bucket, Corpus, Domain};
use crate::diagnostics::score::{aggregate, ScoreWeights};
use crate::eval::ppl::{perplexity, NllBatcher};
use crate::eval::tasks::{generate, task_accuracy, ALL_TASKS};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::Backend;
use crate::tokenizer::Bpe;
use crate::train::{trained_params, TrainOptions};
use crate::util::cli::Args;
use crate::util::fmt_metric;

/// Default training steps per config size (scaled for the 1-core testbed).
pub fn default_steps(name: &str) -> usize {
    match name {
        n if n.ends_with("nano") => 300,
        n if n.ends_with("micro") => 240,
        n if n.ends_with("small") => 180,
        _ => 120,
    }
}

/// Shared setup: config + tokenizer + trained (cached) parameters.
pub fn setup(args: &Args, model: &str) -> Result<(ModelConfig, Bpe, ParamStore)> {
    let root = crate::artifacts_dir();
    let cfg = ModelConfig::load(&root, model)?;
    cfg.validate()?;
    let bpe = corpus::shared_tokenizer(&root, cfg.vocab, 3);
    let steps = args.usize_or("steps", default_steps(model));
    let opt = TrainOptions { steps, ..Default::default() };
    let (params, report) = trained_params(&cfg, &bpe, &opt)?;
    if let Some(r) = report {
        log::info!(
            "[{}] trained {} steps in {:.0}s ({:.0} tok/s), loss {:.3} -> {:.3}",
            cfg.name,
            r.steps,
            r.secs,
            r.tokens_per_sec,
            r.losses.first().map(|x| x.1).unwrap_or(f32::NAN),
            r.final_loss
        );
    }
    Ok((cfg, bpe, params))
}

pub fn pipeline_options(args: &Args) -> PipelineOptions {
    let mut opt = PipelineOptions::default();
    if args.flag("fast") {
        opt.diag_passages = 6;
    }
    if let Some(p) = args.get("passages") {
        opt.diag_passages = p.parse().unwrap_or(opt.diag_passages);
    }
    opt.top_m = args.usize_or("top-m", 1);
    opt.hi_bits = args.usize_or("hi-bits", 4) as u8;
    opt.lo_bits = args.usize_or("lo-bits", 2) as u8;
    if let Some(b) = args.get("backend").and_then(Backend::from_name) {
        opt.backend = b;
    }
    if let Some(e) = args.get("outlier-eps") {
        opt.outlier_eps = e.parse::<f64>().unwrap_or(0.0).clamp(0.0, 1.0);
    }
    let domains = args.list("domains");
    if !domains.is_empty() {
        opt.diag_domains = domains.iter().filter_map(|d| Domain::from_name(d)).collect();
    }
    opt
}

pub fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_nano").to_string();
    let (_cfg, _bpe, _params) = setup(args, &model)?;
    println!("trained checkpoint ready for {model}");
    Ok(())
}

pub fn cmd_diagnose(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_nano").to_string();
    let (cfg, bpe, params) = setup(args, &model)?;
    let pipe = LieqPipeline::new(&cfg, &bpe);
    let opt = pipeline_options(args);
    let diag = pipe.diagnose(&params, &opt)?;
    let scores = aggregate(&diag, ScoreWeights::default());
    println!("layer  dPPL        dR         dE         score");
    for l in 0..cfg.n_layers {
        println!(
            "{l:>5}  {:>9}  {:>9.4}  {:>9.4}  {:>8.4}",
            fmt_metric(diag.ppl_drop[l]),
            diag.compact_delta[l],
            diag.energy_delta[l],
            scores.s[l]
        );
    }
    println!("base PPL: {}", fmt_metric(diag.base_ppl));
    Ok(())
}

pub fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_nano").to_string();
    let (cfg, bpe, params) = setup(args, &model)?;
    let pipe = LieqPipeline::new(&cfg, &bpe);
    let opt = pipeline_options(args);
    let result = pipe.run(&params, &opt)?;
    println!(
        "LieQ {model}: avg bits {:.2}, FP16 ppl {} -> quant ppl {} ({}:top-{} hi{}|lo{})",
        result.avg_bits,
        fmt_metric(result.fp16_ppl),
        fmt_metric(result.quant_ppl),
        opt.backend.name(),
        opt.top_m,
        opt.hi_bits,
        opt.lo_bits
    );
    println!("bits per layer: {:?}", result.bits.0);
    if opt.outlier_eps > 0.0 {
        println!(
            "outlier sidecar (eps {:.3}): +{:.3} bits/weight fp16 overhead \
             -> {:.2} effective avg bits",
            opt.outlier_eps,
            result.outlier_overhead_bits,
            result.avg_bits + result.outlier_overhead_bits
        );
    }
    let kp = result.kernel_paths;
    if kp.total_calls() > 0 {
        println!(
            "kernel paths: {} direct / {} panel / {} lut / {} a8 calls \
             ({} nibble + {} byte, {} lut builds, {} lane builds; \
             {} outlier-fused, {} outlier cols; \
             simd {}: {} direct / {} panel / {} lut)",
            kp.direct_calls,
            kp.panel_calls,
            kp.lut_calls,
            kp.a8_calls,
            kp.lut_nibble_calls,
            kp.lut_byte_calls,
            kp.lut_builds,
            kp.lane_builds,
            kp.outlier_fused_calls,
            kp.outlier_cols,
            crate::kernels::current_tier().name(),
            kp.simd_direct_calls,
            kp.simd_panel_calls,
            kp.simd_lut_calls
        );
    }
    if let Some(out) = args.get("out") {
        if args.flag("packed") {
            // Deployment archive (.lieq v2/v3): real bit-plane payload per
            // quantized linear plus the interleaved lane image, so a cold
            // `lieq serve --archive` skips every planes->lanes conversion.
            // One capture is reused for backend calibration, the
            // native-grid GPTQ replay, and INT8 activation calibration
            // (the W·A8 kernel's per-linear parameters).
            if !matches!(opt.backend, Backend::Rtn | Backend::Gptq) {
                log::warn!(
                    "--packed re-derives per-group grids from the {} output; the archived \
                     payload can differ from the evaluated f32 checkpoint (exact for RTN \
                     and for GPTQ via native-grid replay — see quant::pack_model_entries)",
                    opt.backend.name()
                );
            }
            let cap = pipe.capture(&params)?;
            let q =
                crate::quant::quantize_model(&cfg, &params, &result.bits, opt.backend, Some(&cap))?;
            let entries = crate::quant::pack_model_entries(
                &cfg,
                &q,
                &result.bits,
                opt.backend,
                Some(&params),
                Some(&cap),
                opt.outlier_eps,
            )?;
            crate::tensor::write_archive_v2(out, &entries, true)?;
            let (mut n_packed, mut n_act, mut n_side, mut side_cols) = (0usize, 0usize, 0usize, 0usize);
            for (_, e) in &entries {
                if let crate::tensor::ArchiveEntry::Packed(pw) = e {
                    n_packed += 1;
                    n_act += pw.act.is_some() as usize;
                    let nc = pw.outlier_cols();
                    n_side += (nc > 0) as usize;
                    side_cols += nc;
                }
            }
            println!(
                "saved packed archive to {out} ({n_packed} packed linears, {n_act} with \
                 act calibration, {n_side} with outlier sidecars ({side_cols} fp16 \
                 columns), lanes persisted)"
            );
        } else {
            let q = pipe.quantize_with(&params, &result.bits, opt.backend)?;
            q.save(out)?;
            println!("saved quantized checkpoint to {out}");
        }
    } else if args.flag("packed") {
        log::warn!("--packed has no effect without --out <path>; nothing was written");
    }
    Ok(())
}

pub fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_nano").to_string();
    let (cfg, bpe, params) = setup(args, &model)?;
    let params = match args.get("checkpoint") {
        Some(p) => ParamStore::load(&cfg, p)?,
        None => params,
    };
    let domain = Domain::from_name(args.get_or("domain", "wiki")).unwrap_or(Domain::Wiki);
    // Same world as training (seed 3), held-out passage index range.
    let corpus = Corpus::new(domain, 3);
    let n = args.usize_or("passages", 16);
    let passages = corpus.sample_bucket_from(&bpe, Bucket::Short, n, 50_000);
    let ppl = perplexity(&cfg, &params, &passages)?;
    println!("{model} on {}: ppl {}", domain.name(), fmt_metric(ppl));
    Ok(())
}

pub fn cmd_eval_tasks(args: &Args) -> Result<()> {
    let model = args.get_or("model", "q_nano").to_string();
    let (cfg, bpe, params) = setup(args, &model)?;
    let batcher = NllBatcher::new(&cfg, &params)?;
    let world = Corpus::new(Domain::Wiki, 3).world;
    let n = args.usize_or("items", 40);
    let mut total = 0.0;
    for suite in ALL_TASKS {
        let items = generate(&world, suite, n, 2024);
        let acc = task_accuracy(&batcher, &bpe, &items)?;
        total += acc;
        println!("{:<12} {:.1}%", suite.name(), acc * 100.0);
    }
    println!("{:<12} {:.1}%", "average", total / ALL_TASKS.len() as f64 * 100.0);
    Ok(())
}

pub fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::Arc;
    use std::time::Duration;

    let model = args.get_or("model", "q_nano").to_string();
    let (cfg, bpe, params) = setup(args, &model)?;
    let corpus = Corpus::new(Domain::Hh, 2027);
    let n = args.usize_or("requests", 32);
    let max_batch = args.usize_or("batch", 8);
    let workers = args.usize_or("workers", 0); // 0 = --threads / auto
    let rounds = args.usize_or("rounds", 1);
    let queue_cap = args.usize_or("queue-cap", 0); // 0 = unbounded
    let admission = match AdmissionPolicy::from_name(args.get_or("admission", "block")) {
        Some(p) => p,
        None => anyhow::bail!("unknown --admission (block|reject|shed)"),
    };
    // Iteration-level batching knobs: positions scored per decode
    // iteration (0 = whole request per iteration) and the prefix-reuse
    // KV cache geometry/budget (--kv-mb 0 disables reuse).
    let decode_chunk = args.usize_or("decode-chunk", 64);
    let kv_mb = args.usize_or("kv-mb", 16);
    let kv_block = args.usize_or("kv-block", 16);
    let deadline = args
        .get("deadline-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis);
    // `--variants 2,3` quantizes uniform 2- and 3-bit variants and A/B
    // routes requests across fp16 + each of them on one warm runtime.
    let variant_bits: Vec<u8> =
        args.list("variants").iter().filter_map(|v| v.parse().ok()).collect();
    let backend = args.get("backend").and_then(Backend::from_name).unwrap_or(Backend::Rtn);

    // `--replicas N` serves through the cluster tier instead: N
    // independent runtimes behind one session, least-loaded routing,
    // and failover migration of in-flight requests. `--shards SPEC`
    // (e.g. `0-5,6-11`) additionally pipelines each replica's scoring
    // across layer-range stages connected by bounded conduits (demo
    // affine stages: scores are final-stage activations, not NLL).
    let replicas = args.usize_or("replicas", 1);
    let shard_spec = args.get("shards").map(str::to_string);
    if replicas > 1 || shard_spec.is_some() {
        use crate::coordinator::cluster::shard::{
            affine_stage_factory, sharded_scorer_factory, ShardPipeline, ShardPlan,
        };
        use crate::coordinator::cluster::{ClusterRuntime, ClusterScorerFactory};

        let replicas = replicas.max(1);
        let mut cluster = match &shard_spec {
            Some(spec) => {
                let plan = ShardPlan::parse(spec, cfg.n_layers)?;
                println!(
                    "cluster: {replicas} replica(s), shard plan {plan} \
                     ({} stages over {} layers; demo affine stages)",
                    plan.n_shards(),
                    plan.n_layers()
                );
                let pipelines: Vec<Arc<ShardPipeline>> = (0..replicas)
                    .map(|_| {
                        Arc::new(ShardPipeline::new(
                            plan.clone(),
                            &params,
                            max_batch.max(1),
                            affine_stage_factory(),
                        ))
                    })
                    .collect();
                let factory: ClusterScorerFactory = Arc::new(move |ri, wid, p| {
                    sharded_scorer_factory(Arc::clone(&pipelines[ri]))(wid, p)
                });
                let workers_per = if workers == 0 { 2 } else { workers };
                ClusterRuntime::with_scorer_factory(
                    replicas,
                    workers_per,
                    Arc::new(params.clone()),
                    factory,
                )
            }
            None => {
                println!("cluster: {replicas} replica(s), full model per replica");
                ClusterRuntime::new(&cfg, &params, replicas, workers)
            }
        };
        let mut variant_ids: Vec<Option<String>> = vec![None];
        if !variant_bits.is_empty() {
            let pipe = LieqPipeline::new(&cfg, &bpe);
            for &b in &variant_bits {
                let bits = crate::quant::LayerBits::uniform(cfg.n_layers, b);
                let q = pipe.quantize_with(&params, &bits, backend)?;
                let id = format!("w{b}");
                cluster.register_variant(id.as_str(), Arc::new(q));
                println!(
                    "registered variant {id} on every replica \
                     ({b}-bit uniform, {})",
                    backend.name()
                );
                variant_ids.push(Some(id));
            }
        }
        cluster.configure_kv(kv_block.max(1), kv_mb * (1 << 20));
        let ready = cluster.wait_ready();
        println!("{ready} worker(s) ready across {replicas} replica(s)");
        let session = cluster.session(
            SessionOptions::new()
                .max_batch(max_batch)
                .queue_cap(queue_cap)
                .admission(admission)
                .decode_chunk(decode_chunk),
        )?;
        for round in 0..rounds.max(1) {
            let mut tickets = Vec::with_capacity(n);
            for i in 0..n {
                let tokens = bpe.encode(&corpus.passage(round * n + i, 4));
                let opt = SubmitOptions {
                    deadline,
                    variant: variant_ids[i % variant_ids.len()].clone(),
                    priority: 0,
                };
                match session.submit(tokens, opt) {
                    Ok(t) => tickets.push(Some(t)),
                    Err(SubmitError::QueueFull { .. }) => tickets.push(None),
                    Err(e) => anyhow::bail!("submit failed: {e}"),
                }
            }
            let resps: Vec<Option<Response>> =
                tickets.into_iter().map(|t| t.map(|t| t.recv())).collect();
            let served = resps.iter().flatten().filter(|r| r.is_ok()).count();
            println!(
                "round {round}: {} submitted -> {served} served; \
                 {} migration(s), {} already-streamed token(s) preserved",
                resps.len(),
                session.migration_count(),
                session.migrated_tokens()
            );
            print!("{}", session.stats().render());
            if served == 0 && resps.iter().flatten().count() > 0 {
                let reason = resps
                    .iter()
                    .flatten()
                    .find_map(|r| r.error.as_ref().map(|e| e.to_string()))
                    .unwrap_or_else(|| "unknown".to_string());
                anyhow::bail!("all requests failed: {reason}");
            }
        }
        return Ok(());
    }

    // Persistent runtime: workers (batchers + compiled artifacts) are
    // built once; every round reuses them, so rounds > 1 shows the
    // setup-cost amortization (`setup` column collapses to ~0).
    let mut runtime = WorkerRuntime::new(&cfg, &params, workers);
    let mut variant_ids: Vec<Option<String>> = vec![None]; // None = fp16 default
    if !variant_bits.is_empty() {
        let pipe = LieqPipeline::new(&cfg, &bpe);
        for &b in &variant_bits {
            let bits = crate::quant::LayerBits::uniform(cfg.n_layers, b);
            let q = pipe.quantize_with(&params, &bits, backend)?;
            let id = format!("w{b}");
            runtime.register_variant(id.as_str(), Arc::new(q));
            println!("registered variant {id} ({}-bit uniform, {})", b, backend.name());
            variant_ids.push(Some(id));
        }
    }

    // `--archive path.lieq` cold-loads a deployment archive (v1 f32
    // checkpoint or packed v2) through the process-wide single-flight
    // cache and registers it as an additional serving variant. Packed
    // linears also run a decode-shape readiness pass through the kernel
    // family; a v2 archive with persisted lane images performs **zero**
    // planes->lanes conversions here ("0 lane builds" below).
    if let Some(ap) = args.get("archive") {
        use crate::kernels::{KernelPath, KernelPolicy};
        use crate::tensor::ArchiveEntry;
        let kernel_base = crate::kernels::kernel_path_stats();
        let t_load = crate::util::Timer::start();
        let entries = crate::runtime::cache::load_archive_cached(ap)?;
        let store = crate::quant::store_from_entries(&cfg, &entries)?;
        let load_ms = t_load.secs() * 1e3;
        let packed: Vec<(&str, &crate::quant::PackedWeight)> = entries
            .iter()
            .filter_map(|(name, e)| match e {
                ArchiveEntry::Packed(pw) => Some((name.as_str(), pw)),
                ArchiveEntry::Tensor(_) => None,
            })
            .collect();
        // Direct evidence of persistence, independent of any counters.
        let seeded = packed.iter().filter(|(_, pw)| pw.lanes_built()).count();
        // Outlier residency: v4 sidecars that survived the load (a corrupt
        // sidecar degrades that linear to dense-only, shrinking this count).
        let (n_side, side_cols) = packed.iter().fold((0usize, 0usize), |(n, c), (_, pw)| {
            let nc = pw.outlier_cols();
            (n + (nc > 0) as usize, c + nc)
        });
        // Readiness pass pinned to the LUT path so the lanes are
        // exercised regardless of --kernel/LIEQ_KERNEL overrides or the
        // model's column widths — otherwise "0 lane builds" could just
        // mean the warmup never touched the lanes. Runs on the *cached*
        // weights (no clones), so any lanes built here stay warm for
        // every later load of this archive in the process.
        let lut = KernelPolicy::with_path(KernelPath::Lut);
        let mut rngx = crate::util::Rng::new(17);
        for (_, pw) in &packed {
            let x: Vec<f32> = (0..pw.k).map(|_| rngx.normal_f32()).collect();
            let mut out = vec![0f32; pw.n];
            crate::kernels::dq_gemm_with(&lut, &x, 1, pw, &mut out);
        }
        let kp = crate::kernels::kernel_path_stats().delta_from(kernel_base);
        println!(
            "archive {ap}: cold load {load_ms:.1} ms, {}/{} packed linears with \
             persisted lanes, {}/{} with resident outlier sidecars ({} fp16 \
             columns), warmed via {} lut calls ({} nibble / {} byte, {} \
             outlier-fused): {} lane builds (0 = cold-start-free)",
            seeded,
            packed.len(),
            n_side,
            packed.len(),
            side_cols,
            kp.lut_calls,
            kp.lut_nibble_calls,
            kp.lut_byte_calls,
            kp.outlier_fused_calls,
            kp.lane_builds
        );
        runtime.register_variant("archive", Arc::new(store));
        variant_ids.push(Some("archive".to_string()));
    }

    runtime.kv_cache().configure(kv_block.max(1), kv_mb * (1 << 20));
    let mut session = runtime.session(
        SessionOptions::new()
            .max_batch(max_batch)
            .queue_cap(queue_cap)
            .admission(admission)
            .decode_chunk(decode_chunk),
    )?;
    for round in 0..rounds.max(1) {
        // Streaming enqueue: one submit per request; tickets resolve in
        // submission order via wait_all.
        let mut tickets = Vec::with_capacity(n);
        for i in 0..n {
            let tokens = bpe.encode(&corpus.passage(round * n + i, 4));
            let opt = SubmitOptions {
                deadline,
                variant: variant_ids[i % variant_ids.len()].clone(),
                priority: 0,
            };
            match session.submit(tokens, opt) {
                Ok(t) => tickets.push(Some(t)),
                Err(SubmitError::QueueFull { .. }) => tickets.push(None),
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        }
        let resps: Vec<Option<Response>> =
            tickets.into_iter().map(|t| t.map(|t| t.recv())).collect();
        let s = session.drain_stats();
        println!(
            "round {round}: {} submitted -> {} served / {} failed / {} expired / \
             {} cancelled / {} shed / {} rejected in {} batches: p50 {:.1} ms, \
             p95 {:.1} ms, first-token p50 {:.1} ms / p95 {:.1} ms, {:.1} req/s \
             (peak queue {}, {} variant swaps, runtime cache {} hits / {} loads)",
            s.submitted,
            s.served,
            s.failed,
            s.expired,
            s.cancelled,
            s.shed,
            s.rejected,
            s.batches,
            s.p50_ms,
            s.p95_ms,
            s.first_token_p50_ms,
            s.first_token_p95_ms,
            s.throughput_rps,
            s.max_queue_depth,
            s.variant_swaps,
            s.cache.hits,
            s.cache.misses
        );
        println!(
            "  tokens: {} streamed ({} replayed from prefix cache); kv cache: \
             {} hits / {} misses ({:.0}% hit rate, {} tokens), {} inserted / \
             {} evicted, {} blocks resident ({:.1} MiB)",
            s.tokens_streamed,
            s.cached_tokens,
            s.kv.hits,
            s.kv.misses,
            s.kv.hit_rate() * 100.0,
            s.kv.hit_tokens,
            s.kv.inserted,
            s.kv.evicted,
            s.kv.resident_blocks,
            s.kv.resident_bytes as f64 / (1 << 20) as f64
        );
        for vid in &variant_ids {
            let scored: Vec<f32> = resps
                .iter()
                .flatten()
                .filter(|r| r.is_ok() && r.variant == *vid)
                .map(|r| r.mean_nll)
                .collect();
            if !scored.is_empty() {
                let mean: f32 = scored.iter().sum::<f32>() / scored.len() as f32;
                println!(
                    "  [{}] mean NLL across {} requests: {mean:.3}",
                    vid.as_deref().unwrap_or("fp16"),
                    scored.len()
                );
            }
        }
        let kp = s.kernel_paths;
        if kp.total_calls() > 0 {
            println!(
                "  kernel paths: {} direct / {} panel / {} lut / {} a8 calls \
                 ({} nibble + {} byte, {} lane builds; {} outlier-fused, \
                 {} outlier cols; simd {}: {} direct / {} panel / {} lut)",
                kp.direct_calls,
                kp.panel_calls,
                kp.lut_calls,
                kp.a8_calls,
                kp.lut_nibble_calls,
                kp.lut_byte_calls,
                kp.lane_builds,
                kp.outlier_fused_calls,
                kp.outlier_cols,
                crate::kernels::current_tier().name(),
                kp.simd_direct_calls,
                kp.simd_panel_calls,
                kp.simd_lut_calls
            );
        }
        // Total failure must not look like success (exit 0): surface the
        // per-request error instead of only counting it.
        if s.served == 0 && s.error_replies() > 0 {
            let reason = resps
                .iter()
                .flatten()
                .find_map(|r| r.error.as_ref().map(|e| e.to_string()))
                .unwrap_or_else(|| "unknown".to_string());
            anyhow::bail!("all {} requests failed: {reason}", s.error_replies());
        }
    }
    Ok(())
}

/// `lieq lint [--deny] [--json PATH] [--root SRC_DIR]` — run the
/// self-hosted static analysis over the crate's own sources.
pub fn cmd_lint(args: &Args) -> Result<()> {
    let root = lint_src_root(args);
    let krate = crate::analysis::Crate::load(&root)?;
    let report = crate::analysis::run_all(&krate);
    print!("{}", report.render_text());
    if let Some(path) = args.get("json") {
        report.to_json().write_file(path)?;
        log::info!("wrote {path}");
    }
    let unwaived = report.unwaived().len();
    if unwaived > 0 && args.flag("deny") {
        anyhow::bail!("lint: {unwaived} unwaived finding(s)");
    }
    Ok(())
}

/// Source root for `lint`: `--root` wins; otherwise walk up from the
/// cwd to the first directory holding `rust/src/lib.rs` (repo root) or
/// `src/lib.rs` (crate dir), same discovery style as `artifacts_dir`.
fn lint_src_root(args: &Args) -> std::path::PathBuf {
    if let Some(r) = args.get("root") {
        return r.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return dir.join("rust/src");
        }
        if dir.join("src/lib.rs").is_file() {
            return dir.join("src");
        }
        if !dir.pop() {
            return "rust/src".into();
        }
    }
}
