//! # LieQ — Layer-wise Information Effectiveness Quantization
//!
//! Production-shaped reproduction of *"Exploring Layer-wise Information
//! Effectiveness for Post-Training Quantization in Small Language Models"*
//! (Xiao et al., ACL 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! The Rust crate is **Layer 3**: it owns the entire post-training
//! quantization pipeline — calibration scheduling, the paper's three
//! layer-wise diagnostics, bit-width allocation, the PTQ backends
//! (RTN / GPTQ / AWQ / PB-LLM / SliM-LLM baselines and LieQ itself),
//! packed-weight deployment kernels, evaluation harnesses and benches.
//! Model compute (forward NLL, activation capture, the AdamW train step,
//! and the Pallas fused dequant-GEMM) runs as AOT-compiled XLA artifacts
//! loaded through PJRT (`runtime`); Python never runs at request time.
//!
//! Module map (see DESIGN.md §3 for the full inventory):
//!
//! * [`util`] — RNG, JSON, CLI, logging, micro-bench + property-test
//!   harnesses, and the scoped work-sharing thread pool ([`util::pool`],
//!   no rayon offline) that every parallel hot path — `kernels::dq_gemm`,
//!   per-layer diagnostics, `quant::quantize_model`, the serving loop —
//!   runs on (the offline registry has no serde/clap/criterion/proptest,
//!   so these are first-class substrates).
//! * [`linalg`] — dense matrices, Cholesky, one-sided Jacobi SVD, rank
//!   statistics (Spearman/Pearson).
//! * [`tensor`] — n-d `f32`/`i32`/`u32` tensors + the `.lieq` archive
//!   format shared with the Python compile path.
//! * [`tokenizer`] — byte-level BPE (trainer + encoder/decoder).
//! * [`corpus`] — five synthetic corpus domains standing in for
//!   WikiText-2 / C4 / PTB / Dolly / HH-RLHF, with length bucketing.
//! * [`model`] — model configs mirrored from `python/compile/configs.py`,
//!   parameter stores, manifest binding.
//! * [`runtime`] — PJRT client wrapper, artifact registry, executables
//!   (feature `pjrt`; a pure-Rust stub compiles in by default so offline
//!   builds need no `xla` crate).
//! * [`train`] — Rust-driven training loop over the `train_step` artifact.
//! * [`quant`] — quantization primitives, bit-plane packing, backends.
//! * [`diagnostics`] — the paper's contribution: ΔPPL, representational
//!   compactness, top-k energy, score aggregation, bit allocation.
//! * [`eval`] — perplexity + zero-shot suite harnesses.
//! * [`kernels`] — CPU deployment kernel family (direct bit-plane,
//!   interleaved-lane LUT GEMV, cache-tiled row panel) behind a runtime
//!   `KernelPolicy` dispatcher; bit-identical results at any thread
//!   count, per-path traffic counters.
//! * [`coordinator`] — pipeline orchestration, calibration scheduler,
//!   continuously-batched streaming serving runtime (token-event
//!   tickets, EDF formation, prefix-reuse KV cache), metrics.

// Dense index-style kernels and table plumbing read better with explicit
// loops and wide signatures; keep clippy's style lints out of the way so
// CI can gate on `-D warnings` for the lints that matter.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::inherent_to_string,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::identity_op,
    clippy::erasing_op
)]

pub mod analysis;
pub mod coordinator;
pub mod corpus;
pub mod diagnostics;
pub mod eval;
pub mod kernels;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod tokenizer;
pub mod train;
pub mod util;

/// Repo-relative artifact root (overridable via `LIEQ_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LIEQ_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd until a directory containing `artifacts/` is found
    // (so tests/benches/examples work from any workspace subdir).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

pub mod cmds;
pub mod experiments;
